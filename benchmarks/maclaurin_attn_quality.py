"""Beyond-paper benchmark: the Maclaurin collapse as decode attention.

Two tables:
  (a) approximation quality vs logit magnitude — the attention analogue of
      the paper's Fig 1 / Eq 3.11 story: output error vs scale of q.k.
  (b) decode-state memory: KV-cache bytes vs Maclaurin-state bytes per
      assigned arch at 32k and 500k context — the Table-3 analogue where
      'support vectors' are KV entries.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.kernels.maclaurin_attn import maclaurin_attention_ref, softmax_attention_ref
from benchmarks.common import fmt_table, save_json


def quality_rows() -> list[dict]:
    rng = np.random.default_rng(0)
    B, H, T, D = 1, 4, 128, 32
    rows = []
    for sigma in (0.25, 0.5, 1.0, 2.0):
        q = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32)) * sigma
        k = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32)) * sigma
        v = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32))
        exact = np.asarray(softmax_attention_ref(q, k, v))
        approx = np.asarray(maclaurin_attention_ref(q, k, v))
        rel = np.abs(exact - approx) / (np.abs(exact) + 1e-2)
        u = np.asarray(jnp.einsum("bhtd,bhsd->bhts", q, k)) / np.sqrt(D)
        rows.append({
            "qk_sigma": sigma,
            "max|u|": round(float(np.abs(u).max()), 2),
            "bound_ok": bool(np.abs(u).max() < 0.5),
            "median_rel_err": round(float(np.median(rel)), 4),
            "p90_rel_err": round(float(np.quantile(rel, 0.9)), 4),
        })
    return rows


def state_rows() -> list[dict]:
    rows = []
    for name, cfg in sorted(ARCHS.items()):
        if cfg.family == "ssm":
            continue  # attention-free: technique inapplicable (DESIGN.md §7)
        hd, Hkv, L = cfg.hd, cfg.n_kv_heads, cfg.n_layers
        if cfg.family == "hybrid":
            L = cfg.n_layers // cfg.hybrid_attn_every  # shared-attn applications
        mac_state = L * Hkv * (hd * hd * hd + hd * hd + hd + hd * hd + hd + 3)
        for S in (32768, 524288):
            kv = L * 2 * S * Hkv * hd
            rows.append({
                "arch": name,
                "S": S,
                "kv_cache_MB_bf16": round(kv * 2 / 2**20, 1),
                "mac_state_MB_f32": round(mac_state * 4 / 2**20, 1),
                "ratio": round(kv * 2 / (mac_state * 4), 2),
            })
    return rows


def run() -> dict:
    q = quality_rows()
    s = state_rows()
    print("[mac-attn] (a) approximation error vs q.k magnitude "
          "(the Eq 3.11 envelope, attention edition)")
    print(fmt_table(q, ["qk_sigma", "max|u|", "bound_ok", "median_rel_err", "p90_rel_err"]))
    print("[mac-attn] (b) per-sequence decode state: KV cache vs Maclaurin state")
    print(fmt_table(s, ["arch", "S", "kv_cache_MB_bf16", "mac_state_MB_f32", "ratio"]))
    out = {"quality": q, "state": s}
    save_json("maclaurin_attn_quality.json", out)
    return out


if __name__ == "__main__":
    run()
