"""Benchmark orchestrator: one module per paper table/figure + the
beyond-paper adaptation + the roofline summary (if dry-run results exist).

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time
import traceback


def main():
    t0 = time.time()
    failures = []
    sections = []

    def section(name, fn):
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        try:
            fn()
            sections.append(name)
        except Exception:
            traceback.print_exc()
            failures.append(name)

    from benchmarks import fig1_error, table1_accuracy, table2_speed
    from benchmarks import table3_modelsize, maclaurin_attn_quality
    from benchmarks import serving_latency

    section("Fig 1 — Maclaurin exp relative error", fig1_error.run)
    section("Table 1 — accuracy / label-diff", table1_accuracy.run)
    section("Table 2 — prediction speed (measured, CPU)", table2_speed.run)
    section("Table 3 — model size", table3_modelsize.run)
    section("Beyond-paper — Maclaurin attention", maclaurin_attn_quality.run)
    section("Serving — engine latency + fused head scaling", serving_latency.run)

    def roofline():
        import glob
        if not glob.glob("results/dryrun/*.json"):
            print("no dry-run artifacts found; run: "
                  "PYTHONPATH=src python -m repro.launch.dryrun --all")
            return
        from repro.launch import roofline as rl
        rl.main()

    section("Roofline — 40-cell dry-run summary", roofline)

    print(f"\n{'='*72}")
    print(f"benchmarks done in {time.time()-t0:.1f}s; "
          f"{len(sections)} sections ok, {len(failures)} failed {failures or ''}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
