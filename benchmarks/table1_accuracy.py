"""Table-1 reproduction: per data set — d, gamma_max, gamma, n_test, n_sv,
exact accuracy, and the fraction of labels that DIFFER between exact and
approximated models.

Protocol follows the paper: gamma is chosen at the paper's gamma/gamma_MAX
RATIO for each data set (our synthetic stand-ins have different norms, so
absolute gammas would not be comparable; the ratio is what the bound is
about). LS-SVM training (all points become SVs — the regime the paper
highlights for maximal compression).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (
    approximate,
    approx_decision_function_checked,
    decision_function,
    gamma_max,
)
from repro.data.synthetic import make_dataset
from repro.svm import train_lssvm
from benchmarks.common import fmt_table, save_json

# paper Table 1 gamma / gamma_MAX ratios (first row per data set + extras)
PAPER_RATIOS = {
    "a9a": [0.556, 1.111, 5.556],
    "mnist": [0.1],
    "ijcnn1": [0.781],
    "sensit": [1.2],
    "epsilon": [1.4],
}
# keep the KKT solve tractable on 1 CPU core: n_train ~<= 1500
SCALES = {"a9a": 0.045, "mnist": 0.022, "ijcnn1": 0.03, "sensit": 0.018, "epsilon": 0.0035}


def run() -> list[dict]:
    rows = []
    for name, ratios in PAPER_RATIOS.items():
        Xtr, ytr, Xte, yte, spec = make_dataset(name, scale=SCALES[name], seed=0)
        Xtr_j, ytr_j = jnp.asarray(Xtr), jnp.asarray(ytr)
        Xte_j = jnp.asarray(Xte)
        gm = float(gamma_max(jnp.asarray(np.concatenate([Xtr, Xte]))))
        for ratio in ratios:
            gamma = gm * ratio
            m = train_lssvm(Xtr_j, ytr_j, jnp.float32(gamma), jnp.float32(10.0))
            f = np.asarray(decision_function(m, Xte_j))
            am = approximate(m)
            fh, valid = approx_decision_function_checked(am, Xte_j)
            fh = np.asarray(fh)
            acc = float((np.sign(f) == yte).mean())
            diff = float((np.sign(fh) != np.sign(f)).mean())
            rows.append({
                "dataset": name,
                "d": spec.d,
                "gamma_max": round(gm, 6),
                "gamma": round(gamma, 6),
                "gamma/g_max": ratio,
                "n_test": len(yte),
                "n_sv": m.n_sv,
                "acc%": round(100 * acc, 1),
                "diff%": round(100 * diff, 2),
                "bound_ok%": round(100 * float(np.asarray(valid).mean()), 1),
            })
    print("[table1] exact vs approximated label agreement (paper Table 1 analogue)")
    print(fmt_table(rows, ["dataset", "d", "gamma_max", "gamma", "gamma/g_max",
                           "n_test", "n_sv", "acc%", "diff%", "bound_ok%"]))
    save_json("table1.json", rows)
    # the paper's claim: under the bound, diff stays ~< 1%
    under = [r for r in rows if r["gamma/g_max"] <= 1.0]
    worst = max(r["diff%"] for r in under) if under else None
    print(f"[table1] worst diff under the bound: {worst}% (paper: <1%)")
    return rows


if __name__ == "__main__":
    run()
