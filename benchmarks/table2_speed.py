"""Table-2 reproduction: prediction wall-time, exact vs approximated.

Measured for real on this CPU (the paper's own experiment is CPU timing).
Columns mirror the paper:

    approach x math:  exact GEMM   (the BLAS analogue — XLA dot)
                      exact LOOPS  (the paper's naive-loop baseline: lax.scan
                                    over SVs, one exp per SV per instance)
                      approx       (quadratic form, Eq 3.8)
    t_approx          one-off cost of building (c, v, M)  [ATLAS column]
    ratio1            exact / approx          (ignoring build time)
    ratio2            exact / (approx + build/n_batches)  [amortized]
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import approximate, decision_function, gamma_max
from repro.core.maclaurin import approx_decision_function
from repro.core.rbf import SVMModel, decision_function_loops
from repro.data.synthetic import make_dataset
from benchmarks.common import fmt_table, save_json, timeit

# (scale for n_sv, n_test cap) — full d always; n_sv chosen to keep the
# n_sv/d ratios in the paper's regimes on a 1-core budget.
SETTINGS = {
    "a9a": (0.08, 4000),
    "mnist": (0.02, 2000),
    "ijcnn1": (0.06, 8000),
    "sensit": (0.04, 4000),
    "epsilon": (0.004, 1000),
}


def run() -> list[dict]:
    rows = []
    for name, (scale, n_test_cap) in SETTINGS.items():
        Xtr, ytr, Xte, yte, spec = make_dataset(name, scale=scale, seed=0)
        n_sv = len(Xtr)
        rng = np.random.default_rng(0)
        # random expansion weights stand in for trained alphas — timing is
        # independent of the alpha values
        ay = rng.standard_normal(n_sv).astype(np.float32)
        gamma = float(gamma_max(jnp.asarray(Xtr))) * 0.8
        m = SVMModel(
            X=jnp.asarray(Xtr), alpha_y=jnp.asarray(ay),
            b=jnp.float32(0.1), gamma=jnp.float32(gamma),
        )
        Z = jnp.asarray(Xte[:n_test_cap])

        exact_fn = jax.jit(decision_function)
        loops_fn = jax.jit(decision_function_loops)
        t_exact = timeit(exact_fn, m, Z)
        t_loops = timeit(loops_fn, m, Z)

        # approximation build (the paper's t_approx; ATLAS == XLA GEMM here)
        approx_fn = jax.jit(approximate)
        t_build = timeit(approx_fn, m)
        am = approx_fn(m)
        pred_fn = jax.jit(approx_decision_function)
        t_approx = timeit(pred_fn, am, Z)

        ratio1 = t_exact / t_approx
        ratio2 = t_exact / (t_approx + t_build)
        rows.append({
            "dataset": name,
            "d": spec.d,
            "n_sv": n_sv,
            "n_test": int(Z.shape[0]),
            "t_exact_ms": round(1e3 * t_exact, 2),
            "t_loops_ms": round(1e3 * t_loops, 2),
            "t_build_ms": round(1e3 * t_build, 2),
            "t_approx_ms": round(1e3 * t_approx, 3),
            "ratio1": round(ratio1, 1),
            "ratio2": round(ratio2, 1),
            "nsv/d": round(n_sv / spec.d, 1),
        })
    print("[table2] prediction speed, exact vs approximated (CPU, measured)")
    print(fmt_table(rows, ["dataset", "d", "n_sv", "n_test", "t_exact_ms",
                           "t_loops_ms", "t_build_ms", "t_approx_ms",
                           "ratio1", "ratio2", "nsv/d"]))
    save_json("table2.json", rows)
    print("[table2] paper: speedups 7-137x, largest when n_sv >> d; "
          "LOOPS slower than GEMM (their LOOPS vs BLAS ordering)")
    return rows


if __name__ == "__main__":
    run()
