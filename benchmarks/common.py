"""Shared benchmark utilities: wall-clock timing on the real CPU device.

The paper's own experiments are CPU prediction-speed measurements, so the
Table-2 analogue here is a GENUINE measurement, not a proxy (DESIGN.md §9).
"""

from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results")


def timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N wall time of a jitted fn (seconds); blocks on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def fmt_table(rows: list[dict], cols: list[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    out = [" | ".join(c.ljust(widths[c]) for c in cols)]
    out.append("-|-".join("-" * widths[c] for c in cols))
    for r in rows:
        out.append(" | ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(out)
