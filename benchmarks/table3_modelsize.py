"""Table-3 reproduction: model size, exact vs approximated.

Sizes are computed at the PAPER's exact (d, n_sv) per data set — size
accounting needs shapes, not trained weights — plus our trained scaled
models for cross-checking. The paper stores text; we report binary f32
bytes for both models, so the RATIO is the comparable quantity.
"""

from __future__ import annotations

from benchmarks.common import fmt_table, save_json

# (d, n_sv) from the paper's Tables 1/3
PAPER_SHAPES = {
    "a9a": (122, 11834),
    "mnist": (780, 2174),
    "ijcnn1": (22, 4044),
    "sensit": (100, 25722),
    "epsilon": (2000, 36988),
}
PAPER_RATIOS = {"a9a": 7.5, "mnist": 0.86, "ijcnn1": 150, "sensit": 290, "epsilon": 27}


def run() -> list[dict]:
    rows = []
    for name, (d, n_sv) in PAPER_SHAPES.items():
        exact_bytes = 4 * (n_sv * d + n_sv + 2)        # X, alpha_y, b, gamma
        approx_bytes = 4 * (d * d + d + 4)             # M, v, c, b, gamma, ||x_M||^2
        ratio = exact_bytes / approx_bytes
        rows.append({
            "dataset": name,
            "d": d,
            "n_sv": n_sv,
            "exact_KB": round(exact_bytes / 1024, 1),
            "approx_KB": round(approx_bytes / 1024, 1),
            "ratio": round(ratio, 2),
            "paper_ratio": PAPER_RATIOS[name],
        })
    print("[table3] model size, exact vs approximated (paper shapes, f32)")
    print(fmt_table(rows, ["dataset", "d", "n_sv", "exact_KB", "approx_KB",
                           "ratio", "paper_ratio"]))
    save_json("table3.json", rows)
    print("[table3] ordering matches the paper: mnist (n_sv~3d) barely "
          "compresses; sensit/ijcnn1 (n_sv>>d) compress 100-300x")
    return rows


if __name__ == "__main__":
    run()
