"""Serving-path latency: engine p50/p99 per shape bucket, fused multi-head
vs per-head-vmap scaling, the approximation-family comparison, the
per-bucket block-size sweep that feeds the checked-in tuning table, and
the multi-tenant runtime's coalesced-vs-per-request throughput.

``--smoke`` shrinks repeat counts for CI (same sections, same JSON shape,
noisier numbers). Naming sections (e.g. ``runtime_throughput``) runs only
those and MERGES them into the existing results JSON, so a partial rerun
never clobbers the other sections' trajectory.

Five questions, all measured for real on this host:

1. What end-to-end latency does ``SVMEngine.predict`` deliver per shape
   bucket once warm (zero recompiles)?  p50 is the steady-state cost; p99
   captures jitter (allocator, host padding, sync).
2. What does fusing K heads into one stacked-Hessian contraction buy over
   the seed's K-pass vmap?  Measured at K in {1, 10} on identical data —
   the ratio is the multiclass serving speedup.
3. Which approximation family serves a given (K, d) cheapest, and at what
   accuracy?  ``family_compare`` compiles the SAME synthetic model through
   the maclaurin, poly2 and fourier families (``repro.core.families``),
   serves each through its engine fast path, and reports p50/p99 next to
   the measured error vs the exact RBF expansion — the exact path itself
   is timed as the baseline row. This is the data ``compile_model``'s
   budget decision is made of, recorded over the trajectory.
4. Which tile sizes are fastest per shape bucket?  The sweep times the
   DISPATCHED serving primitives over candidate ``TileConfig``s (default
   included, so the recorded pick can only tie or beat it), records the
   winners through ``repro.kernels.common.autotune`` and persists them to
   the checked-in ``tuning_table.json`` the engine reads back at warmup.
   On non-TPU hosts the dispatched path is XLA and ignores block sizes —
   the spread there is timing noise and the table entry simply pins the
   default-equivalent winner; on a TPU host the same sweep produces real
   per-bucket Pallas tilings.
5. What does micro-batching buy under concurrent traffic?
   ``runtime_throughput`` drives the multi-tenant ``Runtime`` with
   open-loop concurrent clients issuing small (4-row) requests and
   compares coalesced throughput against the same clients calling
   ``engine.predict`` per request (closed loop) — the scheduler must win
   at >= 8 clients, with ZERO steady-state recompiles (asserted via
   ``jit_cache_size`` before/after the stress).
6. What happens when offered load exceeds capacity?  ``overload`` pins
   the per-flush service time with the deterministic fault injector's
   slow-step hook, then bursts far past that capacity through a runtime
   with a bounded queue. Admission control must shed the excess with
   typed ``RuntimeOverloaded`` (every shed carries a ``retry_after_s``
   hint), every ADMITTED future must resolve (zero hung futures), the
   shed accounting must balance to the request (admitted + shed ==
   submitted), and p99 of the admitted traffic stays bounded because the
   queue is — all gated by ``tools/check_bench_invariants.py``.
8. Does the runtime scale across devices?  ``scaleout`` pins per-flush
   service time with the slow-step hook (one physical core backs every
   forced host device, so a GIL-releasing sleep inside each replica's
   dispatch thread is what can honestly overlap here), then publishes
   the same model at ``replicas`` in {1, 2, 4, 8} and requires rows/s to
   rise monotonically with replica count at zero steady-state
   recompiles — the dispatcher's concurrency, the property that
   transfers to real multi-device hosts. The same section serves a
   K=4096 OvR model through the head-sharded ``shard_map`` path and
   gates per-row argmax parity vs the unsharded reference at small K.
   Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
9. What does breaker-open degraded serving cost?  ``degraded_mode``
   trips the per-model circuit breaker with scripted engine faults,
   then measures the exact streaming ``rbf_pred`` degraded path next to
   the healthy fast path on identical traffic. The gated invariants:
   the breaker really is open during the degraded measurement, every
   degraded request is served (none shed, none hung), and degraded
   serving adds ZERO fast-path recompiles (it compiles its own slow
   variants, never touching the bucket cache).

Emits BENCH_serving.json (benchmarks/common.save_json) so later perf PRs
have a trajectory to compare against.
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import RESULTS_DIR, fmt_table, save_json, timeit
from repro.core import approximate, backend, families, gamma_max
from repro.core.rbf import SVMModel, rbf_kernel
from repro.kernels.common import TileConfig, autotune, tuning
from repro.serve.runtime import (
    ENGINE_STEP,
    FaultInjector,
    MetricsRegistry,
    Observability,
    PublishSpec,
    Runtime,
    RuntimeOverloaded,
)
from repro.serve.server import create_app
from repro.serve.server import serve as http_serve
from repro.kernels.quadform.ref import quadform_heads_ref
from repro.serve.svm_engine import SVMEngine, bucket_size

D = 64
N_SV = 512
BATCHES = [1, 8, 32, 64, 256, 1024]
REPEATS = 200
HEAD_COUNTS = [1, 10]
HEADS_BATCH = 1024
SWEEP_BUCKETS = [32, 256, 1024]
SWEEP_BLOCK_N = [64, 128, 256, 512]
SWEEP_BLOCK_M = [64, 128, 256, 512]
SWEEP_PRIOR_KEEP = 3          # measured configs per sweep (+ the default)

# family_compare grid (ISSUE 3): quadform cost grows as K d^2, RFF as F d —
# the d axis is where the families cross over. Every family is measured
# at both storage dtypes (ISSUE 5): int8 rows show what fused-dequant
# serving costs next to the f32 baseline at identical (K, d).
FAMILY_HEADS = [1, 10]
FAMILY_DIMS = [16, 64, 784]
FAMILY_NSV = 256
FAMILY_BATCH = 256
FAMILY_REPEATS = 50
FAMILY_NUM_FEATURES = 2048
FAMILY_DTYPES = ["float32", "int8"]

# model_size (ISSUE 5): serialized footprint of the int8 variant vs its
# f32 parent, with the invariants CI gates on — >= 3x smaller, argmax/
# label parity vs the f32 engine, and the meta's reported quantization
# error reproducible on the same deterministic holdout. Cases are sized
# so the weight payload dominates the constant ~2 KB of npz/zip member
# headers (a K=1 d=64 quadform is an 18 KB file where header overhead,
# not weights, caps the ratio at ~2.8x — not a footprint that needs
# quantizing in the first place).
MODEL_SIZE_CASES = [(10, 64), (1, 256), (10, 784)]  # (K, d)
MODEL_SIZE_NSV = 256
MODEL_SIZE_BATCH = 256

# fastfood (ISSUE 8): the structured-projection fast path head-to-head
# against dense RFF and quadform at fixed (K, F) across the d axis —
# the Fastfood trade is O(F log d') projection FLOPs vs dense's O(F d),
# so the structured rows must pull ahead as d grows (the acceptance
# criterion pins d=784, the mnist shape, where log2(d') = 10 << 784).
# f32 + int8 rows for every variant; the int8 structured rows carry the
# serialized-size ratio and label parity vs their f32 parent, and every
# row asserts zero steady-state recompiles through the timed loop.
FASTFOOD_DIMS = [64, 784, 1024]
FASTFOOD_K = 10
FASTFOOD_NSV = 256
FASTFOOD_BATCH = 256
FASTFOOD_REPEATS = 30
FASTFOOD_VARIANTS = ("structured", "dense", "quadform")
# NOT shrunk under --smoke: the gated claims (structured beats dense at
# d=784, int8 >= 3x smaller) only hold at a real feature count — at
# F = 512 the structured path still pays a full d' = 1024 transform for
# half the features and the scales dominate the int8 layout. Smoke
# reduces dims and repeats instead.
FASTFOOD_FEATURES = 2048

# runtime_throughput: open-loop clients x small requests through the
# micro-batching Runtime vs per-request engine.predict
RUNTIME_CLIENTS = [1, 8, 32]
RUNTIME_REQS_PER_CLIENT = 80
RUNTIME_REQ_ROWS = 4
RUNTIME_FLUSH_ROWS = 256
RUNTIME_MAX_WAIT_US = 1000.0

# overload: the slow-step injection pins service capacity at roughly
# flush_rows / slow_step_s rows/s on ANY host, so the burst (threads
# submitting back-to-back with sheds returning instantly) reliably
# offers a large multiple of capacity without tuning per machine.
OVERLOAD_QUEUE_ROWS = 256
OVERLOAD_FLUSH_ROWS = 64
OVERLOAD_REQ_ROWS = 8
OVERLOAD_CLIENTS = 8
OVERLOAD_REQS_PER_CLIENT = 60
OVERLOAD_SLOW_STEP_S = 0.02
OVERLOAD_RESULT_TIMEOUT_S = 60.0

# degraded_mode: per-request latency of breaker-open exact serving next
# to the healthy fast path on identical traffic
DEGRADED_BATCH = 256
DEGRADED_REPEATS = 50

# scaleout: replicated dispatch across forced host devices, then the
# head-sharded extreme-multiclass path. On this class of host ONE
# physical core backs every forced device, so raw compute cannot scale
# with device count; the per-flush service time is instead PINNED by the
# fault injector's slow-step hook (a GIL-releasing sleep taken inside
# each replica's dispatch thread, the same emulation bench_overload uses
# to pin capacity). What the replica rows measure is therefore the
# DISPATCHER's scaling: N replicas overlap N pinned flushes iff routing,
# inflight accounting and per-replica breaker state are genuinely
# concurrent — the property that transfers to real multi-device hosts.
SCALEOUT_REPLICAS = [1, 2, 4, 8]
SCALEOUT_SLOW_STEP_S = 0.02
SCALEOUT_REQ_ROWS = 64
SCALEOUT_CLIENTS = 8
SCALEOUT_REQS_PER_CLIENT = 25
SCALEOUT_SHARDED_K = 4096       # extreme-OvR head count (the tentpole claim)
SCALEOUT_PARITY_K = 16          # small-K argmax parity vs unsharded reference
SCALEOUT_SHARDED_D = 32
SCALEOUT_SHARDED_BATCH = 256
SCALEOUT_SHARDED_REPEATS = 10

# observability (PR 9): the tracing tax. Identical open-loop workloads
# through an untraced Runtime (obs=False) and a traced one (private
# Observability, so the process-default registry stays clean). The
# flush wait (max_wait_us) dominates both p50s, so the span-recording
# microseconds must vanish into it — CI gates overhead_p50 <= 1.05x.
# The traced run also re-proves three-way conservation: telemetry
# counters, span counts and the Prometheus rendering must agree on
# every request's verdict.
OBS_CLIENTS = 8
OBS_REQS_PER_CLIENT = 60
OBS_REQ_ROWS = 4
OBS_DRIVE_REPEATS = 5

# serving_http (PR 10): the network tax. The SAME runtime serves an
# identical closed-loop workload twice — first in-process
# (rt.submit(...).result()), then through the stdlib HTTP front door
# with one persistent connection per client. Per-request wall-clock
# p50/p99 are measured CLIENT-side in both legs so the ratio is the
# full wire overhead (TCP hop + JSON + ASGI dispatch + executor
# bridge), not just server time. The gated invariants: conservation
# still balances across the HTTP hop (client 200s == telemetry served
# == spans), the queue drains to zero (zero hung futures), requests
# keep coalescing through the bridge, and the HTTP overhead stays
# bounded (generously — CI hosts are noisy; the point is catching a
# 100x regression like an accidental per-request handshake or a
# serialized bridge, not enforcing microseconds).
HTTP_CLIENTS = 8
HTTP_REQS_PER_CLIENT = 50
HTTP_REQ_ROWS = 4
HTTP_MAX_WAIT_US = 1000.0

SMOKE = False           # set by --smoke: same sections, fewer repeats


def family_num_features() -> int:
    """One definition for the fourier basis size the comparison runs at,
    so the measured rows and the recorded JSON meta can never disagree."""
    return 512 if SMOKE else FAMILY_NUM_FEATURES


def _model(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((N_SV, D)).astype(np.float32) * 0.5
    ay = rng.standard_normal(N_SV).astype(np.float32)
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    return SVMModel(
        X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
        b=jnp.float32(0.1), gamma=jnp.float32(gamma),
    )


def bench_engine() -> list[dict]:
    m = _model()
    eng = SVMEngine(approximate(m), m, min_bucket=32, max_batch=1024)
    eng.warmup()
    rng = np.random.default_rng(1)
    rows = []
    for n in BATCHES:
        batches = [rng.standard_normal((n, D)).astype(np.float32) * 0.3
                   for _ in range(8)]
        for Z in batches:                                  # warm this bucket
            eng.predict(Z)
        times = []
        for i in range(REPEATS):
            Z = batches[i % len(batches)]
            t0 = time.perf_counter()
            f, _ = eng.predict(Z)                          # includes sync
            times.append(time.perf_counter() - t0)
        times = np.asarray(times) * 1e3
        rows.append({
            "batch": n,
            "bucket": bucket_size(n, 32, 1024),
            "p50_ms": round(float(np.percentile(times, 50)), 4),
            "p99_ms": round(float(np.percentile(times, 99)), 4),
            "per_row_us_p50": round(1e3 * float(np.percentile(times, 50)) / n, 2),
        })
    assert eng.jit_cache_size() <= 6, "bucket cache must stay bounded"
    rows_meta = {
        "jit_variants": eng.jit_cache_size(),
        "padding_overhead": round(eng.stats.padding_overhead, 4),
    }
    print("[serving] engine latency per bucket (warm, zero recompiles)")
    print(fmt_table(rows, ["batch", "bucket", "p50_ms", "p99_ms", "per_row_us_p50"]))
    print(f"[serving] {rows_meta}")
    return rows, rows_meta


def bench_heads() -> list[dict]:
    """Fused stacked-Hessian scoring vs the seed's per-head vmap at equal K."""
    rng = np.random.default_rng(2)
    Z = jnp.asarray(rng.standard_normal((HEADS_BATCH, D)).astype(np.float32) * 0.3)
    rows = []
    for K in HEAD_COUNTS:
        Ms = rng.standard_normal((K, D, D)).astype(np.float32) * 0.05
        M_all = jnp.asarray((Ms + Ms.transpose(0, 2, 1)) / 2)
        V = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        c = jnp.asarray(rng.standard_normal(K).astype(np.float32))
        b = jnp.asarray(rng.standard_normal(K).astype(np.float32))
        g = jnp.full((K,), 0.05, jnp.float32)
        msq = jnp.full((K,), 2.0, jnp.float32)

        fused = jax.jit(backend.quadform_heads_xla)
        unfused = jax.jit(quadform_heads_ref)              # K-pass vmap oracle
        t_fused = timeit(fused, Z, M_all, V, c, b, g, msq, repeats=20, warmup=3)
        t_vmap = timeit(unfused, Z, M_all, V, c, b, g, msq, repeats=20, warmup=3)
        rows.append({
            "K": K,
            "batch": HEADS_BATCH,
            "d": D,
            "fused_ms": round(1e3 * t_fused, 3),
            "vmap_ms": round(1e3 * t_vmap, 3),
            "speedup": round(t_vmap / t_fused, 2),
        })
    print("[serving] fused multi-head vs per-head vmap (best-of-20)")
    print(fmt_table(rows, ["K", "batch", "d", "fused_ms", "vmap_ms", "speedup"]))
    return rows


def bench_family_compare() -> list[dict]:
    """Approximation families head-to-head on one synthetic model per (K, d).

    Each family's artifact is served through an ``SVMEngine`` with the
    fallback OFF (pure fast-path latency, including host padding + sync);
    the exact expansion (shared kernel-matrix GEMM across heads) is the
    baseline row. Errors are measured against that exact scorer on the
    same batch the latency is measured on.
    """
    repeats = 5 if SMOKE else FAMILY_REPEATS
    num_features = family_num_features()
    rows = []
    for K in FAMILY_HEADS:
        for d in FAMILY_DIMS:
            rng = np.random.default_rng(K * 1000 + d)
            X = rng.standard_normal((FAMILY_NSV, d)).astype(np.float32) * 0.5
            gamma = float(gamma_max(jnp.asarray(X))) * 0.8
            if K == 1:
                ay = rng.standard_normal(FAMILY_NSV).astype(np.float32)
                b = jnp.float32(0.1)
            else:
                ay = rng.standard_normal((K, FAMILY_NSV)).astype(np.float32)
                b = jnp.asarray(0.1 * rng.standard_normal(K).astype(np.float32))
            m = SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                         b=b, gamma=jnp.float32(gamma))
            Z = rng.standard_normal((FAMILY_BATCH, d)).astype(np.float32) * 0.3

            ay2 = m.alpha_y if K > 1 else m.alpha_y[None, :]
            b2 = jnp.reshape(m.b, (K,))
            exact_step = jax.jit(
                lambda Zb, X=m.X, g=m.gamma, a=ay2, bb=b2:
                    rbf_kernel(Zb, X, g) @ a.T + bb[None, :]
            )
            exact = np.asarray(exact_step(jnp.asarray(Z)))        # (n, K)

            def timed(fn):
                fn()                                              # warm
                times = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    fn()
                    times.append(time.perf_counter() - t0)
                t = np.asarray(times) * 1e3
                return (round(float(np.percentile(t, 50)), 4),
                        round(float(np.percentile(t, 99)), 4))

            for name in ("maclaurin", "poly2", "fourier"):
                for dtype in FAMILY_DTYPES:
                    art = families.get_family(name).compile(
                        m, num_features=num_features, dtype=dtype
                    )
                    eng = SVMEngine(art, None, allow_fallback=False,
                                    min_bucket=FAMILY_BATCH,
                                    max_batch=FAMILY_BATCH)
                    eng.warmup([FAMILY_BATCH])
                    vals = eng.predict(Z)[0]
                    got = vals if K > 1 else vals[:, None]
                    err = np.abs(got - exact)
                    p50, p99 = timed(lambda: eng.predict(Z))
                    rows.append({
                        "K": K, "d": d, "family": name, "dtype": dtype,
                        "p50_ms": p50, "p99_ms": p99,
                        "mean_abs_err": round(float(err.mean()), 6),
                        "max_abs_err": round(float(err.max()), 6),
                        "artifact_kb": round(art.nbytes() / 1024, 1),
                    })
            p50, p99 = timed(
                lambda: jax.block_until_ready(exact_step(jnp.asarray(Z)))
            )
            rows.append({
                "K": K, "d": d, "family": "exact", "dtype": "float32",
                "p50_ms": p50, "p99_ms": p99,
                "mean_abs_err": 0.0, "max_abs_err": 0.0,
                "artifact_kb": round(
                    (m.X.size + np.asarray(m.alpha_y).size + 2) * 4 / 1024, 1
                ),
            })
    print("[serving] family comparison (fast path only, fallback off)")
    print(fmt_table(rows, ["K", "d", "family", "dtype", "p50_ms", "p99_ms",
                           "mean_abs_err", "artifact_kb"]))
    return rows


def bench_model_size() -> dict:
    """Serialized footprint of int8 artifact variants vs their f32 parents,
    with the invariants the CI smoke gate asserts from the JSON:

      * int8 serializes >= 3x smaller (the acceptance floor; measured
        ratios run 3.5-3.8x — scales + f32 scalars cost the rest of 4x);
      * label/argmax parity vs the f32 engine on a seeded batch;
      * the quantization error REPORTED in the artifact meta reproduces
        on the same deterministic holdout (measured == reported), so the
        error report a registry consumer reads is real, not vestigial.

    Numbers here are sizes and error magnitudes — deterministic, not
    timing noise — which is what makes them gateable in CI.
    """
    from repro.core.families import fourier as _fourier

    cases = MODEL_SIZE_CASES[:2] if SMOKE else MODEL_SIZE_CASES
    num_features = family_num_features()
    rows = []
    for K, d in cases:
        rng = np.random.default_rng(K * 1000 + d)
        X = rng.standard_normal((MODEL_SIZE_NSV, d)).astype(np.float32) * 0.5
        gamma = float(gamma_max(jnp.asarray(X))) * 0.8
        if K == 1:
            ay = rng.standard_normal(MODEL_SIZE_NSV).astype(np.float32)
            b = jnp.float32(0.1)
        else:
            ay = rng.standard_normal((K, MODEL_SIZE_NSV)).astype(np.float32)
            b = jnp.asarray(0.1 * rng.standard_normal(K).astype(np.float32))
        m = SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                     b=b, gamma=jnp.float32(gamma))
        Z = rng.standard_normal((MODEL_SIZE_BATCH, d)).astype(np.float32) * 0.3
        holdout = _fourier.holdout_sample(m, 0, 256)

        for name in ("maclaurin", "poly2", "fourier"):
            fam = families.get_family(name)
            f32_art = fam.compile(m, num_features=num_features)
            q8_art = fam.compile(m, num_features=num_features, dtype="int8")

            # the meta's error report must reproduce on the holdout it was
            # measured on (same deterministic sample: seed 0, n 256) — via
            # the SAME helper compile used, so only genuine nondeterminism
            # can make measured and reported diverge
            remeasured = families.quantize.measure_quant_error(
                f32_art, q8_art, jnp.asarray(holdout)
            )

            f32_eng = SVMEngine(f32_art, None, allow_fallback=False,
                                min_bucket=MODEL_SIZE_BATCH,
                                max_batch=MODEL_SIZE_BATCH)
            q8_eng = SVMEngine(q8_art, None, allow_fallback=False,
                               min_bucket=MODEL_SIZE_BATCH,
                               max_batch=MODEL_SIZE_BATCH)
            parity = float(np.mean(
                f32_eng.predict_labels(Z) == q8_eng.predict_labels(Z)
            ))

            f32_bytes, q8_bytes = len(f32_art.to_bytes()), len(q8_art.to_bytes())
            rows.append({
                "K": K, "d": d, "family": name,
                "f32_bytes": f32_bytes,
                "int8_bytes": q8_bytes,
                "ratio": round(f32_bytes / q8_bytes, 3),
                "f32_mem_kb": round(f32_art.nbytes() / 1024, 1),
                "int8_mem_kb": round(q8_art.nbytes() / 1024, 1),
                "label_parity": parity,
                "quant_mean_abs_err": q8_art.meta["quant_mean_abs_err"],
                "quant_max_abs_err": q8_art.meta["quant_max_abs_err"],
                "remeasured_mean_abs_err": remeasured["quant_mean_abs_err"],
                "remeasured_max_abs_err": remeasured["quant_max_abs_err"],
                "f32_digest": f32_art.digest()[:12],
                "int8_digest": q8_art.digest()[:12],
            })
    print("[serving] model size: int8 variants vs f32 parents")
    print(fmt_table(rows, ["K", "d", "family", "f32_bytes", "int8_bytes",
                           "ratio", "label_parity", "quant_mean_abs_err"]))
    return {
        "note": (
            "serialized deterministic-npz bytes of each family's int8 "
            "variant vs its f32 parent; CI asserts ratio >= 3, label "
            "parity vs the f32 engine, and that the meta's quant error "
            "report reproduces on the deterministic holdout "
            "(tools/check_bench_invariants.py)"
        ),
        "batch": MODEL_SIZE_BATCH,
        "n_sv": MODEL_SIZE_NSV,
        "num_features": num_features,
        "rows": rows,
    }


def bench_fastfood() -> dict:
    """Structured Fastfood vs dense RFF vs quadform at fixed (K, F).

    One synthetic K-head model per d; every variant serves the same
    batch through an ``SVMEngine`` with the fallback off, f32 and int8.
    The structured rows dispatch the fused FWHT path
    (``backend.fastfood_score*``); rows_per_s is the steady-state p50
    throughput. Gated by ``tools/check_bench_invariants.py``: the full
    (d, variant, dtype) grid present, structured beating dense rows/s at
    d=784, int8 structured >= 3x smaller with >= 0.99 label parity, and
    zero steady-state recompiles on every row.
    """
    dims = [d for d in FASTFOOD_DIMS if d != 1024] if SMOKE else FASTFOOD_DIMS
    repeats = 5 if SMOKE else FASTFOOD_REPEATS
    num_features = FASTFOOD_FEATURES
    rows = []
    for d in dims:
        rng = np.random.default_rng(8000 + d)
        X = rng.standard_normal((FASTFOOD_NSV, d)).astype(np.float32) * 0.5
        gamma = float(gamma_max(jnp.asarray(X))) * 0.8
        ay = rng.standard_normal((FASTFOOD_K, FASTFOOD_NSV)).astype(np.float32)
        b = jnp.asarray(
            0.1 * rng.standard_normal(FASTFOOD_K).astype(np.float32)
        )
        m = SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                     b=b, gamma=jnp.float32(gamma))
        Z = rng.standard_normal((FASTFOOD_BATCH, d)).astype(np.float32) * 0.3

        ay2 = m.alpha_y
        b2 = jnp.reshape(m.b, (FASTFOOD_K,))
        exact = np.asarray(
            rbf_kernel(jnp.asarray(Z), m.X, m.gamma) @ ay2.T + b2[None, :]
        )

        def compile_variant(variant, dtype):
            if variant == "quadform":
                return families.get_family("maclaurin").compile(m, dtype=dtype)
            return families.get_family("fourier").compile(
                m, num_features=num_features,
                structured=(variant == "structured"), dtype=dtype,
            )

        f32_engines = {}
        for variant in FASTFOOD_VARIANTS:
            for dtype in FAMILY_DTYPES:
                art = compile_variant(variant, dtype)
                eng = SVMEngine(art, None, allow_fallback=False,
                                min_bucket=FASTFOOD_BATCH,
                                max_batch=FASTFOOD_BATCH)
                eng.warmup([FASTFOOD_BATCH])
                got = eng.predict(Z)[0]
                err = np.abs(got - exact)
                labels = eng.predict_labels(Z)
                if dtype == "float32":
                    f32_engines[variant] = (eng, labels, len(art.to_bytes()))
                    parity, ratio = 1.0, None
                else:
                    _, f32_labels, f32_bytes = f32_engines[variant]
                    parity = float(np.mean(labels == f32_labels))
                    ratio = round(f32_bytes / len(art.to_bytes()), 3)

                cache_before = eng.jit_cache_size()
                times = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    eng.predict(Z)
                    times.append(time.perf_counter() - t0)
                t = np.asarray(times) * 1e3
                p50 = float(np.percentile(t, 50))
                rows.append({
                    "d": d, "variant": variant, "dtype": dtype,
                    "family": art.family,
                    "num_features": int(
                        art.meta.get("num_features", 0)
                    ) or None,
                    "p50_ms": round(p50, 4),
                    "p99_ms": round(float(np.percentile(t, 99)), 4),
                    "rows_per_s": round(FASTFOOD_BATCH / (p50 / 1e3), 1),
                    "mean_abs_err": round(float(err.mean()), 6),
                    "serialized_bytes": len(art.to_bytes()),
                    "size_ratio_vs_f32": ratio,
                    "label_parity_vs_f32": parity,
                    "steady_state_recompiles":
                        eng.jit_cache_size() - cache_before,
                })
    print("[serving] fastfood: structured vs dense RFF vs quadform")
    print(fmt_table(rows, ["d", "variant", "dtype", "p50_ms", "rows_per_s",
                           "mean_abs_err", "size_ratio_vs_f32",
                           "label_parity_vs_f32"]))
    return {
        "note": (
            "same synthetic K-head model served through the structured "
            "(Fastfood/FWHT), dense-RFF and quadform fast paths at f32 "
            "and int8, fallback off; the structured rows must beat dense "
            "rows/s at d=784 and the int8 structured rows must keep the "
            ">=3x size and >=0.99 parity contract "
            "(tools/check_bench_invariants.py)"
        ),
        "K": FASTFOOD_K,
        "batch": FASTFOOD_BATCH,
        "n_sv": FASTFOOD_NSV,
        "num_features": num_features,
        "dims": dims,
        "rows": rows,
    }


def bench_block_sweep() -> list[dict]:
    """Per-bucket TileConfig sweep through the dispatched serving primitives.

    Every row records the tuned pick next to the old fixed default for the
    same bucket; because the default is always among the candidates, the
    tuned pick is never slower by construction. Winners are persisted to
    the kernels/common tuning table (the file the engine's per-bucket
    resolution reads back).

    Candidates are rank-and-pruned through the analytic roofline prior
    (``repro.launch.roofline.quadform_tile_seconds`` etc.) before being
    measured: only the ``SWEEP_PRIOR_KEEP`` cheapest-predicted configs
    (plus, always, the default) burn wall clock. Each row logs how many
    candidates the prior pruned.
    """
    from repro.launch import roofline
    m = _model()
    am = approximate(m)
    one = lambda x: jnp.reshape(jnp.asarray(x, jnp.float32), (1,))
    M_all, V = am.M[None], am.v[None]
    scalars = (one(am.c), one(am.b), one(am.gamma), one(am.max_sv_sq_norm))
    rng = np.random.default_rng(3)
    rows = []

    def record_row(kernel, bucket, key, winner, sweep, offered):
        default = tuning.DEFAULTS[kernel]
        default_ms = next(r["ms"] for r in sweep if r["config"] == default)
        tuned_ms = min(r["ms"] for r in sweep)
        rows.append({
            "kernel": kernel,
            "bucket": bucket,
            "key": key,
            "tuned": {k: v for k, v in winner.to_json().items()
                      if getattr(default, k) != v} or {"(default)": True},
            "tuned_ms": round(tuned_ms, 4),
            "default_ms": round(default_ms, 4),
            # offered = candidate list handed to autotune (plus the default
            # if it was absent); measured = what survived the prior
            "candidates_offered": offered,
            "candidates_pruned_by_prior": offered - len(sweep),
            "candidates": [
                {"block_n": r["config"].block_n, "block_m": r["config"].block_m,
                 "ms": round(r["ms"], 4)}
                for r in sweep
            ],
        })

    for bucket in SWEEP_BUCKETS:
        Z = jnp.asarray(rng.standard_normal((bucket, D)).astype(np.float32) * 0.3)
        key = tuning.shape_key(d=D, k=1, n=bucket)

        def build(cfg):
            step = jax.jit(
                lambda Zb: backend.quadform_heads(Zb, M_all, V, *scalars, config=cfg)
            )
            return lambda: step(Z)

        # clamp candidates to the bucket (dedup) so small buckets still get
        # a real sweep instead of only the appended default
        cands = [TileConfig(block_n=bn)
                 for bn in sorted({min(bn, bucket) for bn in SWEEP_BLOCK_N})]
        offered = len(cands) + (tuning.DEFAULTS["quadform"] not in cands)
        winner, sweep = autotune.autotune(
            "quadform", key, build, cands, source="benchmarks/serving_latency.py",
            prior=lambda cfg, _n=bucket: roofline.quadform_tile_seconds(
                cfg, n=_n, d=D, k=1
            ),
            prior_keep=SWEEP_PRIOR_KEEP,
        )
        record_row("quadform", bucket, key, winner, sweep, offered)

    # exact-fallback path: SV stream tile size at one representative bucket
    n_fb = 256
    Zfb = jnp.asarray(rng.standard_normal((n_fb, D)).astype(np.float32) * 0.3)
    key = tuning.shape_key(d=D, m=N_SV, n=n_fb)

    def build_rbf(cfg):
        step = jax.jit(
            lambda Zb: backend.rbf_scores(Zb, m.X, m.alpha_y, m.gamma, m.b, config=cfg)
        )
        return lambda: step(Zfb)

    cands = [TileConfig(block_n=256, block_m=bm) for bm in SWEEP_BLOCK_M]
    offered = len(cands) + (tuning.DEFAULTS["rbf_pred"] not in cands)
    winner, sweep = autotune.autotune(
        "rbf_pred", key, build_rbf, cands, source="benchmarks/serving_latency.py",
        prior=lambda cfg: roofline.rbf_tile_seconds(cfg, n=n_fb, d=D, m=N_SV),
        prior_keep=SWEEP_PRIOR_KEEP,
    )
    record_row("rbf_pred", n_fb, key, winner, sweep, offered)

    # structured-Fastfood path: Z-tile size through the fused FWHT scorer,
    # same key shape the family's tile_lookup resolves at serve time
    ff_features = family_num_features()
    ff_art = families.get_family("fourier").compile(
        m, num_features=ff_features, structured=True
    )
    fa = ff_art.arrays
    n_ff = 256
    Zff = jnp.asarray(rng.standard_normal((n_ff, D)).astype(np.float32) * 0.3)
    key = tuning.shape_key(d=D, f=ff_features, n=n_ff)

    def build_fwht(cfg):
        step = jax.jit(
            lambda Zb: backend.fastfood_score(
                Zb, fa["ff_b"], fa["ff_g"], fa["ff_perm"], fa["ff_scale"],
                fa["phase"], fa["weights"], fa["b"], config=cfg,
            )
        )
        return lambda: step(Zff)

    cands = [TileConfig(block_n=bn)
             for bn in sorted({min(bn, n_ff) for bn in SWEEP_BLOCK_N})]
    offered = len(cands) + (tuning.DEFAULTS["fwht"] not in cands)
    winner, sweep = autotune.autotune(
        "fwht", key, build_fwht, cands, source="benchmarks/serving_latency.py",
        prior=lambda cfg: roofline.fwht_tile_seconds(
            cfg, n=n_ff, d=D, f=ff_features, k=fa["weights"].shape[0]
        ),
        prior_keep=SWEEP_PRIOR_KEEP,
    )
    record_row("fwht", n_ff, key, winner, sweep, offered)

    table_path = tuning.save_table()
    print("[serving] block-size sweep (tuned pick vs old fixed default)")
    print(fmt_table(rows, ["kernel", "bucket", "tuned", "tuned_ms", "default_ms"]))
    print(f"[serving] tuning table -> {table_path}")
    return rows


def bench_runtime_throughput() -> dict:
    """Coalesced micro-batching vs per-request ``engine.predict`` under
    concurrent clients, through the multi-tenant ``Runtime``.

    Two models are registered (multi-tenant setup); the measured traffic
    targets the primary alias. The per-request baseline is CLOSED loop
    (each client blocks on its own ``predict``, the pre-runtime serving
    pattern); the runtime path is OPEN loop (clients enqueue all their
    requests, then materialize the futures) — exactly the concurrency the
    scheduler exists to exploit. The engine's bounded-compile guarantee
    must survive coalescing: ``jit_cache_size`` is asserted unchanged
    across the whole stress.
    """
    reqs = 10 if SMOKE else RUNTIME_REQS_PER_CLIENT
    m, m2 = _model(), _model(seed=7)
    art = families.maclaurin.compile(m)
    art2 = families.maclaurin.compile(m2)
    rt = Runtime(
        max_wait_us=RUNTIME_MAX_WAIT_US,
        flush_rows=RUNTIME_FLUSH_ROWS,
        engine_opts=dict(min_bucket=32, max_batch=1024),
    )
    rt.publish("primary", art, PublishSpec(exact=m))
    rt.publish("secondary", art2, PublishSpec(exact=m2))
    rt.warmup("primary")
    rt.warmup("secondary")
    digest, engine = rt.registry.get_engine("primary")
    cache_before = engine.jit_cache_size()

    rng = np.random.default_rng(11)
    rows = []
    for clients in RUNTIME_CLIENTS:
        work = [
            [rng.standard_normal((RUNTIME_REQ_ROWS, D)).astype(np.float32) * 0.3
             for _ in range(reqs)]
            for _ in range(clients)
        ]
        total_rows = clients * reqs * RUNTIME_REQ_ROWS

        def fan_out(target):
            threads = [threading.Thread(target=target, args=(w,)) for w in work]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            return time.perf_counter() - t0

        # baseline: per-request predict, closed loop (pre-runtime pattern)
        def per_request(batches):
            for Z in batches:
                engine.predict(Z)

        t_direct = fan_out(per_request)

        # runtime: open-loop submits, one shared sync per coalesced flush
        before = rt.stats("primary")

        def coalesced(batches):
            futs = [rt.submit("primary", Z) for Z in batches]
            for f in futs:
                f.result().values

        t_runtime = fan_out(coalesced)
        after = rt.stats("primary")

        d_reqs = after["requests"] - before["requests"]
        d_flushes = max(1, after["flushes"] - before["flushes"])
        rows.append({
            "clients": clients,
            "requests": clients * reqs,
            "rows": total_rows,
            "per_request_rows_s": round(total_rows / t_direct, 1),
            "coalesced_rows_s": round(total_rows / t_runtime, 1),
            "speedup": round(t_direct / t_runtime, 2),
            "coalescing_factor": round(d_reqs / d_flushes, 2),
            "p50_ms": after["latency"]["p50_ms"],
            "p99_ms": after["latency"]["p99_ms"],
        })

    cache_after = engine.jit_cache_size()
    assert cache_after == cache_before, (
        f"coalescing must not add compiled variants "
        f"({cache_before} -> {cache_after})"
    )
    snap = rt.stats("primary")
    meta = {
        "req_rows": RUNTIME_REQ_ROWS,
        "flush_rows": RUNTIME_FLUSH_ROWS,
        "max_wait_us": RUNTIME_MAX_WAIT_US,
        "models_registered": 2,
        "steady_state_recompiles": cache_after - cache_before,
        "jit_variants": cache_after,
        "fallback_rate": snap["fallback_rate"],
    }
    rt.close()
    print("[serving] runtime throughput: coalesced vs per-request predict")
    print(fmt_table(rows, ["clients", "requests", "per_request_rows_s",
                           "coalesced_rows_s", "speedup", "coalescing_factor",
                           "p99_ms"]))
    print(f"[serving] {meta}")
    return {
        "note": (
            "open-loop concurrent clients submitting 4-row requests through "
            "Runtime (coalesced into bucket-sized engine steps) vs the same "
            "clients calling engine.predict per request (closed loop); "
            "steady_state_recompiles must be 0"
        ),
        "rows": rows,
        "meta": meta,
    }


def bench_overload() -> dict:
    """Admission control under a burst far past capacity.

    The fault injector's slow-step hook pins per-flush service time at
    ``OVERLOAD_SLOW_STEP_S`` (capacity ~= flush_rows / slow_step_s
    rows/s regardless of host speed); ``OVERLOAD_CLIENTS`` threads then
    submit back-to-back — sheds return instantly, so the offered rate
    is a large multiple of capacity by construction. Everything the CI
    gate asserts is deterministic accounting, not timing: sheds are
    typed ``RuntimeOverloaded`` with a ``retry_after_s`` hint, admitted
    + shed == submitted on both the client and telemetry side, every
    admitted future resolves under a hard timeout (zero hung futures),
    and the burst adds zero fast-path recompiles.
    """
    reqs = 15 if SMOKE else OVERLOAD_REQS_PER_CLIENT
    m = _model(seed=5)
    art = families.maclaurin.compile(m)
    fi = FaultInjector(seed=5, slow_step_rate=1.0,
                       slow_step_s=OVERLOAD_SLOW_STEP_S)
    rt = Runtime(
        max_wait_us=500.0,
        flush_rows=OVERLOAD_FLUSH_ROWS,
        max_queue_rows=OVERLOAD_QUEUE_ROWS,
        engine_opts=dict(min_bucket=32, max_batch=1024),
        fault_injector=fi,
    )
    rt.publish("hot", art, PublishSpec(exact=m))
    rt.warmup("hot")
    rng = np.random.default_rng(13)
    warm = rng.standard_normal((OVERLOAD_REQ_ROWS, D)).astype(np.float32) * 0.3
    rt.predict("hot", warm)                            # warm the serving path
    _, engine = rt.registry.get_engine("hot")
    cache_before = engine.jit_cache_size()

    work = [
        [rng.standard_normal((OVERLOAD_REQ_ROWS, D)).astype(np.float32) * 0.3
         for _ in range(reqs)]
        for _ in range(OVERLOAD_CLIENTS)
    ]
    admitted, retry_hints = [], []
    lock = threading.Lock()

    def client(batches):
        for Z in batches:
            try:
                f = rt.submit("hot", Z)
            except RuntimeOverloaded as e:
                with lock:
                    retry_hints.append(float(e.retry_after_s))
            else:
                with lock:
                    admitted.append(f)

    threads = [threading.Thread(target=client, args=(w,)) for w in work]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t_burst = time.perf_counter() - t0

    # every admitted future must resolve — a future still pending after
    # the hard timeout is exactly the hang the robustness layer forbids
    hung = 0
    for f in admitted:
        try:
            f.result(timeout=OVERLOAD_RESULT_TIMEOUT_S).values
        except concurrent.futures.TimeoutError:
            hung += 1

    st = rt.stats("hot")
    cache_after = engine.jit_cache_size()
    rt.close()

    submitted = OVERLOAD_CLIENTS * reqs
    offered_rows_s = submitted * OVERLOAD_REQ_ROWS / t_burst
    capacity_rows_s = OVERLOAD_FLUSH_ROWS / OVERLOAD_SLOW_STEP_S
    meta = {
        "clients": OVERLOAD_CLIENTS,
        "submitted": submitted,
        "admitted": len(admitted),
        "shed_requests": len(retry_hints),
        "shed_requests_telemetry": st["shed_requests"],
        "retry_after_s_min": round(min(retry_hints), 4) if retry_hints else None,
        "retry_after_s_max": round(max(retry_hints), 4) if retry_hints else None,
        "hung_futures": hung,
        "queue_rows_after_drain": st["queue_rows"],
        # the telemetry gauge counts a popped batch until its flush is
        # recorded, so the provable high-water is waiting rows (bounded
        # by admission) + the batch in execution: 2x the bound
        "max_queue_rows_observed": st["max_queue_rows"],
        "max_queue_rows_bound": OVERLOAD_QUEUE_ROWS,
        "offered_rows_s": round(offered_rows_s, 1),
        "pinned_capacity_rows_s": round(capacity_rows_s, 1),
        "burst_multiple": round(offered_rows_s / capacity_rows_s, 1),
        "admitted_p50_ms": st["latency"]["p50_ms"],
        "admitted_p99_ms": st["latency"]["p99_ms"],
        "tightened_waits": st["tightened_waits"],
        "steady_state_recompiles": cache_after - cache_before,
    }
    print("[serving] overload: bounded queue under a burst past capacity")
    print(f"[serving] {meta}")
    return {
        "note": (
            "slow-step injection pins service capacity, then an 8-thread "
            "burst offers a large multiple of it; admission sheds the "
            "excess with RuntimeOverloaded(retry_after_s) and every "
            "admitted future resolves; CI gates the accounting "
            "(tools/check_bench_invariants.py)"
        ),
        "req_rows": OVERLOAD_REQ_ROWS,
        "flush_rows": OVERLOAD_FLUSH_ROWS,
        "slow_step_s": OVERLOAD_SLOW_STEP_S,
        "meta": meta,
    }


def bench_degraded_mode() -> dict:
    """Breaker-open exact serving next to the healthy fast path.

    Scripted engine faults trip the per-model circuit breaker; with a
    long ``reset_after_s`` it stays open for the whole degraded
    measurement, so every request is served by the exact streaming
    ``rbf_pred`` path. The slowdown ratio is the price of graceful
    degradation (the alternative is failing the requests); the gated
    invariants are that the breaker really was open, nothing was shed
    or left unserved, and the fast-path bucket cache gained nothing.
    """
    repeats = 10 if SMOKE else DEGRADED_REPEATS
    m = _model(seed=6)
    art = families.maclaurin.compile(m)
    fi = FaultInjector(seed=6)
    rt = Runtime(
        max_wait_us=500.0,
        flush_rows=DEGRADED_BATCH,
        engine_opts=dict(min_bucket=32, max_batch=1024),
        breaker=dict(fail_threshold=3, reset_after_s=600.0),
        fault_injector=fi,
    )
    rt.publish("hot", art, PublishSpec(exact=m))
    rt.warmup("hot")
    rng = np.random.default_rng(17)
    Z = rng.standard_normal((DEGRADED_BATCH, D)).astype(np.float32) * 0.3

    def timed_predicts():
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            rt.predict("hot", Z)
            times.append(time.perf_counter() - t0)
        t = np.asarray(times) * 1e3
        return (round(float(np.percentile(t, 50)), 4),
                round(float(np.percentile(t, 99)), 4))

    rt.predict("hot", Z)                                  # warm fast path
    healthy_p50, healthy_p99 = timed_predicts()
    _, engine = rt.registry.get_engine("hot")
    cache_before = engine.jit_cache_size()

    # trip the breaker: 3 scripted consecutive engine-step faults
    fi.fail_next(ENGINE_STEP, 3)
    failed_trips = 0
    for _ in range(3):
        try:
            rt.predict("hot", Z)
        except Exception:
            failed_trips += 1

    rt.predict("hot", Z)                  # warm the degraded slow variant
    degraded_p50, degraded_p99 = timed_predicts()
    st = rt.stats("hot")
    cache_after = engine.jit_cache_size()
    rt.close()

    meta = {
        "batch": DEGRADED_BATCH,
        "healthy_p50_ms": healthy_p50,
        "healthy_p99_ms": healthy_p99,
        "degraded_p50_ms": degraded_p50,
        "degraded_p99_ms": degraded_p99,
        "slowdown_p50": round(degraded_p50 / max(healthy_p50, 1e-9), 2),
        "breaker_state": st["breaker"]["state"],
        "breaker_trips": st["breaker"]["trips"],
        "tripping_failures": failed_trips,
        "degraded_requests": st["breaker"]["degraded_requests"],
        "breaker_shed_requests": st["breaker"]["shed_requests"],
        "steady_state_recompiles": cache_after - cache_before,
    }
    print("[serving] degraded mode: breaker-open exact path vs fast path")
    print(f"[serving] {meta}")
    return {
        "note": (
            "scripted faults trip the circuit breaker (reset_after_s "
            "600 keeps it open), then the same traffic is measured on "
            "the exact streaming degraded path; CI gates breaker state, "
            "full service (no sheds) and zero fast-path recompiles"
        ),
        "meta": meta,
    }


def _synthetic_quadform(k: int, d: int, seed: int) -> families.CompiledArtifact:
    """A random K-head quadform artifact sized for the extreme-OvR bench.

    Training a real K=4096 OvR ensemble is not what this section
    measures; serving one is. gamma = 0.01 and msq = 1 keep every
    z ~ 0.3 N(0, I) row inside the Eq 3.11 envelope (msq ||z||^2 ~ 3
    << 0.0625 / gamma^2 = 625), so the fast path serves 100% of rows.
    """
    rng = np.random.default_rng(seed)
    f32 = np.float32
    arrays = {
        "M": jnp.asarray(rng.standard_normal((k, d, d)).astype(f32) * 0.05),
        "v": jnp.asarray(rng.standard_normal((k, d)).astype(f32) * 0.1),
        "c": jnp.asarray(rng.standard_normal((k,)).astype(f32) * 0.1),
        "b": jnp.asarray(rng.standard_normal((k,)).astype(f32) * 0.1),
        "gamma": jnp.full((k,), 0.01, jnp.float32),
        "msq": jnp.ones((k,), jnp.float32),
    }
    from repro.core.families.base import base_meta

    return families.CompiledArtifact(
        family="maclaurin",
        arrays=arrays,
        meta=base_meta(d=d, num_heads=k, multiclass=True, synthetic=True),
    )


def bench_scaleout() -> dict:
    """Multi-device scale-out: replicated dispatch + head-sharded serving.

    Replica rows: each flush's service time is pinned at
    ``SCALEOUT_SLOW_STEP_S`` by the injector (see the constant block for
    why — one physical core backs every forced host device, so pinned
    GIL-releasing sleeps are the honest scaling substrate here), then
    ``replicas=N`` must deliver ~N x rows/s because the micro-batcher
    overlaps N in-flight flushes across the per-replica dispatch
    threads. Gated: rows/s monotone in N, zero steady-state recompiles,
    every replica actually served.

    Sharded rows: the K=4096 synthetic OvR model serves through
    ``head_mesh`` (shard_map over the stacked Hessian); argmax parity vs
    the unsharded reference is asserted exactly at K=16 (identical math,
    different partitioning) and gated at 1.0.
    """
    from jax.sharding import Mesh

    devices = jax.local_devices()
    ndev = len(devices)
    reqs = 8 if SMOKE else SCALEOUT_REQS_PER_CLIENT
    m = _model(seed=9)
    art = families.maclaurin.compile(m)
    rng = np.random.default_rng(23)
    work = [
        [rng.standard_normal((SCALEOUT_REQ_ROWS, D)).astype(np.float32) * 0.3
         for _ in range(reqs)]
        for _ in range(SCALEOUT_CLIENTS)
    ]
    total_rows = SCALEOUT_CLIENTS * reqs * SCALEOUT_REQ_ROWS

    counts = [n for n in SCALEOUT_REPLICAS if n <= ndev] or [1]
    replica_rows = []
    for n_rep in counts:
        fi = FaultInjector(seed=9, slow_step_rate=1.0,
                           slow_step_s=SCALEOUT_SLOW_STEP_S)
        rt = Runtime(
            max_wait_us=500.0,
            flush_rows=SCALEOUT_REQ_ROWS,
            engine_opts=dict(
                min_bucket=SCALEOUT_REQ_ROWS, max_batch=SCALEOUT_REQ_ROWS
            ),
            fault_injector=fi,
        )
        rt.publish("scale", art, PublishSpec(exact=m, replicas=n_rep))
        _, engines = rt.registry.get_engines("scale")
        cache_before = sum(e.jit_cache_size() for e in engines)

        def client(batches):
            futs = [rt.submit("scale", Z) for Z in batches]
            for f in futs:
                f.result().values
        threads = [threading.Thread(target=client, args=(w,)) for w in work]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0

        st = rt.stats("scale")
        cache_after = sum(e.jit_cache_size() for e in engines)
        rt.close()
        per_replica = st.get("replicas", {})
        flushes = [per_replica[k]["flushes"] for k in sorted(per_replica)]
        replica_rows.append({
            "replicas": n_rep,
            "rows": total_rows,
            "rows_s": round(total_rows / elapsed, 1),
            "p50_ms": st["latency"]["p50_ms"],
            "p99_ms": st["latency"]["p99_ms"],
            "per_replica_flushes": flushes,
            "all_replicas_served": (
                len(flushes) == n_rep and all(f > 0 for f in flushes)
            ),
            "steady_state_recompiles": cache_after - cache_before,
            "failed_requests": st["failed_requests"],
            "shed_requests": st["shed_requests"],
        })

    # ---- head-sharded extreme multiclass ------------------------------
    mesh = Mesh(np.array(devices), ("heads",))
    repeats = 3 if SMOKE else SCALEOUT_SHARDED_REPEATS
    d = SCALEOUT_SHARDED_D
    Zs = rng.standard_normal(
        (SCALEOUT_SHARDED_BATCH, d)
    ).astype(np.float32) * 0.3
    eng_opts = dict(
        min_bucket=SCALEOUT_SHARDED_BATCH, max_batch=SCALEOUT_SHARDED_BATCH
    )

    # exact-math parity at small K: same artifact, sharded vs unsharded
    art_small = _synthetic_quadform(SCALEOUT_PARITY_K, d, seed=31)
    ref = SVMEngine(art_small, **eng_opts)
    shd = SVMEngine(art_small, head_mesh=mesh, **eng_opts)
    r_ref, r_shd = ref.submit(Zs), shd.submit(Zs)
    parity = float(np.mean(r_ref.labels == r_shd.labels))
    scores_close = bool(
        np.allclose(r_ref.values, r_shd.values, rtol=1e-4, atol=1e-5)
    )

    def timed(engine):
        engine.predict(Zs)                                  # warm
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            engine.predict(Zs)
            times.append(time.perf_counter() - t0)
        t = np.asarray(times) * 1e3
        return (round(float(np.percentile(t, 50)), 3),
                round(float(np.percentile(t, 99)), 3))

    art_big = _synthetic_quadform(SCALEOUT_SHARDED_K, d, seed=37)
    big_ref = SVMEngine(art_big, **eng_opts)
    big_shd = SVMEngine(art_big, head_mesh=mesh, **eng_opts)
    ref_p50, ref_p99 = timed(big_ref)
    shd_p50, shd_p99 = timed(big_shd)
    sharded = {
        "K": SCALEOUT_SHARDED_K,
        "d": d,
        "batch": SCALEOUT_SHARDED_BATCH,
        "shards": ndev,
        "padded_heads": int(
            big_shd._serve_artifact.meta.get(
                "padded_heads", SCALEOUT_SHARDED_K
            )
        ),
        "parity_K": SCALEOUT_PARITY_K,
        "argmax_parity": parity,
        "scores_allclose": scores_close,
        "fallback_rate": big_shd.stats.fallback_rate,
        "unsharded_p50_ms": ref_p50,
        "unsharded_p99_ms": ref_p99,
        "sharded_p50_ms": shd_p50,
        "sharded_p99_ms": shd_p99,
    }

    meta = {
        "devices": ndev,
        "device_kind": jax.default_backend(),
        "clients": SCALEOUT_CLIENTS,
        "req_rows": SCALEOUT_REQ_ROWS,
        "slow_step_s": SCALEOUT_SLOW_STEP_S,
    }
    print("[serving] scaleout: replicated dispatch on forced host devices")
    print(fmt_table(replica_rows, ["replicas", "rows_s", "p50_ms", "p99_ms",
                                   "per_replica_flushes",
                                   "steady_state_recompiles"]))
    print(f"[serving] scaleout sharded: {sharded}")
    return {
        "note": (
            "replica rows: per-flush service time pinned by slow-step "
            "injection (one physical core backs all forced host devices, "
            "so sleeps that release the GIL inside the per-replica "
            "dispatch threads are what can honestly scale here); rows/s "
            "must rise monotonically with replica count and is gated "
            "structurally. sharded: K=4096 OvR served via shard_map over "
            "heads; argmax parity vs the unsharded reference gated at "
            "K=16. Generate under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8"
        ),
        "meta": meta,
        "replica_rows": replica_rows,
        "sharded": sharded,
    }


def bench_observability() -> dict:
    """Traced vs untraced serving on identical closed-loop traffic.

    Two fresh runtimes serve the same (seeded) workload: one with
    observability disabled (``obs=False`` — no spans, no metric
    mirroring), one fully traced onto a private registry. Clients are
    CLOSED-LOOP (one outstanding request each): the p50 ratio then
    measures the per-request cost of tracing itself. An open-loop burst
    would instead measure how queueing amplifies any slowdown on a
    saturated box — real, but a property of the load, not the tracer
    (throughput impact stays visible in ``rows_s``). Request p50/p99
    come from each runtime's own latency window, so the comparison is
    request-level, not wall-clock. The traced run's accounting is then
    checked three ways — telemetry counters, tracer span counts,
    Prometheus rendering — and the booleans land in the meta for
    ``check_bench_invariants`` to gate.
    """
    reqs = 10 if SMOKE else OBS_REQS_PER_CLIENT

    def drive(obs):
        m = _model()
        art = families.maclaurin.compile(m)
        rt = Runtime(
            max_wait_us=RUNTIME_MAX_WAIT_US,
            flush_rows=RUNTIME_FLUSH_ROWS,
            engine_opts=dict(min_bucket=32, max_batch=1024),
            obs=obs,
        )
        rt.publish("primary", art, PublishSpec(exact=m))
        rt.warmup("primary")
        digest = rt.registry.resolve("primary")
        rng = np.random.default_rng(11)
        work = [
            [rng.standard_normal((OBS_REQ_ROWS, D)).astype(np.float32) * 0.3
             for _ in range(reqs)]
            for _ in range(OBS_CLIENTS)
        ]

        def client(batches):
            for Z in batches:
                rt.submit("primary", Z).result().values

        threads = [threading.Thread(target=client, args=(w,)) for w in work]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        rt.close()                       # drain; every verdict is final
        st = rt.stats(digest)
        return rt, st, digest, elapsed

    total_reqs = OBS_CLIENTS * reqs
    total_rows = total_reqs * OBS_REQ_ROWS

    def row(mode, st, elapsed):
        return {
            "mode": mode,
            "requests": total_reqs,
            "rows_s": round(total_rows / elapsed, 1),
            "p50_ms": st["latency"]["p50_ms"],
            "p99_ms": st["latency"]["p99_ms"],
        }

    # best-of-N per mode: each drive is ~100 ms, and on a small shared
    # box (1-2 cores) a single drive's p50 carries GIL/scheduler noise
    # comparable to the tracing cost under test — the minimum over
    # repeats estimates each mode's noise floor, which is the honest
    # numerator/denominator for an overhead *ratio*
    def best(make_obs):
        picked = None
        for _ in range(OBS_DRIVE_REPEATS):
            o = make_obs()
            run = (o, *drive(o))
            if picked is None or (
                run[2]["latency"]["p50_ms"] < picked[2]["latency"]["p50_ms"]
            ):
                picked = run
        return picked

    _, _, st_off, _, t_off = best(lambda: False)
    obs, rt_on, st_on, digest, t_on = best(
        lambda: Observability(seed=0, registry=MetricsRegistry())
    )
    rows = [row("untraced", st_off, t_off), row("traced", st_on, t_on)]

    # -- three-way conservation on the traced run ------------------------
    tele_balances = st_on["requests"] == (
        st_on["served_requests"] + st_on["failed_requests"]
        + st_on["deadline_timeouts"] + st_on["closed_requests"]
    )
    cons = obs.tracer.conservation(digest[:12])
    spans_match = (
        cons["admitted"] == st_on["requests"]
        and cons["served"] == st_on["served_requests"]
        and cons["shed"] == st_on["shed_requests"]
    )
    series = obs.metrics.collect()

    def prom_total(name):
        return sum(series.get(f"repro_serve_{name}_total", {}).values())

    prom_balances = prom_total("requests") == (
        prom_total("served_requests") + prom_total("failed_requests")
        + prom_total("deadline_timeouts") + prom_total("closed_requests")
    ) and prom_total("requests") == st_on["requests"]
    rendered = obs.render_prometheus()
    gauges_present = all(
        f"repro_serve_{g}" in rendered
        for g in ("validity_fraction", "fallback_rate", "queue_rows",
                  "step_time_ewma_seconds", "breaker_state")
    )

    p50_off = st_off["latency"]["p50_ms"] or 1e-9
    p99_off = st_off["latency"]["p99_ms"] or 1e-9
    meta = {
        "clients": OBS_CLIENTS,
        "reqs_per_client": reqs,
        "req_rows": OBS_REQ_ROWS,
        "drives_per_mode": OBS_DRIVE_REPEATS,
        "max_wait_us": RUNTIME_MAX_WAIT_US,
        "overhead_p50": round((st_on["latency"]["p50_ms"] or 0) / p50_off, 4),
        "overhead_p99": round((st_on["latency"]["p99_ms"] or 0) / p99_off, 4),
        "span_count": sum(
            v for k, v in obs.tracer.counts(digest[:12]).items()
            if "[" not in k
        ),
        "conservation": {
            "submitted": cons["submitted"],
            "unaccounted": cons["unaccounted"],
            "telemetry_balances": bool(tele_balances),
            "spans_match_telemetry": bool(spans_match),
            "prometheus_balances": bool(prom_balances),
            "prometheus_gauges_present": bool(gauges_present),
        },
    }
    print("[serving] observability: traced vs untraced closed-loop serving")
    print(fmt_table(rows, ["mode", "requests", "rows_s", "p50_ms", "p99_ms"]))
    print(f"[serving] {meta}")
    return {
        "note": (
            "identical seeded closed-loop workloads through Runtime(obs=False) "
            "and a fully traced Runtime (private registry); best-of-N drives "
            "per mode, p50/p99 from the per-request latency window, so "
            "overhead_p50 is the request-level tracing tax (gated <= 1.05x; "
            "the coalesce wait dominates both). "
            "conservation re-proves served+failed+expired+closed == admitted "
            "in telemetry counters, span counts and the Prometheus rendering"
        ),
        "rows": rows,
        "meta": meta,
    }


def bench_serving_http() -> dict:
    """The HTTP front door vs in-process submit on identical traffic.

    One runtime, two legs. Leg A: closed-loop clients calling
    ``rt.submit(...).result()`` directly. Leg B: the same clients as
    HTTP clients (stdlib ``http.client``, one keep-alive connection
    each) POSTing ``:predict`` to the ASGI app — the full wire path:
    parse, tenancy, executor bridge, micro-batcher, JSON response.
    Latencies are client-side per request in BOTH legs, so the
    overhead ratio is honest about everything the network adds.
    """
    reqs = 8 if SMOKE else HTTP_REQS_PER_CLIENT
    m = _model(seed=3)
    art = families.maclaurin.compile(m)
    rt = Runtime(
        max_wait_us=HTTP_MAX_WAIT_US,
        flush_rows=RUNTIME_FLUSH_ROWS,
        engine_opts=dict(min_bucket=32, max_batch=1024),
        obs=Observability(seed=0, registry=MetricsRegistry()),
    )
    rt.publish("primary", art, PublishSpec(exact=m))
    rt.warmup("primary")
    digest = rt.registry.resolve("primary")
    rng = np.random.default_rng(17)
    work = [
        [rng.standard_normal((HTTP_REQ_ROWS, D)).astype(np.float32) * 0.3
         for _ in range(reqs)]
        for _ in range(HTTP_CLIENTS)
    ]
    total_rows = HTTP_CLIENTS * reqs * HTTP_REQ_ROWS

    def fan_out(target):
        threads = [threading.Thread(target=target, args=(i, w))
                   for i, w in enumerate(work)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return time.perf_counter() - t0

    # ---- leg A: in-process closed loop --------------------------------
    lat_proc: list[list[float]] = [[] for _ in range(HTTP_CLIENTS)]

    def in_process(i, batches):
        for Z in batches:
            t0 = time.perf_counter()
            rt.submit("primary", Z).result().values
            lat_proc[i].append(time.perf_counter() - t0)

    t_proc = fan_out(in_process)

    # ---- leg B: the same traffic over HTTP ----------------------------
    app = create_app(rt)
    lat_http: list[list[float]] = [[] for _ in range(HTTP_CLIENTS)]
    statuses: list[list[int]] = [[] for _ in range(HTTP_CLIENTS)]
    before = rt.stats("primary")
    with http_serve(app) as handle:
        import http.client

        def over_http(i, batches):
            conn = http.client.HTTPConnection(handle.host, handle.port,
                                              timeout=60)
            for Z in batches:
                body = json.dumps({"rows": Z.tolist()}).encode()
                t0 = time.perf_counter()
                conn.request("POST", "/v1/models/primary:predict", body,
                             {"Content-Type": "application/json"})
                resp = conn.getresponse()
                resp.read()
                lat_http[i].append(time.perf_counter() - t0)
                statuses[i].append(resp.status)
            conn.close()

        t_http = fan_out(over_http)
    after = rt.stats("primary")

    flat_proc = np.array([t for c in lat_proc for t in c])
    flat_http = np.array([t for c in lat_http for t in c])
    flat_status = [s for c in statuses for s in c]
    d_reqs = after["requests"] - before["requests"]
    d_flushes = max(1, after["flushes"] - before["flushes"])
    cons = rt.obs.tracer.conservation(digest[:12])
    queue_rows = after["queue_rows"]
    rt.close()

    p = lambda a, q: round(float(np.percentile(a, q)) * 1e3, 3)  # noqa: E731
    rows = [
        {"path": "in_process", "clients": HTTP_CLIENTS,
         "requests": len(flat_proc), "p50_ms": p(flat_proc, 50),
         "p99_ms": p(flat_proc, 99),
         "rows_s": round(total_rows / t_proc, 1)},
        {"path": "http", "clients": HTTP_CLIENTS,
         "requests": len(flat_http), "p50_ms": p(flat_http, 50),
         "p99_ms": p(flat_http, 99),
         "rows_s": round(total_rows / t_http, 1)},
    ]
    meta = {
        "req_rows": HTTP_REQ_ROWS,
        "max_wait_us": HTTP_MAX_WAIT_US,
        "http_statuses_ok": sum(1 for s in flat_status if s == 200),
        "http_statuses_other": sum(1 for s in flat_status if s != 200),
        "http_overhead_p50": round(
            rows[1]["p50_ms"] / max(rows[0]["p50_ms"], 1e-9), 2
        ),
        "http_coalescing_factor": round(d_reqs / d_flushes, 2),
        "queue_rows_after": queue_rows,
        "conservation": cons,
    }
    print("[serving] serving_http: in-process vs HTTP front door")
    print(fmt_table(rows, ["path", "clients", "requests", "p50_ms",
                           "p99_ms", "rows_s"]))
    print(f"[serving] {meta}")
    return {
        "note": (
            "identical closed-loop traffic served in-process "
            "(rt.submit().result()) and over the stdlib HTTP front door "
            "(persistent connections, JSON bodies); latencies are "
            "client-side per request; conservation must balance and the "
            "queue must drain to zero after the HTTP leg"
        ),
        "rows": rows,
        "meta": meta,
    }


SECTIONS = (
    "engine",
    "head_scaling",
    "family_compare",
    "model_size",
    "fastfood",
    "block_sweep",
    "runtime_throughput",
    "overload",
    "degraded_mode",
    "scaleout",
    "observability",
    "serving_http",
)


def run(sections: list[str] | None = None):
    chosen = set(sections) if sections else set(SECTIONS)
    unknown = chosen - set(SECTIONS)
    if unknown:
        raise SystemExit(f"unknown sections {sorted(unknown)}; "
                         f"known: {sorted(SECTIONS)}")

    # partial runs merge over the existing results file so a targeted rerun
    # (e.g. CI's `runtime_throughput --smoke`) keeps the other trajectories
    payload = {}
    existing = os.path.join(RESULTS_DIR, "BENCH_serving.json")
    if chosen != set(SECTIONS) and os.path.exists(existing):
        try:
            with open(existing) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}

    payload.update({
        "host_backend": jax.default_backend(),
        "svm_backend": backend.resolve(),
        "smoke": SMOKE,
        "model": {"d": D, "n_sv": N_SV},
    })
    if "engine" in chosen:
        engine_rows, engine_meta = bench_engine()
        payload["engine"] = engine_rows
        payload["engine_meta"] = engine_meta
    if "head_scaling" in chosen:
        payload["head_scaling"] = bench_heads()
    if "family_compare" in chosen:
        payload["family_compare"] = {
            "note": (
                "engine fast-path p50/p99 (fallback off) and measured error "
                "vs the exact RBF expansion on the same batch; 'exact' rows "
                "are the shared kernel-matrix GEMM baseline with zero error "
                "by definition; int8 rows serve the same model through the "
                "fused-dequant path"
            ),
            "batch": FAMILY_BATCH,
            "n_sv": FAMILY_NSV,
            "num_features": family_num_features(),
            "rows": bench_family_compare(),
        }
    if "model_size" in chosen:
        payload["model_size"] = bench_model_size()
    if "fastfood" in chosen:
        payload["fastfood"] = bench_fastfood()
    if "block_sweep" in chosen:
        payload["block_sweep"] = {
            "note": (
                "tuned = argmin over candidates INCLUDING the default, so "
                "tuned_ms <= default_ms by construction; on non-TPU hosts "
                "the dispatched path is XLA and the spread is noise"
            ),
            "platform": tuning.platform(),
            "rows": bench_block_sweep(),
        }
    if "runtime_throughput" in chosen:
        payload["runtime_throughput"] = bench_runtime_throughput()
    if "overload" in chosen:
        payload["overload"] = bench_overload()
    if "degraded_mode" in chosen:
        payload["degraded_mode"] = bench_degraded_mode()
    if "scaleout" in chosen:
        payload["scaleout"] = bench_scaleout()
    if "observability" in chosen:
        payload["observability"] = bench_observability()
    if "serving_http" in chosen:
        payload["serving_http"] = bench_serving_http()
    path = save_json("BENCH_serving.json", payload)
    print(f"[serving] wrote {path}")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("sections", nargs="*", choices=[[], *sorted(SECTIONS)],
                    help="sections to (re)run and merge into the results "
                         "JSON; default: all")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: same sections and JSON shape, far fewer "
                         "repeats (numbers are noisy, structure is exercised)")
    args = ap.parse_args()
    if args.smoke:
        SMOKE = True
        REPEATS = 20
        BATCHES = [1, 64, 256]
        RUNTIME_CLIENTS = [1, 8]
    run(args.sections or None)
