"""Serving-path latency: engine p50/p99 per shape bucket, and fused
multi-head vs per-head-vmap scaling.

Two questions, both measured for real on this host:

1. What end-to-end latency does ``SVMEngine.predict`` deliver per shape
   bucket once warm (zero recompiles)?  p50 is the steady-state cost; p99
   captures jitter (allocator, host padding, sync).
2. What does fusing K heads into one stacked-Hessian contraction buy over
   the seed's K-pass vmap?  Measured at K in {1, 10} on identical data —
   the ratio is the multiclass serving speedup.

Emits BENCH_serving.json (benchmarks/common.save_json) so later perf PRs
have a trajectory to compare against.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, save_json, timeit
from repro.core import approximate, backend, gamma_max
from repro.core.rbf import SVMModel
from repro.kernels.quadform.ref import quadform_heads_ref
from repro.serve.svm_engine import SVMEngine, bucket_size

D = 64
N_SV = 512
BATCHES = [1, 8, 32, 64, 256, 1024]
REPEATS = 200
HEAD_COUNTS = [1, 10]
HEADS_BATCH = 1024


def _model(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((N_SV, D)).astype(np.float32) * 0.5
    ay = rng.standard_normal(N_SV).astype(np.float32)
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    return SVMModel(
        X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
        b=jnp.float32(0.1), gamma=jnp.float32(gamma),
    )


def bench_engine() -> list[dict]:
    m = _model()
    eng = SVMEngine(approximate(m), m, min_bucket=32, max_batch=1024)
    eng.warmup()
    rng = np.random.default_rng(1)
    rows = []
    for n in BATCHES:
        batches = [rng.standard_normal((n, D)).astype(np.float32) * 0.3
                   for _ in range(8)]
        for Z in batches:                                  # warm this bucket
            eng.predict(Z)
        times = []
        for i in range(REPEATS):
            Z = batches[i % len(batches)]
            t0 = time.perf_counter()
            f, _ = eng.predict(Z)                          # includes sync
            times.append(time.perf_counter() - t0)
        times = np.asarray(times) * 1e3
        rows.append({
            "batch": n,
            "bucket": bucket_size(n, 32, 1024),
            "p50_ms": round(float(np.percentile(times, 50)), 4),
            "p99_ms": round(float(np.percentile(times, 99)), 4),
            "per_row_us_p50": round(1e3 * float(np.percentile(times, 50)) / n, 2),
        })
    assert eng.jit_cache_size() <= 6, "bucket cache must stay bounded"
    rows_meta = {
        "jit_variants": eng.jit_cache_size(),
        "padding_overhead": round(eng.stats.padding_overhead, 4),
    }
    print("[serving] engine latency per bucket (warm, zero recompiles)")
    print(fmt_table(rows, ["batch", "bucket", "p50_ms", "p99_ms", "per_row_us_p50"]))
    print(f"[serving] {rows_meta}")
    return rows, rows_meta


def bench_heads() -> list[dict]:
    """Fused stacked-Hessian scoring vs the seed's per-head vmap at equal K."""
    rng = np.random.default_rng(2)
    Z = jnp.asarray(rng.standard_normal((HEADS_BATCH, D)).astype(np.float32) * 0.3)
    rows = []
    for K in HEAD_COUNTS:
        Ms = rng.standard_normal((K, D, D)).astype(np.float32) * 0.05
        M_all = jnp.asarray((Ms + Ms.transpose(0, 2, 1)) / 2)
        V = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        c = jnp.asarray(rng.standard_normal(K).astype(np.float32))
        b = jnp.asarray(rng.standard_normal(K).astype(np.float32))
        g = jnp.full((K,), 0.05, jnp.float32)
        msq = jnp.full((K,), 2.0, jnp.float32)

        fused = jax.jit(backend.quadform_heads_xla)
        unfused = jax.jit(quadform_heads_ref)              # K-pass vmap oracle
        t_fused = timeit(fused, Z, M_all, V, c, b, g, msq, repeats=20, warmup=3)
        t_vmap = timeit(unfused, Z, M_all, V, c, b, g, msq, repeats=20, warmup=3)
        rows.append({
            "K": K,
            "batch": HEADS_BATCH,
            "d": D,
            "fused_ms": round(1e3 * t_fused, 3),
            "vmap_ms": round(1e3 * t_vmap, 3),
            "speedup": round(t_vmap / t_fused, 2),
        })
    print("[serving] fused multi-head vs per-head vmap (best-of-20)")
    print(fmt_table(rows, ["K", "batch", "d", "fused_ms", "vmap_ms", "speedup"]))
    return rows


def run():
    engine_rows, engine_meta = bench_engine()
    head_rows = bench_heads()
    payload = {
        "host_backend": jax.default_backend(),
        "svm_backend": backend.resolve(),
        "model": {"d": D, "n_sv": N_SV},
        "engine": engine_rows,
        "engine_meta": engine_meta,
        "head_scaling": head_rows,
    }
    path = save_json("BENCH_serving.json", payload)
    print(f"[serving] wrote {path}")
    return payload


if __name__ == "__main__":
    run()
