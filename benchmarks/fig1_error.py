"""Figure-1 reproduction: absolute relative error of the second-order
Maclaurin approximation of exp, and the Eq A.2 certificate."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.bounds import maclaurin_rel_error, REL_ERR_AT_HALF
from benchmarks.common import save_json


def run() -> dict:
    xs = np.linspace(-3.0, 3.0, 1201)
    errs = np.asarray(maclaurin_rel_error(jnp.asarray(xs, jnp.float64)))
    inside = np.abs(xs) <= 0.5
    sup_inside = float(errs[inside].max())
    result = {
        "sup_rel_err_inside_half": sup_inside,
        "paper_bound": REL_ERR_AT_HALF,
        "bound_holds": sup_inside < REL_ERR_AT_HALF,
        "err_at_1": float(maclaurin_rel_error(jnp.float64(-1.0))),
        "err_at_2": float(maclaurin_rel_error(jnp.float64(-2.0))),
        "curve": {"x": xs[::10].tolist(), "err": errs[::10].tolist()},
    }
    save_json("fig1_error.json", result)
    print(f"[fig1] sup |x|<=0.5 rel err = {sup_inside:.4f} "
          f"(paper bound {REL_ERR_AT_HALF}) -> {'OK' if result['bound_holds'] else 'VIOLATION'}")
    print(f"[fig1] err grows fast outside: e(-1)={result['err_at_1']:.3f} "
          f"e(-2)={result['err_at_2']:.3f} (why ignoring Eq 3.11 forfeits guarantees)")
    return result


if __name__ == "__main__":
    run()
