"""CI gate: compiled artifacts must be byte-deterministic ACROSS processes.

The registry content-addresses artifacts by the SHA-256 of their
deterministic npz bytes; everything above it (dedupe, lazy directory
indexing, alias hot-swap, int8-vs-f32 variant identity) assumes the same
model + seed compiles to bit-identical bytes in any process. A stray
nondeterminism — an unseeded rng, dict-order leakage into the meta JSON,
platform-dependent quantization rounding — would silently fork digests
between the process that saved an artifact and the one that loads it.

This script compiles one seeded model under EVERY (family, dtype)
candidate in two separate interpreter processes and fails if any digest
differs (it also checks the int8 digest actually differs from the f32
one, so the quantized variants stay distinct registry entries).

Usage: ``python tools/check_artifact_determinism.py`` (spawns its own
children; needs ``src`` importable or on PYTHONPATH).
"""

from __future__ import annotations

import os
import subprocess
import sys

# (label, family, dtype, extra compile opts). The label keys the digest
# comparison — "fourier" appears twice (dense and structured), and the
# structured-Fastfood int8 layout (sign/int8/int16/f16 narrowing) has its
# own cross-process bit-determinism to prove.
CASES = [
    ("maclaurin", "maclaurin", "float32", {}),
    ("maclaurin-q8", "maclaurin", "int8", {}),
    ("poly2", "poly2", "float32", {}),
    ("poly2-q8", "poly2", "int8", {}),
    ("fourier", "fourier", "float32", {}),
    ("fourier-q8", "fourier", "int8", {}),
    ("fastfood", "fourier", "float32", {"structured": True}),
    ("fastfood-q8", "fourier", "int8", {"structured": True}),
]

# f32/int8 variant pairs whose digests must stay DISTINCT registry entries.
VARIANT_PAIRS = [
    ("maclaurin", "maclaurin-q8"),
    ("poly2", "poly2-q8"),
    ("fourier", "fourier-q8"),
    ("fastfood", "fastfood-q8"),
]


def emit() -> None:
    """Child mode: print '<label> <digest>' per candidate."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core import gamma_max
    from repro.core.families import get_family
    from repro.core.rbf import SVMModel

    rng = np.random.default_rng(42)
    X = rng.standard_normal((96, 24)).astype(np.float32) * 0.5
    ay = rng.standard_normal((4, 96)).astype(np.float32) * 0.5
    b = jnp.asarray(0.1 * rng.standard_normal(4).astype(np.float32))
    svm = SVMModel(
        X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
        b=b, gamma=jnp.float32(0.8 * float(gamma_max(jnp.asarray(X)))),
    )
    for label, family, dtype, opts in CASES:
        art = get_family(family).compile(
            svm, dtype=dtype, seed=7, num_features=128, **opts
        )
        print(f"{label} {art.digest()}")


def main() -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ)
    extra = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + extra if extra else "")

    def run() -> dict[str, str]:
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--emit"],
            check=True, capture_output=True, text=True, env=env,
        ).stdout
        digests = {}
        for line in out.strip().splitlines():
            label, digest = line.split()
            digests[label] = digest
        return digests

    first, second = run(), run()
    problems = []
    for label, _, _, _ in CASES:
        if first[label] != second[label]:
            problems.append(
                f"{label}: digest differs across processes "
                f"({first[label][:16]} vs {second[label][:16]})"
            )
    for f32_label, q8_label in VARIANT_PAIRS:
        if first.get(f32_label) == first.get(q8_label):
            problems.append(f"{f32_label}: int8 digest equals f32 digest")
    if problems:
        print(f"[determinism] {len(problems)} violation(s):")
        for p in problems:
            print(f"  FAIL {p}")
        return 1
    print(f"[determinism] OK — {len(CASES)} (family, dtype, opts) artifacts "
          f"compile to identical digests in two separate processes")
    return 0


if __name__ == "__main__":
    if "--emit" in sys.argv:
        emit()
    else:
        sys.exit(main())
