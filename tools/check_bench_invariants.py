"""CI gate over ``results/BENCH_serving.json`` STRUCTURE, not numbers.

Latency numbers from shared CI runners are noise and are never asserted.
What IS asserted are the properties that hold on any host or the build
is broken:

  * ``model_size``: every int8 variant serializes >= 3x smaller than its
    f32 parent, keeps label/argmax parity with the f32 engine, carries a
    distinct content digest, and its meta's reported quantization error
    reproduces on the deterministic holdout (measured-within-report);
  * ``family_compare``: every family was measured at both dtypes, and
    quantization does not blow up the family's measured error;
  * ``runtime_throughput``: coalescing added ZERO steady-state
    recompiles.

Usage: ``python tools/check_bench_invariants.py [path-to-json]``
Exits non-zero listing every violated invariant.
"""

from __future__ import annotations

import json
import os
import sys

MIN_SIZE_RATIO = 3.0
MIN_LABEL_PARITY = 0.99
QUANT_ERR_REPRO_RTOL = 0.05     # measured == reported up to float noise
QUANT_ERR_SLACK = 0.01          # int8 family error <= f32 error + this

DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "BENCH_serving.json",
)


def check_model_size(payload: dict, problems: list[str]) -> None:
    section = payload.get("model_size")
    if not section or not section.get("rows"):
        problems.append("model_size: section missing or empty")
        return
    for r in section["rows"]:
        tag = f"model_size[{r['family']} K={r['K']} d={r['d']}]"
        if r["ratio"] < MIN_SIZE_RATIO:
            problems.append(
                f"{tag}: int8 ratio {r['ratio']} < {MIN_SIZE_RATIO}"
            )
        if r["label_parity"] < MIN_LABEL_PARITY:
            problems.append(
                f"{tag}: label parity {r['label_parity']} < {MIN_LABEL_PARITY}"
            )
        if r["int8_digest"] == r["f32_digest"]:
            problems.append(f"{tag}: int8 digest equals f32 digest")
        for stat in ("mean_abs_err", "max_abs_err"):
            reported = r[f"quant_{stat}"]
            measured = r[f"remeasured_{stat}"]
            if abs(measured - reported) > 1e-9 + QUANT_ERR_REPRO_RTOL * reported:
                problems.append(
                    f"{tag}: quant {stat} reported {reported:.3e} does not "
                    f"reproduce (measured {measured:.3e})"
                )


def check_family_compare(payload: dict, problems: list[str]) -> None:
    section = payload.get("family_compare")
    if not section or not section.get("rows"):
        problems.append("family_compare: section missing or empty")
        return
    rows = section["rows"]
    by_key = {
        (r["K"], r["d"], r["family"], r.get("dtype")): r
        for r in rows
    }
    cells = {(r["K"], r["d"]) for r in rows}
    for K, d in sorted(cells):
        for family in ("maclaurin", "poly2", "fourier"):
            f32 = by_key.get((K, d, family, "float32"))
            q8 = by_key.get((K, d, family, "int8"))
            tag = f"family_compare[{family} K={K} d={d}]"
            if f32 is None or q8 is None:
                problems.append(f"{tag}: missing a dtype row "
                                f"(f32={f32 is not None}, int8={q8 is not None})")
                continue
            if q8["mean_abs_err"] > f32["mean_abs_err"] + QUANT_ERR_SLACK:
                problems.append(
                    f"{tag}: int8 mean error {q8['mean_abs_err']:.4g} blows "
                    f"past f32 {f32['mean_abs_err']:.4g} + {QUANT_ERR_SLACK}"
                )


def check_runtime(payload: dict, problems: list[str]) -> None:
    section = payload.get("runtime_throughput")
    if not section:
        problems.append("runtime_throughput: section missing")
        return
    recompiles = section.get("meta", {}).get("steady_state_recompiles")
    if recompiles != 0:
        problems.append(
            f"runtime_throughput: steady_state_recompiles == {recompiles!r}, "
            f"must be 0"
        )


def main(argv: list[str]) -> int:
    path = argv[1] if len(argv) > 1 else DEFAULT_PATH
    with open(path) as f:
        payload = json.load(f)
    problems: list[str] = []
    check_model_size(payload, problems)
    check_family_compare(payload, problems)
    check_runtime(payload, problems)
    if problems:
        print(f"[bench-invariants] {len(problems)} violation(s) in {path}:")
        for p in problems:
            print(f"  FAIL {p}")
        return 1
    print(f"[bench-invariants] OK — model_size, family_compare and "
          f"runtime_throughput invariants hold in {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
