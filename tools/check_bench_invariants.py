"""CI gate over ``results/BENCH_serving.json`` STRUCTURE, not numbers.

Latency numbers from shared CI runners are noise and are never asserted.
What IS asserted are the properties that hold on any host or the build
is broken:

  * ``model_size``: every int8 variant serializes >= 3x smaller than its
    f32 parent, keeps label/argmax parity with the f32 engine, carries a
    distinct content digest, and its meta's reported quantization error
    reproduces on the deterministic holdout (measured-within-report);
  * ``family_compare``: every family was measured at both dtypes, and
    quantization does not blow up the family's measured error;
  * ``fastfood``: the full (d, variant, dtype) grid is present, the
    structured (FWHT) rows beat the dense-RFF rows/s at d=784, the int8
    structured rows keep the >= 3x size ratio and >= 0.99 label parity,
    and no row added steady-state recompiles;
  * ``runtime_throughput``: coalescing added ZERO steady-state
    recompiles;
  * ``overload``: the burst past capacity really shed (typed, with a
    retry hint), the accounting balances (admitted + shed ==
    submitted, client-side == telemetry-side), ZERO admitted futures
    hung, the queue respected its bound, and the burst added zero
    steady-state recompiles;
  * ``degraded_mode``: the breaker was genuinely open during the
    degraded measurement, every request was served (none shed), and
    degraded serving added zero fast-path recompiles;
  * ``scaleout``: with per-flush service time pinned, rows/s rises
    (tolerance-)monotonically with replica count and the top count
    strictly beats one replica, every replica actually served, nothing
    failed or shed, zero steady-state recompiles on the replicated
    path; the head-sharded K>=4096 serving kept exact argmax parity
    with the unsharded reference. The section must be generated under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (>= 2
    devices are required);
  * ``observability``: the traced run's request p50 stays within 1.05x
    of the untraced run's (span recording is lock-cheap), and the
    conservation identity (served + failed + expired + closed ==
    admitted; submitted == admitted + shed) holds simultaneously in
    telemetry counters, tracer span counts and the Prometheus
    rendering, with the first-class gauges present in the exposition;
  * ``serving_http``: every HTTP prediction on the un-overloaded
    workload succeeded, the conservation identity survives the network
    hop, the queue drains to zero after the HTTP leg (zero hung
    futures), requests still coalesce through the async bridge, and
    the HTTP p50 overhead stays under a generous structural bound.

Usage: ``python tools/check_bench_invariants.py [path-to-json]``
Exits non-zero listing every violated invariant.
"""

from __future__ import annotations

import json
import os
import sys

MIN_SIZE_RATIO = 3.0
MIN_LABEL_PARITY = 0.99
QUANT_ERR_REPRO_RTOL = 0.05     # measured == reported up to float noise
QUANT_ERR_SLACK = 0.01          # int8 family error <= f32 error + this
SCALEOUT_MONOTONIC_TOL = 0.9    # rows/s per count >= 0.9x best smaller count
HTTP_OVERHEAD_MAX = 25.0        # HTTP p50 <= 25x in-process p50: catches a
                                # structural regression (per-request
                                # handshake, serialized bridge), not noise

DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "BENCH_serving.json",
)


def check_model_size(payload: dict, problems: list[str]) -> None:
    section = payload.get("model_size")
    if not section or not section.get("rows"):
        problems.append("model_size: section missing or empty")
        return
    for r in section["rows"]:
        tag = f"model_size[{r['family']} K={r['K']} d={r['d']}]"
        if r["ratio"] < MIN_SIZE_RATIO:
            problems.append(
                f"{tag}: int8 ratio {r['ratio']} < {MIN_SIZE_RATIO}"
            )
        if r["label_parity"] < MIN_LABEL_PARITY:
            problems.append(
                f"{tag}: label parity {r['label_parity']} < {MIN_LABEL_PARITY}"
            )
        if r["int8_digest"] == r["f32_digest"]:
            problems.append(f"{tag}: int8 digest equals f32 digest")
        for stat in ("mean_abs_err", "max_abs_err"):
            reported = r[f"quant_{stat}"]
            measured = r[f"remeasured_{stat}"]
            if abs(measured - reported) > 1e-9 + QUANT_ERR_REPRO_RTOL * reported:
                problems.append(
                    f"{tag}: quant {stat} reported {reported:.3e} does not "
                    f"reproduce (measured {measured:.3e})"
                )


def check_family_compare(payload: dict, problems: list[str]) -> None:
    section = payload.get("family_compare")
    if not section or not section.get("rows"):
        problems.append("family_compare: section missing or empty")
        return
    rows = section["rows"]
    by_key = {
        (r["K"], r["d"], r["family"], r.get("dtype")): r
        for r in rows
    }
    cells = {(r["K"], r["d"]) for r in rows}
    for K, d in sorted(cells):
        for family in ("maclaurin", "poly2", "fourier"):
            f32 = by_key.get((K, d, family, "float32"))
            q8 = by_key.get((K, d, family, "int8"))
            tag = f"family_compare[{family} K={K} d={d}]"
            if f32 is None or q8 is None:
                problems.append(f"{tag}: missing a dtype row "
                                f"(f32={f32 is not None}, int8={q8 is not None})")
                continue
            if q8["mean_abs_err"] > f32["mean_abs_err"] + QUANT_ERR_SLACK:
                problems.append(
                    f"{tag}: int8 mean error {q8['mean_abs_err']:.4g} blows "
                    f"past f32 {f32['mean_abs_err']:.4g} + {QUANT_ERR_SLACK}"
                )


def check_fastfood(payload: dict, problems: list[str]) -> None:
    section = payload.get("fastfood")
    if not section or not section.get("rows"):
        problems.append("fastfood: section missing or empty")
        return
    rows = section["rows"]
    by_key = {(r["d"], r["variant"], r["dtype"]): r for r in rows}
    dims = section.get("dims") or sorted({r["d"] for r in rows})
    variants = ("structured", "dense", "quadform")
    for d in dims:
        for variant in variants:
            for dtype in ("float32", "int8"):
                tag = f"fastfood[{variant} d={d} {dtype}]"
                r = by_key.get((d, variant, dtype))
                if r is None:
                    problems.append(f"{tag}: row missing from the grid")
                    continue
                if r.get("steady_state_recompiles") != 0:
                    problems.append(
                        f"{tag}: steady_state_recompiles == "
                        f"{r.get('steady_state_recompiles')!r}, must be 0"
                    )
                if dtype == "int8":
                    if r.get("label_parity_vs_f32", 0) < MIN_LABEL_PARITY:
                        problems.append(
                            f"{tag}: label parity vs f32 "
                            f"{r.get('label_parity_vs_f32')!r} "
                            f"< {MIN_LABEL_PARITY}"
                        )
                    if variant != "quadform" and (
                        r.get("size_ratio_vs_f32") or 0
                    ) < MIN_SIZE_RATIO:
                        problems.append(
                            f"{tag}: int8 size ratio "
                            f"{r.get('size_ratio_vs_f32')!r} "
                            f"< {MIN_SIZE_RATIO}"
                        )
    # the paper's claim: at MNIST-sized d the structured projection beats
    # the dense RFF GEMM in steady-state throughput
    if 784 in dims:
        st = by_key.get((784, "structured", "float32"))
        dn = by_key.get((784, "dense", "float32"))
        if st and dn and st["rows_per_s"] <= dn["rows_per_s"]:
            problems.append(
                f"fastfood[d=784 float32]: structured {st['rows_per_s']} "
                f"rows/s did not beat dense RFF {dn['rows_per_s']} rows/s"
            )


def check_runtime(payload: dict, problems: list[str]) -> None:
    section = payload.get("runtime_throughput")
    if not section:
        problems.append("runtime_throughput: section missing")
        return
    recompiles = section.get("meta", {}).get("steady_state_recompiles")
    if recompiles != 0:
        problems.append(
            f"runtime_throughput: steady_state_recompiles == {recompiles!r}, "
            f"must be 0"
        )


def check_overload(payload: dict, problems: list[str]) -> None:
    section = payload.get("overload")
    if not section or not section.get("meta"):
        problems.append("overload: section missing or empty")
        return
    meta = section["meta"]
    if meta.get("shed_requests", 0) <= 0:
        problems.append(
            f"overload: burst past capacity shed nothing "
            f"(shed_requests == {meta.get('shed_requests')!r})"
        )
    elif meta.get("retry_after_s_min") is None or meta["retry_after_s_min"] <= 0:
        problems.append(
            f"overload: sheds carried no positive retry_after_s hint "
            f"(min == {meta.get('retry_after_s_min')!r})"
        )
    if meta.get("shed_requests") != meta.get("shed_requests_telemetry"):
        problems.append(
            f"overload: client-side sheds {meta.get('shed_requests')!r} != "
            f"telemetry sheds {meta.get('shed_requests_telemetry')!r}"
        )
    if meta.get("admitted", 0) + meta.get("shed_requests", 0) != meta.get("submitted"):
        problems.append(
            f"overload: accounting leak — admitted {meta.get('admitted')!r} "
            f"+ shed {meta.get('shed_requests')!r} != "
            f"submitted {meta.get('submitted')!r}"
        )
    if meta.get("hung_futures") != 0:
        problems.append(
            f"overload: {meta.get('hung_futures')!r} admitted future(s) "
            f"never resolved"
        )
    if meta.get("queue_rows_after_drain") != 0:
        problems.append(
            f"overload: queue gauge {meta.get('queue_rows_after_drain')!r} "
            f"rows after full drain, must be 0"
        )
    # the telemetry gauge keeps counting a popped batch until its flush
    # is recorded, so the provable high-water is waiting rows (bounded
    # by admission) plus the batch in execution: 2x the admission bound
    bound = meta.get("max_queue_rows_bound")
    if bound is not None and meta.get("max_queue_rows_observed", 0) > 2 * bound:
        problems.append(
            f"overload: queue high-water {meta.get('max_queue_rows_observed')!r} "
            f"exceeded waiting + in-flight bound {2 * bound!r}"
        )
    if meta.get("steady_state_recompiles") != 0:
        problems.append(
            f"overload: steady_state_recompiles == "
            f"{meta.get('steady_state_recompiles')!r}, must be 0"
        )


def check_degraded(payload: dict, problems: list[str]) -> None:
    section = payload.get("degraded_mode")
    if not section or not section.get("meta"):
        problems.append("degraded_mode: section missing or empty")
        return
    meta = section["meta"]
    if meta.get("breaker_state") != "open":
        problems.append(
            f"degraded_mode: breaker state {meta.get('breaker_state')!r} "
            f"during the degraded measurement, must be 'open'"
        )
    if meta.get("breaker_trips", 0) < 1:
        problems.append("degraded_mode: breaker never recorded a trip")
    if meta.get("degraded_requests", 0) <= 0:
        problems.append(
            f"degraded_mode: no requests served degraded "
            f"(degraded_requests == {meta.get('degraded_requests')!r})"
        )
    if meta.get("breaker_shed_requests") != 0:
        problems.append(
            f"degraded_mode: {meta.get('breaker_shed_requests')!r} requests "
            f"shed despite an exact model being published"
        )
    if meta.get("steady_state_recompiles") != 0:
        problems.append(
            f"degraded_mode: degraded serving added "
            f"{meta.get('steady_state_recompiles')!r} fast-path variants, "
            f"must be 0"
        )


def check_scaleout(payload: dict, problems: list[str]) -> None:
    section = payload.get("scaleout")
    if (
        not section
        or not section.get("replica_rows")
        or not section.get("sharded")
    ):
        problems.append(
            "scaleout: section missing or empty (generate under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
        return
    meta = section.get("meta", {})
    if meta.get("devices", 0) < 2:
        problems.append(
            f"scaleout: {meta.get('devices')!r} visible device(s) — run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )
    rows = section["replica_rows"]
    if len(rows) < 2:
        problems.append(
            f"scaleout: {len(rows)} replica count(s) measured, need >= 2"
        )
    best = None
    for r in rows:
        tag = f"scaleout[replicas={r.get('replicas')}]"
        if r.get("steady_state_recompiles") != 0:
            problems.append(
                f"{tag}: steady_state_recompiles == "
                f"{r.get('steady_state_recompiles')!r}, must be 0"
            )
        if not r.get("all_replicas_served"):
            problems.append(
                f"{tag}: not every replica served a flush "
                f"(per_replica_flushes == {r.get('per_replica_flushes')!r})"
            )
        if r.get("failed_requests", 0) != 0 or r.get("shed_requests", 0) != 0:
            problems.append(
                f"{tag}: lost traffic — failed "
                f"{r.get('failed_requests')!r}, shed {r.get('shed_requests')!r}"
            )
        rs = r.get("rows_s", 0)
        if best is not None and rs < SCALEOUT_MONOTONIC_TOL * best:
            problems.append(
                f"{tag}: rows/s {rs} regressed below {SCALEOUT_MONOTONIC_TOL}x "
                f"the best smaller count ({best})"
            )
        best = rs if best is None else max(best, rs)
    if len(rows) >= 2 and rows[-1].get("rows_s", 0) <= rows[0].get("rows_s", 0):
        problems.append(
            f"scaleout: {rows[-1].get('replicas')} replicas "
            f"({rows[-1].get('rows_s')} rows/s) did not beat 1 replica "
            f"({rows[0].get('rows_s')} rows/s) — dispatch is not overlapping"
        )
    sh = section["sharded"]
    if sh.get("K", 0) < 4096:
        problems.append(
            f"scaleout: sharded K == {sh.get('K')!r}, extreme-multiclass "
            f"claim needs >= 4096"
        )
    if sh.get("shards", 0) < 2:
        problems.append(
            f"scaleout: sharded over {sh.get('shards')!r} shard(s), "
            f"need >= 2 for a real partition"
        )
    if sh.get("argmax_parity") != 1.0:
        problems.append(
            f"scaleout: head-sharded argmax parity "
            f"{sh.get('argmax_parity')!r} at K={sh.get('parity_K')!r}, "
            f"must be exactly 1.0"
        )
    if not sh.get("scores_allclose"):
        problems.append(
            "scaleout: head-sharded scores diverged from the unsharded "
            "reference beyond tolerance"
        )
    if sh.get("fallback_rate", 0) != 0:
        problems.append(
            f"scaleout: sharded bench traffic left the Eq 3.11 envelope "
            f"(fallback_rate == {sh.get('fallback_rate')!r})"
        )


def check_observability(payload: dict, problems: list[str]) -> None:
    section = payload.get("observability")
    if not section or not section.get("rows") or not section.get("meta"):
        problems.append("observability: section missing or empty")
        return
    modes = {r.get("mode") for r in section["rows"]}
    if modes != {"untraced", "traced"}:
        problems.append(
            f"observability: need untraced+traced rows, got {sorted(modes)}"
        )
    meta = section["meta"]
    overhead = meta.get("overhead_p50")
    if overhead is None or overhead > 1.05:
        problems.append(
            f"observability: traced p50 overhead {overhead!r} > 1.05x — "
            f"span recording is no longer lock-cheap"
        )
    cons = meta.get("conservation", {})
    if cons.get("unaccounted") != 0:
        problems.append(
            f"observability: {cons.get('unaccounted')!r} request span(s) "
            f"unaccounted (admitted != served+failed+expired+closed)"
        )
    if not cons.get("submitted"):
        problems.append("observability: zero submitted requests traced")
    for flag in (
        "telemetry_balances",
        "spans_match_telemetry",
        "prometheus_balances",
        "prometheus_gauges_present",
    ):
        if cons.get(flag) is not True:
            problems.append(
                f"observability: conservation flag {flag} is "
                f"{cons.get(flag)!r}, must be True"
            )


def check_serving_http(payload: dict, problems: list[str]) -> None:
    section = payload.get("serving_http")
    if not section or not section.get("rows") or not section.get("meta"):
        problems.append("serving_http: section missing or empty")
        return
    paths = {r.get("path") for r in section["rows"]}
    if paths != {"in_process", "http"}:
        problems.append(
            f"serving_http: need in_process+http rows, got {sorted(paths)}"
        )
    meta = section["meta"]
    if meta.get("http_statuses_other", 1) != 0:
        problems.append(
            f"serving_http: {meta.get('http_statuses_other')!r} non-200 "
            f"response(s) on a workload with no induced overload"
        )
    if meta.get("http_statuses_ok", 0) <= 0:
        problems.append("serving_http: zero successful HTTP predictions")
    overhead = meta.get("http_overhead_p50")
    if overhead is None or overhead > HTTP_OVERHEAD_MAX:
        problems.append(
            f"serving_http: HTTP p50 overhead {overhead!r} > "
            f"{HTTP_OVERHEAD_MAX}x in-process — the wire path regressed "
            f"structurally (per-request handshake? serialized bridge?)"
        )
    if meta.get("http_coalescing_factor", 0) < 1.0:
        problems.append(
            f"serving_http: coalescing factor "
            f"{meta.get('http_coalescing_factor')!r} < 1.0 through the "
            f"async bridge"
        )
    if meta.get("queue_rows_after", 1) != 0:
        problems.append(
            f"serving_http: queue gauge {meta.get('queue_rows_after')!r} "
            f"rows after the HTTP leg drained, must be 0 (hung futures?)"
        )
    cons = meta.get("conservation", {})
    if cons.get("unaccounted") != 0:
        problems.append(
            f"serving_http: {cons.get('unaccounted')!r} request span(s) "
            f"unaccounted after the HTTP leg"
        )
    if cons.get("submitted", 0) <= 0:
        problems.append("serving_http: zero submitted requests traced")
    if cons.get("submitted") != cons.get("admitted", 0) + cons.get("shed", 0):
        problems.append(
            f"serving_http: accounting leak — admitted "
            f"{cons.get('admitted')!r} + shed {cons.get('shed')!r} != "
            f"submitted {cons.get('submitted')!r}"
        )


def main(argv: list[str]) -> int:
    path = argv[1] if len(argv) > 1 else DEFAULT_PATH
    with open(path) as f:
        payload = json.load(f)
    problems: list[str] = []
    check_model_size(payload, problems)
    check_family_compare(payload, problems)
    check_fastfood(payload, problems)
    check_runtime(payload, problems)
    check_overload(payload, problems)
    check_degraded(payload, problems)
    check_scaleout(payload, problems)
    check_observability(payload, problems)
    check_serving_http(payload, problems)
    if problems:
        print(f"[bench-invariants] {len(problems)} violation(s) in {path}:")
        for p in problems:
            print(f"  FAIL {p}")
        return 1
    print(f"[bench-invariants] OK — model_size, family_compare, fastfood, "
          f"runtime_throughput, overload, degraded_mode, scaleout, "
          f"observability and serving_http invariants hold in {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
