"""Box-constrained dual kernel-SVM trainer (LIBSVM stand-in), pure JAX.

Solves the C-SVC dual with the bias folded into the kernel (the classic
"K + 1" trick, which drops the equality constraint sum alpha_i y_i = 0):

    max_alpha  1^T alpha - 1/2 alpha^T Q alpha,   0 <= alpha <= C
    Q_ij = y_i y_j (K(x_i, x_j) + 1)

by projected gradient ascent with a Lipschitz step (1 / lambda_max(Q),
estimated by power iteration). The bias is then b = sum_i alpha_i y_i.
Converges to the same decision function family as LIBSVM's C-SVC up to the
bias-handling convention; produces genuinely sparse alpha (many exact zeros
after projection), giving the paper's n_sv < n regime.

The container has no LIBSVM and no network — this trainer is the
substrate-complete replacement (DESIGN.md §2/§9).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.rbf import SVMModel, rbf_kernel

Array = jax.Array


def _power_iter_lmax(Q: Array, iters: int = 32) -> Array:
    """Largest eigenvalue of PSD Q by power iteration (fixed iterations)."""
    n = Q.shape[0]
    v = jnp.ones((n,), Q.dtype) / jnp.sqrt(n)

    def body(v, _):
        w = Q @ v
        return w / (jnp.linalg.norm(w) + 1e-30), None

    v, _ = jax.lax.scan(body, v, None, length=iters)
    return v @ (Q @ v)


@partial(jax.jit, static_argnames=("num_steps",))
def train_svc(
    X: Array,
    y: Array,
    gamma: Array,
    C: Array,
    num_steps: int = 500,
    sv_threshold: float = 1e-6,
) -> tuple[SVMModel, Array]:
    """Train a binary C-SVC.

    Returns (model, sv_mask). The model keeps ALL rows (static shapes for
    jit); ``sv_mask`` marks alpha > sv_threshold * C. Use
    ``compress_support`` to materialize the sparse model outside jit.
    """
    n = X.shape[0]
    K = rbf_kernel(X, X, gamma) + 1.0  # bias folded into kernel
    Q = (y[:, None] * y[None, :]) * K
    lmax = _power_iter_lmax(Q)
    step = 1.0 / (lmax + 1e-12)

    def body(alpha, _):
        grad = 1.0 - Q @ alpha
        alpha = jnp.clip(alpha + step * grad, 0.0, C)
        return alpha, None

    alpha0 = jnp.zeros((n,), X.dtype)
    alpha, _ = jax.lax.scan(body, alpha0, None, length=num_steps)

    b = jnp.sum(alpha * y)  # from the K+1 trick
    sv_mask = alpha > sv_threshold * C
    # Zero out non-SVs so the dense model is numerically identical to the
    # compressed one.
    alpha = jnp.where(sv_mask, alpha, 0.0)
    model = SVMModel(X=X, alpha_y=alpha * y, b=b, gamma=jnp.asarray(gamma))
    return model, sv_mask


def compress_support(model: SVMModel, sv_mask: Array) -> SVMModel:
    """Drop non-support rows (dynamic shape — call outside jit)."""
    import numpy as np

    mask = np.asarray(sv_mask)
    return SVMModel(
        X=jnp.asarray(np.asarray(model.X)[mask]),
        alpha_y=jnp.asarray(np.asarray(model.alpha_y)[mask]),
        b=model.b,
        gamma=model.gamma,
    )
