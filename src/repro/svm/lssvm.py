"""Least-squares SVM trainer (Suykens & Vandewalle 1999), pure JAX.

LS-SVMs solve the KKT linear system

    [ 0      y^T          ] [ b     ]   [ 0 ]
    [ y   Omega + I/reg_c ] [ alpha ] = [ 1 ]

with Omega_ij = y_i y_j K(x_i, x_j).  Every training point gets a nonzero
alpha — i.e. n_sv = n_train. This is exactly the regime the paper highlights
(§3, §5): LS-SVM models are not sparse, so the Maclaurin collapse gives the
largest compression ratios.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.rbf import SVMModel, rbf_kernel

Array = jax.Array


@partial(jax.jit, static_argnames=())
def train_lssvm(X: Array, y: Array, gamma: Array, reg_c: Array) -> SVMModel:
    """Train a binary LS-SVM classifier.

    Args:
      X: (n, d) training rows.
      y: (n,) labels in {-1, +1} (float).
      gamma: RBF kernel parameter.
      reg_c: regularization constant (larger = less regularization).

    Returns:
      SVMModel with n_sv == n.
    """
    n = X.shape[0]
    K = rbf_kernel(X, X, gamma)
    omega = (y[:, None] * y[None, :]) * K
    # Dense KKT system, solved in f64-ish stability via symmetrize + jitter.
    A = jnp.zeros((n + 1, n + 1), dtype=K.dtype)
    A = A.at[0, 1:].set(y)
    A = A.at[1:, 0].set(y)
    A = A.at[1:, 1:].set(omega + jnp.eye(n, dtype=K.dtype) / reg_c)
    rhs = jnp.concatenate([jnp.zeros((1,), K.dtype), jnp.ones((n,), K.dtype)])
    sol = jnp.linalg.solve(A, rhs)
    b, alpha = sol[0], sol[1:]
    return SVMModel(X=X, alpha_y=alpha * y, b=b, gamma=jnp.asarray(gamma))
