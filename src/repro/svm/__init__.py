from repro.svm.lssvm import train_lssvm
from repro.svm.dual import train_svc
from repro.svm.multiclass import compile_ovr, train_one_vs_rest, ovr_predict

__all__ = [
    "train_lssvm",
    "train_svc",
    "train_one_vs_rest",
    "ovr_predict",
    "compile_ovr",
]
