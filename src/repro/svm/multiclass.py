"""One-vs-rest multiclass wrapper (the paper's mnist/sensit protocol).

The paper classifies "class k versus others" for mnist (class 1) and sensit
(class 3). We provide both that binary slicing and a full OvR ensemble whose
per-class models share X, so the Maclaurin collapse produces one
(c, v, M) triple per class — still O(K d^2) total, independent of n_sv.

Prediction is FUSED across heads: the K stacked Hessians are evaluated by
one backend call (one Pallas pallas_call / one XLA GEMM — not K), and the
exact OvR path shares a single kernel-matrix GEMM across all K heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.core.maclaurin import ApproxModel, approximate
from repro.core.rbf import SVMModel, rbf_kernel
from repro.svm.lssvm import train_lssvm

Array = jax.Array


def binary_labels(y_multi: Array, positive_class: int) -> Array:
    """'class k vs others' labels in {-1, +1}."""
    return jnp.where(y_multi == positive_class, 1.0, -1.0)


def train_one_vs_rest(
    X: Array, y_multi: Array, num_classes: int, gamma, reg_c
) -> SVMModel:
    """Train K binary LS-SVMs with shared X; batched over classes via vmap.

    Returns an SVMModel whose alpha_y has shape (K, n) and b shape (K,).
    """
    ys = jax.vmap(lambda k: binary_labels(y_multi, k))(jnp.arange(num_classes))
    models = jax.vmap(lambda yk: train_lssvm(X, yk, gamma, reg_c))(ys)
    # vmap stacks leaves: X (K, n, d) — dedupe the shared X.
    return SVMModel(
        X=models.X[0], alpha_y=models.alpha_y, b=models.b, gamma=models.gamma[0]
    )


@jax.jit
def ovr_scores(model: SVMModel, Z: Array) -> Array:
    """Exact per-class decision values (n, K): ONE kernel-matrix GEMM shared
    by all heads (K[i, j] is class-independent; only alpha differs)."""
    K_mat = rbf_kernel(Z, model.X, model.gamma)          # (n, n_sv), shared
    return K_mat @ model.alpha_y.T + model.b[None, :]    # (n, K)


def ovr_predict(model: SVMModel, Z: Array) -> Array:
    """argmax over per-class decision values."""
    return jnp.argmax(ovr_scores(model, Z), axis=-1)


def approximate_ovr(model: SVMModel) -> ApproxModel:
    """Collapse every class head; shares nothing but shapes (K-stacked)."""
    def one(ay, b):
        m = SVMModel(X=model.X, alpha_y=ay, b=b, gamma=model.gamma)
        return approximate(m)

    return jax.vmap(one)(model.alpha_y, model.b)


@jax.jit
def approx_ovr_scores(approx: ApproxModel, Z: Array) -> Array:
    """Fused K-head scores (n, K): one backend call for all heads."""
    scores, _, _ = backend.quadform_heads(
        Z, approx.M, approx.v, approx.c, approx.b, approx.gamma,
        approx.max_sv_sq_norm,
    )
    return scores


@jax.jit
def approx_ovr_predict(approx: ApproxModel, Z: Array) -> Array:
    return jnp.argmax(approx_ovr_scores(approx, Z), axis=-1)


def compile_ovr(model: SVMModel, family: str = "maclaurin", **opts):
    """Compile an OvR ensemble into a servable K-head artifact.

    Thin convenience over ``repro.core.families``: every family compiles
    the (K, n_sv) alpha stack of ``train_one_vs_rest`` directly (shared X,
    one artifact, fused K-head serving) — pass the artifact to
    ``SVMEngine`` or ``CompiledArtifact.save`` it for a serving process.
    """
    from repro.core import families

    return families.get_family(family).compile(model, **opts)
