"""Roofline-term derivation from the dry-run artifacts (deliverable g).

Per (arch x shape) cell on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bandwidth
    collective term = wire_bytes_per_device / ICI_link_bandwidth

cost_analysis() is already per-device (post-SPMD). Collective wire bytes
use ring-algorithm multipliers on the parsed per-device result sizes:

    all-reduce       2 (g-1)/g x bytes          (reduce-scatter + all-gather)
    all-gather       (g-1)/g x result bytes     (result = gathered buffer)
    reduce-scatter   (g-1)   x result bytes     (result = scattered shard)
    all-to-all       (g-1)/g x bytes
    collective-perm  1 x bytes

MODEL_FLOPS uses the classic estimators (6 N_active D for train,
2 N_active D for single forward) against global HLO FLOPs to expose
remat/dispatch overheads. Hardware constants per the brief (TPU v5e):
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = "results/dryrun"


def wire_bytes(collective_ops: list[dict], default_group: int = 16) -> float:
    total = 0.0
    for op in collective_ops:
        g = op.get("group_size") or default_group
        b = op.get("total_bytes", op["bytes"] * op.get("count", 1))
        k = op["kind"]
        if k == "all-reduce":
            total += 2 * (g - 1) / g * b
        elif k == "all-gather":
            total += (g - 1) / g * b
        elif k == "reduce-scatter":
            total += (g - 1) * b
        elif k == "all-to-all":
            total += (g - 1) / g * b
        else:  # collective-permute
            total += b
    return total


def model_flops(meta: dict) -> float:
    n = meta["active_params"]
    tokens = meta["global_batch"] * (
        1 if meta["kind"] == "decode" else meta["seq_len"]
    )
    mult = 6 if meta["kind"] == "train" else 2
    return mult * n * tokens


def analyze_cell(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    t_compute = rec["cost"]["flops"] / PEAK_FLOPS
    t_memory = rec["cost"]["bytes_accessed"] / HBM_BW
    wb = wire_bytes(rec.get("collective_ops", []))
    t_coll = wb / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["cost"]["flops"] * n_dev
    useful = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful work at peak vs the bounding term
    ideal = mf / n_dev / PEAK_FLOPS
    bound = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["kind"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_fraction": (ideal / bound) if bound else 0.0,
        "mem_gib_per_dev": rec["memory"]["peak_device_bytes"] / 2**30,
        "collectives": rec.get("collectives", {}),
        "rules": rec.get("rules", "default"),
    }


def load_all(mesh: str = "16x16", rules: str = "auto") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        # exact arch__shape__mesh tags only — hillclimb variants carry
        # extra __suffixes and are excluded from the headline table
        with open(path) as f:
            rec = json.load(f)
        if rec.get("rules", "default") != rules:
            continue
        if rec["mesh"] != mesh:
            continue
        out.append(analyze_cell(rec))
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL/HLO | roofline frac | mem GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['mem_gib_per_dev']:.1f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    rows = load_all()
    os.makedirs("results", exist_ok=True)
    md = to_markdown(rows)
    with open("results/roofline.md", "w") as f:
        f.write(md)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(md)
    print(f"{len(rows)} cells analyzed -> results/roofline.md")


if __name__ == "__main__":
    main()
