"""Roofline-term derivation from the dry-run artifacts (deliverable g).

Per (arch x shape) cell on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bandwidth
    collective term = wire_bytes_per_device / ICI_link_bandwidth

cost_analysis() is already per-device (post-SPMD). Collective wire bytes
use ring-algorithm multipliers on the parsed per-device result sizes:

    all-reduce       2 (g-1)/g x bytes          (reduce-scatter + all-gather)
    all-gather       (g-1)/g x result bytes     (result = gathered buffer)
    reduce-scatter   (g-1)   x result bytes     (result = scattered shard)
    all-to-all       (g-1)/g x bytes
    collective-perm  1 x bytes

MODEL_FLOPS uses the classic estimators (6 N_active D for train,
2 N_active D for single forward) against global HLO FLOPs to expose
remat/dispatch overheads. Hardware constants per the brief (TPU v5e):
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

The same three-term model doubles as the ANALYTIC PRIOR for the serving
kernels' tile search (``*_tile_seconds`` below): per candidate
``TileConfig`` the weight-streaming traffic is a closed form in the tile
shape, so the autotuner can rank candidates and measure only the
plausibly-fast ones, and ``compile_model`` can skip compiling candidates
whose predicted cost is hopeless (``family_candidate_seconds``). The
prior ranks — measurement still decides (the never-worse-than-default
guarantee lives in ``kernels.common.autotune``, which always measures
the default).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = "results/dryrun"


def wire_bytes(collective_ops: list[dict], default_group: int = 16) -> float:
    total = 0.0
    for op in collective_ops:
        g = op.get("group_size") or default_group
        b = op.get("total_bytes", op["bytes"] * op.get("count", 1))
        k = op["kind"]
        if k == "all-reduce":
            total += 2 * (g - 1) / g * b
        elif k == "all-gather":
            total += (g - 1) / g * b
        elif k == "reduce-scatter":
            total += (g - 1) * b
        elif k == "all-to-all":
            total += (g - 1) / g * b
        else:  # collective-permute
            total += b
    return total


def predict_seconds(flops: float, bytes_accessed: float, wire: float = 0.0) -> float:
    """Roofline lower bound for one kernel invocation: the binding term."""
    t = max(flops / PEAK_FLOPS, bytes_accessed / HBM_BW)
    if wire:
        t = max(t, wire / ICI_BW)
    return t


def _row_blocks(n: int, block_n) -> int:
    """How many row tiles a batch of ``n`` splits into under ``block_n``."""
    n = max(1, int(n))
    b = int(block_n) if block_n else n
    b = max(1, min(b, n))
    return -(-n // b)


def quadform_tile_seconds(cfg, *, n: int, d: int, k: int,
                          weight_bytes: int = 4) -> float:
    """Analytic cost of one fused quadform step (Eq 3.8, all K heads).

    The (K, d, d) stacked Hessian is re-streamed once per row tile —
    the term that actually moves with ``block_n`` (bigger tiles amortize
    the weight traffic; FLOPs are tile-invariant). ``weight_bytes=1``
    models the int8 variants.
    """
    blocks = _row_blocks(n, getattr(cfg, "block_n", None) if cfg else None)
    flops = 2.0 * n * k * d * (d + 1)
    stream = float(blocks) * k * d * d * weight_bytes
    io = 4.0 * (n * d + n * k) + float(weight_bytes) * k * d
    return predict_seconds(flops, stream + io)


def rbf_tile_seconds(cfg, *, n: int, d: int, m: int) -> float:
    """Analytic cost of the exact streaming ``rbf_pred`` path (m SVs)."""
    blocks = _row_blocks(n, getattr(cfg, "block_n", None) if cfg else None)
    flops = 2.0 * n * m * d
    stream = float(blocks) * m * d * 4.0
    io = 4.0 * (n * d + n)
    return predict_seconds(flops, stream + io)


def rff_tile_seconds(cfg, *, n: int, d: int, f: int, k: int,
                     weight_bytes: int = 4) -> float:
    """Analytic cost of the fused RFF step (projection + readout GEMMs)."""
    blocks = _row_blocks(n, getattr(cfg, "block_n", None) if cfg else None)
    flops = 2.0 * n * f * (d + k)
    stream = float(blocks) * (f * d + k * f) * float(weight_bytes)
    io = 4.0 * (n * d + n * k)
    return predict_seconds(flops, stream + io)


def fwht_tile_seconds(cfg, *, n: int, d: int, f: int, k: int,
                      weight_bytes: int = 4) -> float:
    """Analytic cost of the fused Fastfood step (FWHT stacks + readout).

    Per row: each of the ``stacks`` = F / d' stacks runs two d'-wide
    Walsh-Hadamard transforms (log2(d') add stages each) plus the three
    diagonal multiplies and permutation — ~2 d' (log2 d' + 2) FLOPs per
    stack, i.e. O(F log d') in place of the dense path's O(F d) — then
    the same 2 F K readout GEMM as dense RFF. Streamed weights are the
    O(F) diagonals (4 arrays of F elements at ``weight_bytes``, plus the
    f32 phase) and the (K, F) readout, re-streamed once per row tile;
    ``weight_bytes=1`` models the int8 variant. The structured prior
    undercuts ``rff_tile_seconds`` wherever log2(d') << d — the
    compile-search ranking the paper's loglinear claim turns into.
    """
    blocks = _row_blocks(n, getattr(cfg, "block_n", None) if cfg else None)
    dd = 1 << max(1, (d - 1).bit_length())                 # next pow2 >= d
    stacks = -(-int(f) // dd)
    fp = stacks * dd                                       # F rounded to stacks
    log_dd = max(1, dd.bit_length() - 1)
    flops = float(n) * (2.0 * stacks * dd * (log_dd + 2) + 2.0 * fp * k)
    stream = float(blocks) * (
        fp * (3.0 * weight_bytes + 4.0)                    # B/G/S diagonals + phase
        + k * fp * weight_bytes                            # readout
    )
    io = 4.0 * (n * d + n * k)
    return predict_seconds(flops, stream + io)


def family_candidate_seconds(
    family: str, dtype: str, *, n: int, d: int, k: int,
    num_features: int | None = None, structured: bool = False, cfg=None,
) -> float | None:
    """Predicted serving seconds for one ``compile_model`` candidate.

    Returns ``None`` for families without an analytic model — the caller
    must then measure (never prune on ignorance).
    """
    wb = 1 if dtype == "int8" else 4
    if family in ("maclaurin", "poly2"):
        return quadform_tile_seconds(cfg, n=n, d=d, k=k, weight_bytes=wb)
    if family == "fourier":
        f = int(num_features) if num_features else 1024  # fourier default
        if structured:
            return fwht_tile_seconds(cfg, n=n, d=d, f=f, k=k, weight_bytes=wb)
        return rff_tile_seconds(cfg, n=n, d=d, f=f, k=k, weight_bytes=wb)
    return None


def model_flops(meta: dict) -> float:
    n = meta["active_params"]
    tokens = meta["global_batch"] * (
        1 if meta["kind"] == "decode" else meta["seq_len"]
    )
    mult = 6 if meta["kind"] == "train" else 2
    return mult * n * tokens


def analyze_cell(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    t_compute = rec["cost"]["flops"] / PEAK_FLOPS
    t_memory = rec["cost"]["bytes_accessed"] / HBM_BW
    wb = wire_bytes(rec.get("collective_ops", []))
    t_coll = wb / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["cost"]["flops"] * n_dev
    useful = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful work at peak vs the bounding term
    ideal = mf / n_dev / PEAK_FLOPS
    bound = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["kind"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_fraction": (ideal / bound) if bound else 0.0,
        "mem_gib_per_dev": rec["memory"]["peak_device_bytes"] / 2**30,
        "collectives": rec.get("collectives", {}),
        "rules": rec.get("rules", "default"),
    }


def load_all(mesh: str = "16x16", rules: str = "auto") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        # exact arch__shape__mesh tags only — hillclimb variants carry
        # extra __suffixes and are excluded from the headline table
        with open(path) as f:
            rec = json.load(f)
        if rec.get("rules", "default") != rules:
            continue
        if rec["mesh"] != mesh:
            continue
        out.append(analyze_cell(rec))
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL/HLO | roofline frac | mem GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['mem_gib_per_dev']:.1f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    rows = load_all()
    os.makedirs("results", exist_ok=True)
    md = to_markdown(rows)
    with open("results/roofline.md", "w") as f:
        f.write(md)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(md)
    print(f"{len(rows)} cells analyzed -> results/roofline.md")


if __name__ == "__main__":
    main()
