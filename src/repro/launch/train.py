"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Wires together: config -> init (or restore) -> sharded train_step ->
step-resumable data pipeline -> async checkpointing -> watchdog. On real
hardware the same script runs under multi-host jax.distributed; on this
container it runs single-device (meshless) or on a fake mesh for tests.

Fault-tolerance drill (--simulate-failure N): the process "loses a node" at
step N — the launcher saves nothing special, exits, and a restart with the
same flags resumes from the last committed async checkpoint, replaying the
data stream from the restored step. See examples/elastic_restart.py for the
remesh-on-shrink variant.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.loader import lm_token_batches
from repro.models.transformer import init_params
from repro.train import checkpoint as ckpt
from repro.train.train_step import OptimizerConfig, init_opt_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    ocfg = OptimizerConfig(
        peak_lr=args.lr, warmup=max(5, args.steps // 20), total_steps=args.steps,
        microbatches=args.microbatches, compress_grads=args.compress_grads,
    )

    start_step = 0
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(ocfg, params)
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.ckpt_dir and (last := ckpt.latest_step(args.ckpt_dir)) is not None:
        state = ckpt.restore(args.ckpt_dir, last, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = last + 1
        print(f"[train] resumed from step {last}", flush=True)

    step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    make_batch = lm_token_batches(cfg.vocab_size, args.batch, args.seq, seed=42)

    t_last, tok_per_step = time.time(), args.batch * args.seq
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch, jnp.int32(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t_last
            t_last = time.time()
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({args.log_every * tok_per_step / max(dt, 1e-9):.0f} tok/s)",
                  flush=True)
        if saver and step > 0 and step % args.ckpt_every == 0:
            saver.save(step, {"params": params, "opt": opt_state})
        if args.simulate_failure is not None and step == args.simulate_failure:
            print(f"[train] SIMULATED NODE FAILURE at step {step} — dying "
                  f"uncleanly (restart me to resume)", flush=True)
            sys.exit(42)
    if saver:
        saver.save(args.steps - 1, {"params": params, "opt": opt_state})
        saver.wait()
    print("[train] done", flush=True)


if __name__ == "__main__":
    main()
