import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import in the process (XLA locks the device count on
first jax init) — hence the os.environ lines above everything else.

Per cell, records to results/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis()   — per-device argument/output/temp bytes (fits check)
  * cost_analysis()     — per-device HLO FLOPs + bytes accessed
  * collective ops      — parsed from the post-SPMD HLO text: op kind,
    result shape bytes, replica-group size (for link-traffic modelling)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 x 2 cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --multi-pod
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.sharding.partitioning import (
    DEFAULT_RULES, DP_ONLY_RULES, EP_DATA_RULES, EP_DP_RULES, SP_RULES,
    TP_ONLY_RULES,
)

RESULTS_DIR = "results/dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> list[dict]:
    """Extract collective ops: kind, per-device result bytes, group size."""
    out = []
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue  # async pairs: count the -start only
        m = _COLL_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            nbytes = _shape_bytes(dtype, dims)
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if not mt:
                continue
            parts, kind = mt.groups()
            nbytes = 0
            for p in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", parts):
                nbytes += _shape_bytes(*p.groups())
        gm = _GROUPS_RE.search(line)
        group_size = int(gm.group(2)) if gm else None
        out.append({"kind": kind, "bytes": nbytes, "group_size": group_size})
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, rules_name: str = "auto",
             force: bool = False, reanalyze: bool = False,
             microbatches: int | None = None, backend: str | None = None,
             scores_bf16: bool = False, kv_int8: bool = False) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if rules_name != "auto":
        tag += f"__{rules_name}"
    if microbatches is not None:
        tag += f"__mb{microbatches}"
    if backend:
        tag += f"__{backend}"
    if scores_bf16:
        tag += "__sbf16"
    if kv_int8:
        tag += "__kvint8"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, tag + ".json")
    hlo_path = os.path.join(RESULTS_DIR, tag + ".hlo.gz")
    if os.path.exists(path) and not (force or reanalyze):
        with open(path) as f:
            return json.load(f)
    if reanalyze and os.path.exists(path) and os.path.exists(hlo_path):
        # recompute the cost model from the stored HLO — no recompile
        import gzip

        with open(path) as f:
            result = json.load(f)
        with gzip.open(hlo_path, "rt") as f:
            text = f.read()
        result = _attach_costs(result, text, keep_xla=True)
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        return result

    cfg = ARCHS[arch]
    if backend:
        cfg = cfg.with_backend(backend)
    if scores_bf16:
        import dataclasses as _dc2

        cfg = _dc2.replace(cfg, attn_scores_dtype="bfloat16")
    if kv_int8:
        import dataclasses as _dc3

        cfg = _dc3.replace(cfg, kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    rules = {
        "auto": None,
        "default": DEFAULT_RULES,
        "tp_only": TP_ONLY_RULES,
        "dp_only": DP_ONLY_RULES,
        "ep_data": EP_DATA_RULES,
        "ep_dp": EP_DP_RULES,
        "sp": SP_RULES,
    }[rules_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    ocfg = None
    if microbatches is not None:
        from repro.launch.specs import choose_optimizer
        import dataclasses as _dc

        ocfg = _dc.replace(choose_optimizer(cfg, shape), microbatches=microbatches)
    cell = build_cell(cfg, shape, mesh, rules, ocfg=ocfg)
    from repro.sharding.hints import use_hints
    from repro.launch.specs import choose_rules

    active_rules = choose_rules(cell_cfg_for_rules(cfg, shape), shape, rules)
    with mesh, use_hints(mesh, active_rules):
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
        from repro.launch.hlo_cost import normalize_cost_analysis

        ma = compiled.memory_analysis()
        ca = normalize_cost_analysis(compiled.cost_analysis())
        text = compiled.as_text()
    import gzip

    with gzip.open(hlo_path, "wt") as f:
        f.write(text)
    result = {
        **cell.meta,
        "mesh": mesh_name,
        "rules": rules_name,
        "n_devices": mesh.size,
        "compile_seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_device_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "xla_cost": {
            "flops_body_once": ca.get("flops", 0.0),
            "bytes_body_once": ca.get("bytes accessed", 0.0),
        },
    }
    result = _attach_costs(result, text)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def cell_cfg_for_rules(cfg, shape):
    from repro.launch.specs import pick_backend

    return pick_backend(cfg, shape)


def _attach_costs(result: dict, text: str, keep_xla: bool = False) -> dict:
    """Trip-count-aware cost model (XLA's cost_analysis counts while bodies
    once — ~60x off for scanned stacks; see launch/hlo_cost.py)."""
    from repro.launch.hlo_cost import analyze_text

    hc = analyze_text(text)
    result["cost"] = {
        "flops": hc["flops"],
        "bytes_accessed": hc["bytes_accessed"],
    }
    result["collectives"] = hc["collectives"]
    result["collective_ops"] = hc["collective_ops"]
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rules", default="auto",
                    choices=["auto", "default", "tp_only", "dp_only", "ep_data", "ep_dp", "sp"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute costs from stored HLO, no recompile")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--backend", default=None, choices=[None, "maclaurin", "softmax"])
    ap.add_argument("--scores-bf16", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch:24s} {shape:12s} {'2x16x16' if mp else '16x16':8s}"
                try:
                    r = run_cell(arch, shape, mp, args.rules, args.force, args.reanalyze,
                                 args.microbatches, args.backend, args.scores_bf16,
                                 args.kv_int8)
                    mem_gb = r["memory"]["peak_device_bytes"] / 2**30
                    print(
                        f"OK   {label} flops/dev={r['cost']['flops']:.3e} "
                        f"mem/dev={mem_gb:.2f}GiB colls={sum(v['count'] for v in r['collectives'].values())} "
                        f"({r['compile_seconds']}s)",
                        flush=True,
                    )
                    n_ok += 1
                except Exception:
                    print(f"FAIL {label}", flush=True)
                    traceback.print_exc()
                    n_fail += 1
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
