"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run owns XLA_FLAGS and device counts).

Topology (TPU v5e pods): 256 chips/pod as a (16, 16) (data, model) mesh;
multi-pod adds a leading "pod" axis over DCN. The "model" axis is the
fast-ICI dimension (TP/EP collectives); "data"+"pod" carry gradient
reduction, hierarchically: reduce-scatter over ICI inside the pod, then a
cross-pod all-reduce of the scattered shards over DCN.
"""

from __future__ import annotations

import jax


def _make(shape: tuple[int, ...], axes: tuple[str, ...]):
    # axis_types only exists on jax >= 0.5 (and Auto is its default there);
    # 0.4.x make_mesh takes no such kwarg.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic-remesh path and tests)."""
    return _make(shape, axes)
