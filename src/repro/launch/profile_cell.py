"""Per-cell HLO profile: where the flops/bytes/collective terms come from.

    PYTHONPATH=src python -m repro.launch.profile_cell <cell-tag>

Reads results/dryrun/<tag>.hlo.gz and prints the top contributors by op kind
and by tensor shape, trip-count weighted — the 'profiler' of the dry-run
perf loop (there is no wall-clock on this container; this is the structural
profile the §Perf methodology iterates on).
"""

from __future__ import annotations

import gzip
import sys
from collections import defaultdict

from repro.launch.hlo_cost import (
    CostModel, _CALLS_RE, _TRIP_RE, _COLLECTIVES, _MATERIALIZING,
)


def profile(text: str):
    cm = CostModel(text)
    flops_by = defaultdict(float)
    bytes_by = defaultdict(float)
    bytes_by_shape = defaultdict(float)
    flops_by_shape = defaultdict(float)
    coll_by = defaultdict(float)

    def walk(comp_name: str, mult: float, seen):
        comp = cm.comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        seen = seen | {comp_name}
        for op in comp.ops:
            kind = op.kind.replace("-start", "")
            if kind == "while":
                tm = _TRIP_RE.search(op.rest)
                trips = int(tm.group(1)) if tm else 1
                body = _CALLS_RE.search(op.rest)
                if body:
                    walk(body.group(1), mult * trips, seen)
                continue
            if kind in ("call", "conditional"):
                for ref in _CALLS_RE.findall(op.rest):
                    walk(ref, mult, seen)
                continue
            if kind == "fusion":
                body = _CALLS_RE.search(op.rest)
                if body:
                    walk(body.group(1), mult, seen)
                continue
            if kind == "dot":
                f = cm._dot_flops(op) * mult
                flops_by["dot"] += f
                flops_by_shape[op.result_type.split("{")[0]] += f
            if kind in _COLLECTIVES:
                c = cm._collective(op)
                coll_by[f"{kind} g={c['group_size']}"] += c["bytes"] * mult
            if kind in _MATERIALIZING:
                b = cm._op_bytes(op) * mult
                bytes_by[kind] += b
                bytes_by_shape[op.result_type.split("{")[0]] += b

    walk(cm.entry, 1.0, frozenset())
    return flops_by, bytes_by, bytes_by_shape, flops_by_shape, coll_by


def main():
    tag = sys.argv[1]
    with gzip.open(f"results/dryrun/{tag}.hlo.gz", "rt") as f:
        text = f.read()
    fb, bb, bbs, fbs, cb = profile(text)
    print(f"== {tag}")
    print("-- bytes by op kind (GB):")
    for k, v in sorted(bb.items(), key=lambda kv: -kv[1])[:8]:
        print(f"   {k:24s} {v/1e9:10.2f}")
    print("-- bytes by result shape (GB):")
    for k, v in sorted(bbs.items(), key=lambda kv: -kv[1])[:10]:
        print(f"   {k:44s} {v/1e9:10.2f}")
    print("-- dot flops by result shape (GFLOP):")
    for k, v in sorted(fbs.items(), key=lambda kv: -kv[1])[:10]:
        print(f"   {k:44s} {v/1e9:10.2f}")
    print("-- collective bytes by kind/group (GB):")
    for k, v in sorted(cb.items(), key=lambda kv: -kv[1])[:8]:
        print(f"   {k:24s} {v/1e9:10.2f}")


if __name__ == "__main__":
    main()
