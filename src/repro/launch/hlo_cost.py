"""Trip-count-aware cost model over post-optimization HLO text.

XLA's HloCostAnalysis counts a while-loop body ONCE, but jax lowers
lax.scan to while — so for a 60-layer scanned transformer the built-in
cost_analysis() under-reports FLOPs/bytes/collectives by ~60x (verified
empirically; see EXPERIMENTS.md §Dry-run notes). This module re-derives

    flops              dots (2*M*N*K) + elementwise/transcendental (1/elem)
    hbm bytes          operand+result sizes of materializing top-level ops
                       (fusion boundaries = buffer materialization points)
    collective ops     (kind, result bytes, replica-group size) x multiplier

by walking the HLO call graph and MULTIPLYING while bodies by their trip
counts (parsed from the loop-condition constant). Costs are per-device —
the text is the post-SPMD module.

This is a deliberately simple model: bitcasts/reshapes/tuples are free,
fusions count their operands+outputs as HBM traffic and their interior
elementwise work as flops. Good to ~10-20% vs the built-in analysis on
loop-free programs (tested in tests/test_hlo_cost.py).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field


def normalize_cost_analysis(ca) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returned a per-device LIST of properties dicts (sometimes
    empty), current jax returns the dict directly; ``None`` shows up on
    backends without a cost model. Callers always want one flat dict —
    ``{}`` when nothing is available — so indexing like ``ca["flops"]``
    never dies with "list indices must be integers".
    """
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_ELEMENTWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "and",
    "or", "xor", "negate", "abs", "compare", "select", "clamp", "floor",
    "ceil", "round-nearest-afz", "sign", "not",
}
_TRANSCENDENTAL = {
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "logistic",
    "sine", "cosine", "exponential-minus-one", "log-plus-one", "atan2",
    "erf", "cbrt",
}
# HBM-traffic model, two tiers (EXPERIMENTS.md §Dry-run notes):
#
# _MATERIALIZING ("perfect-fusion" / dot-centric, the headline number):
#   tensors crossing compute/reorder/collective boundaries — dot operands
#   and results, cache updates, gathers/scatters, sorts, collectives. This
#   approximates a well-fused TPU program where elementwise chains stay in
#   VMEM/registers. Top-level convert/copy/broadcast and *fusion outputs*
#   are excluded: on this CPU backend they are bf16-normalization and
#   fusion-granularity artifacts (measured 10-50x inflation vs TPU-plausible
#   traffic when included).
# _MATERIALIZING_UPPER adds fusion-boundary I/O — a conservative upper
#   bound reported alongside as bytes_upper.
_MATERIALIZING = {
    "dot", "dynamic-update-slice", "dynamic-slice",
    "convolution", "gather", "scatter", "reduce", "sort",
    "concatenate", "pad", "reduce-window",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "rng", "select-and-scatter",
    "cholesky", "triangular-solve",
}
_MATERIALIZING_UPPER = _MATERIALIZING | {"fusion"}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# first lowercase word( in the RHS is the op kind; everything before it is
# the (possibly tuple, possibly /*index=N*/-commented) result type
_KIND_RE = re.compile(r"(?:^|[\s/])([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shapes(type_str: str) -> list[tuple[str, str]]:
    return _SHAPE_RE.findall(type_str)


def _type_bytes(type_str: str) -> int:
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
        for dt, dims in _first_shapes(type_str)
    )


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    rest: str  # args + attributes, raw


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for line in text.splitlines():
        if line.rstrip().endswith("{") and "->" in line and ("%" in line or line.lstrip().startswith("ENTRY")):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                current = Computation(m.group(1))
                comps[current.name] = current
                continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _ASSIGN_RE.match(line)
        if m:
            name, rhs = m.groups()
            km = _KIND_RE.search(rhs)
            if not km:
                continue
            kind = km.group(1)
            rtype = rhs[: km.start()].strip()
            rest = rhs[km.end():]
            current.ops.append(Op(name, kind, rtype, rest))
    return comps


class CostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        # symbol table: op name -> result type (for operand byte lookup)
        self.types: dict[str, str] = {}
        self.consts: dict[str, int] = {}
        for comp in self.comps.values():
            for op in comp.ops:
                self.types[op.name] = op.result_type
                if op.kind == "constant" and op.result_type.startswith("s32[]"):
                    cm = re.match(r"(\d+)", op.rest)
                    if cm:
                        self.consts[op.name] = int(cm.group(1))
        self._memo: dict[str, tuple[float, float, list]] = {}
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        return m.group(1) if m else next(iter(self.comps))

    # ------------------------------------------------------------- pieces

    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if not comp:
            return 1
        best = 1
        for op in comp.ops:
            if op.kind == "compare":
                for ref in _OPERAND_RE.findall(op.rest):
                    if ref in self.consts:
                        best = max(best, self.consts[ref])
            if op.kind == "constant" and op.result_type.startswith("s32[]"):
                cm = re.match(r"(\d+)", op.rest)
                if cm:
                    best = max(best, int(cm.group(1)))
        return best

    def _dot_flops(self, op: Op) -> float:
        out_elems = sum(_shape_elems(d) for _, d in _first_shapes(op.result_type))
        m = _LHS_CONTRACT_RE.search(op.rest)
        k = 1
        if m:
            # lhs operand type = first shape among the args
            args_part = op.rest.split("),")[0]
            lhs_ref = _OPERAND_RE.search(args_part)
            if lhs_ref and lhs_ref.group(1) in self.types:
                lhs_shapes = _first_shapes(self.types[lhs_ref.group(1)])
                if lhs_shapes:
                    dims = [int(x) for x in lhs_shapes[0][1].split(",") if x]
                    for idx in m.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            k *= dims[int(idx)]
        return 2.0 * out_elems * k

    def _op_bytes(self, op: Op) -> float:
        # In-place/indexed ops: count only the data actually moved, not the
        # whole buffer — XLA aliases DUS in place (we donate caches), and a
        # gather reads |result| rows, not the table. Without this the scan
        # plumbing of a 60-layer KV cache shows up as 2.5 TB/step (measured).
        kind = op.kind
        if kind in ("dynamic-slice", "gather"):
            return float(_type_bytes(op.result_type))
        if kind in ("dynamic-update-slice", "scatter"):
            ops_ = _OPERAND_RE.findall(
                op.rest.split(", calls=")[0].split(", body=")[0]
            )
            if len(ops_) >= 2 and ops_[1] in self.types:
                return 2.0 * _type_bytes(self.types[ops_[1]])  # read+write slot
            return float(_type_bytes(op.result_type))
        total = _type_bytes(op.result_type)
        # operands: look up each referenced symbol once
        for ref in _OPERAND_RE.findall(op.rest.split(", calls=")[0].split(", body=")[0]):
            if ref in self.types:
                total += _type_bytes(self.types[ref])
        return float(total)

    def _collective(self, op: Op) -> dict:
        nbytes = _type_bytes(op.result_type)
        gm = _GROUPS_RE.search(op.rest)
        if gm:
            group = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(op.rest)
            group = len(gb.group(1).split(",")) if gb else None
        return {"kind": op.kind.replace("-start", ""), "bytes": nbytes, "group_size": group}

    # ------------------------------------------------------------- walk

    def cost(self, comp_name: str | None = None) -> tuple[float, float, float, list]:
        """Returns (flops, hbm_bytes, hbm_bytes_upper, collectives list) for
        a computation, while bodies multiplied by trip count."""
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return (0.0, 0.0, 0.0, [])
        flops = 0.0
        bytes_ = 0.0
        bytes_up = 0.0
        colls: list[dict] = []
        self._memo[comp_name] = (0.0, 0.0, 0.0, [])  # cycle guard
        for op in comp.ops:
            kind = op.kind.replace("-start", "")
            if kind == "while":
                body = _CALLS_RE.search(op.rest)
                tm = _TRIP_RE.search(op.rest)  # XLA annotates known trip counts
                if tm:
                    trips = int(tm.group(1))
                else:
                    cond = _COND_RE.search(op.rest)
                    trips = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    bf, bb, bu, bc = self.cost(body.group(1))
                    flops += trips * bf
                    bytes_ += trips * bb
                    bytes_up += trips * bu
                    for c in bc:
                        colls.append({**c, "count_mult": trips * c.get("count_mult", 1)})
                continue
            if kind in ("call", "conditional"):
                for ref in _CALLS_RE.findall(op.rest):
                    cf, cb, cu, cc = self.cost(ref)
                    flops += cf
                    bytes_ += cb
                    bytes_up += cu
                    colls.extend(cc)
                continue
            if kind == "fusion":
                body = _CALLS_RE.search(op.rest)
                if body:
                    cf, cb, cu, cc = self.cost(body.group(1))
                    flops += cf           # interior arithmetic
                    bytes_ += cb          # dots/gathers inside the fusion
                    bytes_up += cu
                    colls.extend(cc)
                bytes_up += self._op_bytes(op)  # fusion-boundary I/O (upper tier)
                continue
            if kind in _COLLECTIVES:
                colls.append(self._collective(op))
                bytes_ += self._op_bytes(op)
                bytes_up += self._op_bytes(op)
                continue
            if kind == "dot":
                flops += self._dot_flops(op)
                bytes_ += self._op_bytes(op)
                bytes_up += self._op_bytes(op)
                continue
            if kind == "convolution":
                # rough: 2 * out_elems * (kernel window size); fall back to bytes
                flops += 2.0 * _type_bytes(op.result_type)
                bytes_ += self._op_bytes(op)
                bytes_up += self._op_bytes(op)
                continue
            elems = sum(_shape_elems(d) for _, d in _first_shapes(op.result_type))
            if kind in _TRANSCENDENTAL:
                flops += 4.0 * elems  # transcendental weight
            elif kind in _ELEMENTWISE_1 or kind in ("reduce", "reduce-window"):
                flops += float(elems)
            if kind in _MATERIALIZING:
                bytes_ += self._op_bytes(op)
            if kind in _MATERIALIZING_UPPER:
                bytes_up += self._op_bytes(op)
        result = (flops, bytes_, bytes_up, colls)
        self._memo[comp_name] = result
        return result


def analyze_text(text: str) -> dict:
    cm = CostModel(text)
    flops, bytes_, bytes_up, colls = cm.cost()
    expanded = []
    for c in colls:
        mult = c.pop("count_mult", 1)
        expanded.append({**c, "count": mult, "total_bytes": c["bytes"] * mult})
    agg: dict[str, dict] = {}
    for c in expanded:
        a = agg.setdefault(c["kind"], {"count": 0, "bytes": 0.0})
        a["count"] += c["count"]
        a["bytes"] += c["total_bytes"]
    return {
        "flops": flops,
        "bytes_accessed": bytes_,
        "bytes_upper": bytes_up,
        "collective_ops": expanded,
        "collectives": agg,
    }
