"""ShapeDtypeStruct input specs + sharding assembly for every dry-run cell.

``input_specs(cfg, shape)`` returns (args, in_shardings, step_fn, out_shardings)
builders used by launch/dryrun.py — no device allocation anywhere
(everything is jax.eval_shape + NamedSharding).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import cache_spec, init_cache, init_params
from repro.serve.decode_step import make_prefill_step, make_serve_step
from repro.sharding.partitioning import (
    AxisRules,
    DEFAULT_RULES,
    batch_pspec,
    param_shardings,
    spec_to_pspec,
    _is_spec_leaf,
)
from repro.train.train_step import OptimizerConfig, init_opt_state, make_train_step

SDS = jax.ShapeDtypeStruct


# ----------------------------------------------------------------- helpers


def pick_backend(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k: substitute the paper's Maclaurin attention for every arch
    that has attention (full softmax at 500k would be quadratic — DESIGN.md
    §7); rwkv6 runs its native O(d) recurrence."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        return cfg.with_backend("maclaurin")
    return cfg


def choose_optimizer(cfg: ModelConfig, shape: ShapeConfig | None = None,
                     dp_ways: int = 16) -> OptimizerConfig:
    """Adafactor for the 480B-class (HBM napkin math in DESIGN.md §6) and
    enough gradient-accumulation microbatches that the per-layer activation
    stash fits the 16 GB v5e budget.

    Stash estimate (remat saves the residual stream per scanned layer):
        L x (global_tokens / data_ways) x d_model x 2 bytes
    target <= ~5 GB/device => microbatches = next_pow2(stash / 5GB).
    """
    name = "adafactor" if cfg.param_count() > 100e9 else "adamw"
    mb = 1
    if shape is not None and shape.kind == "train":
        local_tokens = shape.global_batch * shape.seq_len / dp_ways
        stash = cfg.n_layers * local_tokens * cfg.d_model * 2
        target = 5e9
        # per-microbatch batch must stay divisible by the dp axes, or GSPMD
        # replicates it (measured 162 GiB/dev on llama-vision before this)
        mb_cap = max(1, shape.global_batch // dp_ways)
        while mb < mb_cap and stash / mb > target:
            mb *= 2
    return OptimizerConfig(name=name, microbatches=mb)


def choose_rules(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules | None) -> AxisRules:
    """Auto rule selection (tuned by the §Perf hillclimb; overridable):

    train, small (<1B)  -> DP_ONLY: replicate weights, batch over the whole
                           mesh. Models this size can't divide the model
                           axis (9 heads vs 16) — TP left attention sharded
                           only 16/256 ways (measured 16x roofline-fraction
                           win on smollm train_4k).
    train, MoE          -> EP_DATA: experts fully sharded (experts x data,
                           expert-ffn x model), tokens all-to-all; removes
                           per-layer-per-microbatch expert-weight gathers
                           (measured -34% collective on arctic train_4k).
    train, dense        -> DEFAULT (TP + FSDP/ZeRO-3 over data).
    serve               -> TP_ONLY when bf16 weights fit per-device under
                           pure TP (no optimizer state at inference, so
                           FSDP's per-layer all-gathers are pure overhead —
                           measured 85x collective-term win on yi-34b
                           decode_32k); DEFAULT (2D weights) for the 100B+
                           models where TP alone cannot hold the weights.
    """
    from repro.sharding.partitioning import (
        DP_ONLY_RULES, EP_DATA_RULES, TP_ONLY_RULES,
    )

    if rules is not None:
        return rules
    if shape.kind == "train":
        if cfg.param_count() <= 1e9 and cfg.family in ("dense", "audio"):
            return DP_ONLY_RULES
        # EP-over-data pays only when expert weights dwarf the tokens being
        # moved (arctic: 35M-element experts -> -34% collective; qwen3's
        # 1.6M-element experts measured WORSE under it, see §Perf)
        if cfg.moe_num_experts and cfg.moe_d_ff * cfg.d_model >= 10e6:
            return EP_DATA_RULES
        return DEFAULT_RULES
    # serving holds bf16 weights (2 bytes) — budget ~10 GB of the 16 GB HBM
    # for TP-resident weights before falling back to 2D sharding
    tp_bytes = cfg.param_count() * 2 / 16
    return TP_ONLY_RULES if tp_bytes <= 10e9 else DEFAULT_RULES


def sanitize(sharding_tree, shape_tree, mesh: Mesh):
    """Drop sharding on any dim not divisible by its mesh extent (GSPMD would
    pad; explicit in_shardings must divide evenly)."""

    def fix(sh: NamedSharding, sds):
        spec = list(sh.spec) + [None] * (len(sds.shape) - len(sh.spec))
        out = []
        for dim, s in zip(sds.shape, spec):
            if s is None:
                out.append(None)
                continue
            axes = (s,) if isinstance(s, str) else tuple(s)
            extent = math.prod(mesh.shape[a] for a in axes)
            out.append(s if dim % extent == 0 else None)
        return NamedSharding(mesh, PartitionSpec(*out))

    return jax.tree.map(fix, sharding_tree, shape_tree)


def _opt_spec_tree(ocfg: OptimizerConfig, param_spec, param_sds):
    """Logical spec tree for the optimizer state, mirroring init_opt_state."""
    scalar = ()
    if ocfg.name == "adafactor":
        def leaf(s, p):
            s = tuple(s) + (None,) * (len(p.shape) - len(s))
            if len(p.shape) >= 2:
                return {"vr": s[:-1], "vc": s[:-2] + s[-1:]}
            return {"v": s}

        v = jax.tree.map(leaf, param_spec, param_sds, is_leaf=_is_spec_leaf)
        state = {"v": v, "count": scalar}
    else:
        state = {"m": param_spec, "v": param_spec, "count": scalar}
    if ocfg.compress_grads:
        state["ef"] = param_spec
    return state


# ----------------------------------------------------------------- cell spec


@dataclasses.dataclass
class CellSpec:
    """Everything dryrun needs to lower one (arch x shape) cell."""

    step_fn: Any
    args: tuple            # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    meta: dict
    donate_argnums: tuple = ()


def build_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    rules: AxisRules | None = None,
    ocfg: OptimizerConfig | None = None,
) -> CellSpec:
    cfg = pick_backend(cfg, shape)
    rules = choose_rules(cfg, shape, rules)
    dp_ways = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    ocfg = ocfg or choose_optimizer(cfg, shape, dp_ways=dp_ways)
    key = jax.random.PRNGKey(0)

    # eval_shape can't return the (string-leaved) spec tree; capture it via
    # closure side-channel — the tracer runs the builder exactly once.
    spec_box = {}

    def _build(k):
        p, s = init_params(cfg, k)
        spec_box["spec"] = s
        return p

    params_sds = jax.eval_shape(_build, key)
    spec = spec_box["spec"]
    if shape.kind != "train":
        # serving weights are bf16-resident (the model casts to cfg.dtype
        # internally anyway; f32 masters live only in the training job)
        params_sds = jax.tree.map(
            lambda s: SDS(s.shape, jnp.bfloat16)
            if jnp.issubdtype(s.dtype, jnp.floating) else s,
            params_sds,
        )
    p_sh = sanitize(param_shardings(spec, rules, mesh), params_sds, mesh)
    bspec = batch_pspec(mesh, rules)
    GB, T = shape.global_batch, shape.seq_len
    data_ways = math.prod(
        mesh.shape[a]
        for a in (bspec[0] if isinstance(bspec[0], tuple) else (bspec[0],))
        if a is not None
    )
    bsh = NamedSharding(mesh, bspec if GB % max(data_ways, 1) == 0 else PartitionSpec(None))
    repl = NamedSharding(mesh, PartitionSpec())

    vlm = cfg.family == "vlm"
    img_sds = (
        SDS((GB, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16) if vlm else None
    )

    if shape.kind == "train":
        opt_sds = jax.eval_shape(lambda p: init_opt_state(ocfg, p), params_sds)
        o_spec = _opt_spec_tree(ocfg, spec, params_sds)
        o_sh = sanitize(
            jax.tree.map(
                lambda s: NamedSharding(mesh, spec_to_pspec(s, rules, mesh)),
                o_spec,
                is_leaf=_is_spec_leaf,
            ),
            opt_sds,
            mesh,
        )
        batch = {
            "tokens": SDS((GB, T), jnp.int32),
            "labels": SDS((GB, T), jnp.int32),
        }
        b_sh = {"tokens": bsh, "labels": bsh}
        if vlm:
            batch["image_embeds"] = img_sds
            b_sh["image_embeds"] = bsh
        step_fn = make_train_step(cfg, ocfg)
        args = (params_sds, opt_sds, batch, SDS((), jnp.int32))
        in_sh = (p_sh, o_sh, b_sh, repl)
        out_sh = (p_sh, o_sh, None)
        donate = (0, 1)  # params + opt state are consumed
        meta = {"kind": "train", "optimizer": ocfg.name}
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(cfg)
        if vlm:
            args = (params_sds, SDS((GB, T), jnp.int32), img_sds)
            in_sh = (p_sh, bsh, bsh)
        else:
            args = (params_sds, SDS((GB, T), jnp.int32))
            in_sh = (p_sh, bsh)
        out_sh = None
        donate = ()
        meta = {"kind": "prefill"}
    else:  # decode
        cache_sds = jax.eval_shape(
            lambda p, img: init_cache(cfg, GB, T, image_embeds=img, params=p),
            params_sds,
            img_sds,
        )
        c_spec = cache_spec(cfg)
        c_sh = sanitize(
            jax.tree.map(
                lambda s: NamedSharding(
                    mesh,
                    spec_to_pspec(
                        tuple("batch" if a == "batch" else a for a in s), rules, mesh
                    ),
                ),
                c_spec,
                is_leaf=_is_spec_leaf,
            ),
            cache_sds,
            mesh,
        )
        # batch=1 cells: replicate the cache batch dim along with the batch
        if GB % max(data_ways, 1) != 0:
            c_sh = jax.tree.map(
                lambda sh: NamedSharding(
                    mesh,
                    PartitionSpec(*[
                        None if (i == 1) else s for i, s in enumerate(sh.spec)
                    ]),
                ),
                c_sh,
            )
        # KV caches whose kv-head dim doesn't divide the model axis fall
        # back to SEQUENCE-sharded storage (S % model == 0 always at 32k):
        # the decode softmax/value-sum then runs as sharded partial
        # reductions + a tiny cross-shard combine (GSPMD inserts them).
        model_ways = mesh.shape.get("model", 1)

        def _seq_shard(sh: NamedSharding, sds):
            if (
                len(sds.shape) == 5
                and sds.shape[2] == T
                and sds.shape[3] % model_ways != 0
                and T % model_ways == 0
            ):
                spec = list(sh.spec) + [None] * (5 - len(sh.spec))
                if spec[3] in (None, "model") and spec[2] is None:
                    spec[2], spec[3] = "model", None
                    return NamedSharding(mesh, PartitionSpec(*spec))
            return sh

        c_sh = jax.tree.map(_seq_shard, c_sh, cache_sds)
        step_fn = make_serve_step(cfg)
        if vlm:
            args = (params_sds, SDS((GB, 1), jnp.int32), SDS((), jnp.int32), cache_sds, img_sds)
            in_sh = (p_sh, bsh, repl, c_sh, bsh)
        else:
            args = (params_sds, SDS((GB, 1), jnp.int32), SDS((), jnp.int32), cache_sds)
            in_sh = (p_sh, bsh, repl, c_sh)
        out_sh = (None, c_sh)
        donate = (3,)  # in-place KV-cache / state update
        meta = {"kind": "decode", "backend": cfg.attention_backend}
    meta.update(
        arch=cfg.name, shape=shape.name, family=cfg.family,
        params=cfg.param_count(), active_params=cfg.active_param_count(),
        seq_len=T, global_batch=GB,
    )
    return CellSpec(step_fn, args, in_sh, out_sh, meta, donate)
