"""Activation-sharding hints (flax-style logical constraints, opt-in).

Model code calls ``hint(x, "batch", None, "vocab")`` at layout-critical
points (logits, MoE dispatch buffers). Outside a distributed context this is
an exact no-op, so smoke tests and single-device examples never see a mesh.
The dry-run / trainer enables hints with the active mesh + rules; the
constraint is emitted as with_sharding_constraint(NamedSharding(...)),
auto-downgrading any dim whose size does not divide its mesh extent.
"""

from __future__ import annotations

import math
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.sharding.partitioning import AxisRules, spec_to_pspec

_ACTIVE: tuple[Mesh, AxisRules] | None = None


@contextmanager
def use_hints(mesh: Mesh, rules: AxisRules):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = (mesh, rules)
    try:
        yield
    finally:
        _ACTIVE = prev


def hint(x, *logical):
    if _ACTIVE is None:
        return x
    mesh, rules = _ACTIVE
    spec = spec_to_pspec(tuple(logical), rules, mesh)
    fixed = []
    for dim, s in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if s is None:
            fixed.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        extent = math.prod(mesh.shape[a] for a in axes)
        fixed.append(s if dim % extent == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*fixed))
    )
