"""Logical-axis -> mesh-axis partitioning rules (flax-style, dependency-free).

Every parameter builder in repro.models returns a spec pytree whose leaves
are tuples of logical axis names (or None). This module maps those to
jax.sharding.PartitionSpec / NamedSharding for a given mesh.

Default strategy (the paper-agnostic, 1000-node posture — DESIGN.md §6):

  model axis  : tensor-parallel dims — heads / kv_heads / ffn / vocab /
                experts (EP)
  data axis   : FSDP/ZeRO-3 — the "embed" dim of weight matrices is sharded
                over data; GSPMD all-gathers weights per layer inside the
                scan and reduce-scatters their gradients
  pod axis    : pure data parallelism; weights REPLICATED across pods so
                gradient sync over the slow DCN hop is a single all-reduce
                of already-reduce-scattered shards (hierarchical reduction)

A mesh axis is consumed at most once per PartitionSpec (first logical axis
wins; later mentions degrade to replication) so specs like
("embed", "embed") stay valid.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = tuple[str, ...] | str | None


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-compatible AbstractMesh constructor.

    jax >= 0.5 takes (axis_sizes, axis_names); 0.4.x takes a single tuple
    of (name, size) pairs. Rule/spec logic only needs names and sizes, not
    real devices, so tests build meshes through this.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: dict[str, MeshAxes]

    def lookup(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def replace(self, **kv) -> "AxisRules":
        return AxisRules({**self.rules, **kv})


DEFAULT_RULES = AxisRules(
    {
        "batch": ("pod", "data"),
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "embed": "data",   # FSDP: weight-matrix d_model dim sharded over data
        "layers": None,    # stacked-layer leading axis: never sharded
    }
)

# TP-only variant (no FSDP) — used by the perf loop and small models where
# weight all-gathers cost more than the memory they save.
TP_ONLY_RULES = DEFAULT_RULES.replace(embed=None)

# Pure data parallelism over the whole mesh: for small models whose head
# counts don't divide the model axis, TP wastes it — attention then shards
# only 16/256 ways (measured 26x useless-flops factor on smollm train_4k).
# Weights replicated (they're small by construction of this regime).
DP_ONLY_RULES = AxisRules(
    {
        "batch": ("pod", "data", "model"),
        "layers": None,
    }
)

# Expert-parallelism over the DATA axis: expert weights live fully sharded
# (experts x data, ffn x model), tokens all-to-all to their experts'
# owners (GShard). Removes the per-layer-per-microbatch FSDP all-gather of
# expert weights that dominates the 128-expert models' train cells
# (weights >> activations: gathering 3.3 GB/layer of experts vs ~0.2 GB of
# tokens — see EXPERIMENTS.md §Perf).
EP_DATA_RULES = DEFAULT_RULES.replace(experts="data", embed=None)

# Sequence parallelism (Korthikanti et al. 2022): the residual stream is
# sequence-sharded over 'model' between blocks, turning each Megatron
# activation all-reduce (2(g-1)/g x bytes) into a reduce-scatter + later
# all-gather pair (half the wire bytes) and shrinking the norm/residual
# working set by the TP width.
SP_RULES = DEFAULT_RULES.replace(seq="model")

# EP over data + pure DP (batch over data AND model) for the dense parts:
# removes Megatron TP activation all-reduces entirely; dense/attention
# weights replicate (grads all-reduce once per microbatch — the measured
# trade, see §Perf iteration log).
EP_DP_RULES = AxisRules(
    {
        "batch": ("pod", "data", "model"),
        "experts": "data",
        "ffn": "model",     # expert ffn dim only (dense FFN uses 'ffn' too —
                            # batch consumes 'model' first on activations)
        "layers": None,
    }
)


def spec_to_pspec(spec: tuple, rules: AxisRules, mesh: Mesh) -> PartitionSpec:
    """Map one leaf spec (tuple of logical names) to a PartitionSpec."""
    used: set[str] = set()
    out = []
    for logical in spec:
        mesh_axes = rules.lookup(logical)
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        # keep only axes present in the mesh and not already consumed
        usable = tuple(
            a for a in mesh_axes if a in mesh.axis_names and a not in used
        )
        used.update(usable)
        if not usable:
            out.append(None)
        elif len(usable) == 1:
            out.append(usable[0])
        else:
            out.append(usable)
    return PartitionSpec(*out)


def _is_spec_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def param_shardings(spec_tree, rules: AxisRules, mesh: Mesh):
    """Map a spec pytree to a NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, rules, mesh)),
        spec_tree,
        is_leaf=_is_spec_leaf,
    )


def param_pspecs(spec_tree, rules: AxisRules, mesh: Mesh):
    return jax.tree.map(
        lambda s: spec_to_pspec(s, rules, mesh),
        spec_tree,
        is_leaf=_is_spec_leaf,
    )


def batch_pspec(mesh: Mesh, rules: AxisRules = DEFAULT_RULES) -> PartitionSpec:
    """PartitionSpec for the leading batch dim of inputs/activations."""
    axes = rules.lookup("batch")
    if isinstance(axes, str):
        axes = (axes,)
    usable = tuple(a for a in axes if a in mesh.axis_names)
    if not usable:
        return PartitionSpec(None)
    return PartitionSpec(usable if len(usable) > 1 else usable[0])


def zero1_opt_sharding(param_sharding: NamedSharding, shape: tuple[int, ...], mesh: Mesh):
    """ZeRO-1: additionally shard optimizer moments over 'data' along the
    largest currently-unsharded dim (falls back to the param sharding)."""
    spec = list(param_sharding.spec) + [None] * (len(shape) - len(param_sharding.spec))
    if "data" in [a for s in spec if s for a in (s if isinstance(s, tuple) else (s,))]:
        return param_sharding
    # find largest unsharded, divisible dim
    data_size = mesh.shape.get("data", 1)
    best, best_dim = -1, -1
    for i, (s, n) in enumerate(zip(spec, shape)):
        if s is None and n % data_size == 0 and n > best:
            best, best_dim = n, i
    if best_dim < 0:
        return param_sharding
    spec[best_dim] = "data"
    return NamedSharding(mesh, PartitionSpec(*spec))
