from repro.sharding.partitioning import (
    AxisRules,
    DEFAULT_RULES,
    param_shardings,
    spec_to_pspec,
    batch_pspec,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "param_shardings",
    "spec_to_pspec",
    "batch_pspec",
]
