"""RWKV6 ("Finch", arXiv:2404.05892) — attention-free, data-dependent decay.

Time-mixing recurrence per head (K = V = head size):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t            S: (K, V)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + lora(x_t))) in (0,1) per channel (the
data-dependent decay that distinguishes RWKV6 from RWKV5), and u the
current-token bonus.

Chunked evaluation (GLA-style factorized decay): within a chunk, with
lw = cumsum(log w) (lw <= 0), the decay from s to t factorizes
exp(lw_t - lw_s) = exp(lw_t) * exp(-lw_s) per channel, so the intra-chunk
contribution is a plain GEMM of transformed r/k. Exponents are clipped to
+-30 — the clipped terms are decayed to numerical zero anyway. Chunk of 32
keeps the clip inactive for realistic decays.

NOTE: the paper's technique (Maclaurin collapse of exp-of-inner-products)
is INAPPLICABLE here — there is no exponential of an inner product; the
recurrence is already O(d) per token. DESIGN.md §7 records this; rwkv6 is
built without the technique.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init

Array = jax.Array


def rwkv6_params(key, d: int, d_ff: int, *, head_dim: int = 64, lora_r: int = 64):
    n_heads = d // head_dim
    ks = jax.random.split(key, 12)
    params = {
        "ln1": jnp.ones((d,), jnp.float32),  # pre-time-mix RMSNorm scale
        "ln2": jnp.ones((d,), jnp.float32),  # pre-channel-mix RMSNorm scale
        # time-mix lerp coefficients for r/k/v/w/g token shift
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "w_r": _init(ks[0], (d, d)),
        "w_k": _init(ks[1], (d, d)),
        "w_v": _init(ks[2], (d, d)),
        "w_g": _init(ks[3], (d, d)),
        # data-dependent decay: w = exp(-exp(w0 + (tanh(x Wa) Wb)))
        "w0": -6.0 * jnp.ones((d,), jnp.float32) / 3.0,
        "w_lora_a": _init(ks[4], (d, lora_r)),
        "w_lora_b": _init(ks[5], (lora_r, d), scale=0.01),
        "u": jnp.zeros((n_heads, head_dim), jnp.float32),
        "ln_scale": jnp.ones((d,), jnp.float32),  # per-head group-norm scale
        "w_o": _init(ks[6], (d, d), scale=1.0 / (d**0.5)),
        # channel mixing
        "mu_ffn": 0.5 * jnp.ones((2, d), jnp.float32),
        "w_ffn_k": _init(ks[7], (d, d_ff)),
        "w_ffn_v": _init(ks[8], (d_ff, d), scale=1.0 / (d_ff**0.5)),
        "w_ffn_r": _init(ks[9], (d, d)),
    }
    spec = {
        "ln1": ("embed",),
        "ln2": ("embed",),
        "mu": (None, "embed"),
        "w_r": ("embed", "heads"),
        "w_k": ("embed", "heads"),
        "w_v": ("embed", "heads"),
        "w_g": ("embed", "heads"),
        "w0": ("heads",),
        "w_lora_a": ("embed", None),
        "w_lora_b": (None, "heads"),
        "u": (None, None),
        "ln_scale": ("heads",),
        "w_o": ("heads", "embed"),
        "mu_ffn": (None, "embed"),
        "w_ffn_k": ("embed", "ffn"),
        "w_ffn_v": ("ffn", "embed"),
        "w_ffn_r": ("embed", "embed"),
    }
    return params, spec


def _token_shift(x: Array, last: Array | None = None):
    """x_{t-1}; for decode, `last` carries the previous token."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    return last


def _group_norm(x: Array, scale: Array, n_heads: int, eps: float = 1e-5):
    """Per-head LayerNorm of the wkv output (RWKV convention)."""
    B, T, d = x.shape
    xh = x.reshape(B, T, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    out = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (out.reshape(B, T, d) * scale).astype(x.dtype)


def _decay(params, xw: Array) -> Array:
    """log w in (-inf, 0): -exp(w0 + lora(x)), clipped away from 0."""
    lora = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    return -jnp.exp(params["w0"] + lora) - 1e-4


def time_mix_forward(params, x: Array, *, head_dim: int = 64, chunk: int = 32):
    """Training/prefill path. x: (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    H = d // head_dim
    xs = _token_shift(x)
    mix = lambda i: x + (xs - x) * params["mu"][i]
    r = (mix(0) @ params["w_r"]).reshape(B, T, H, head_dim)
    k = (mix(1) @ params["w_k"]).reshape(B, T, H, head_dim)
    v = (mix(2) @ params["w_v"]).reshape(B, T, H, head_dim)
    lw = _decay(params, mix(3)).reshape(B, T, H, head_dim)  # log w
    g = jax.nn.silu(mix(4) @ params["w_g"])

    n_chunks = T // chunk
    assert n_chunks * chunk == T
    cs = chunk
    rs = lambda t: t.reshape(B, n_chunks, cs, H, head_dim).transpose(1, 0, 2, 3, 4)
    r_c, k_c, v_c, lw_c = rs(r), rs(k), rs(v), rs(lw)
    u = params["u"]

    def scan_chunk(S, inputs):
        rc, kc, vc, lwc = inputs                       # (B,Cs,H,K)
        L = jnp.cumsum(lwc, axis=1)                    # inclusive cumsum of log w
        # decay applied BETWEEN s and t (exclusive of s): exp(L_{t-1} - L_s)
        # shift L for the query side: decay up to but excluding token t's own w.
        Lq = jnp.concatenate([jnp.zeros_like(L[:, :1]), L[:, :-1]], axis=1)
        r_t = rc * jnp.exp(jnp.clip(Lq, -30.0, 30.0))
        k_s = kc * jnp.exp(jnp.clip(-L, -30.0, 30.0))
        A = jnp.einsum("bthk,bshk->bhts", r_t, k_s)    # strict lower part valid
        tri = jnp.tril(jnp.ones((cs, cs), dtype=bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        # current-token bonus u
        diag = jnp.einsum("bthk,hk,bthk->bth", rc, u, kc)
        y = jnp.einsum("bhts,bshv->bthv", A, vc)
        y = y + diag[..., None] * vc
        # inter-chunk: state seen by token t decayed by Lq
        y = y + jnp.einsum("bthk,bhkv->bthv", r_t, S)
        # state update: S' = diag(prod w) S + sum_s (k_s * exp(L_end - L_s)) v_s
        L_end = L[:, -1]                               # (B,H,K)
        k_upd = kc * jnp.exp(jnp.clip(L_end[:, None] - L, -30.0, 30.0))
        S = jnp.exp(jnp.clip(L_end, -30.0, 30.0))[..., None] * S + jnp.einsum(
            "bshk,bshv->bhkv", k_upd, vc
        )
        return S, y

    S0 = jnp.zeros((B, H, head_dim, head_dim), x.dtype)
    _, ys = jax.lax.scan(scan_chunk, S0, (r_c, k_c, v_c, lw_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, d)
    y = _group_norm(y, params["ln_scale"], H) * g
    return y @ params["w_o"]


def time_mix_decode(params, x: Array, state, *, head_dim: int = 64):
    """One-token decode. state = (S (B,H,K,V), x_prev (B,1,d))."""
    B, _, d = x.shape
    H = d // head_dim
    S, x_prev = state
    mix = lambda i: x + (x_prev - x) * params["mu"][i]
    r = (mix(0) @ params["w_r"]).reshape(B, H, head_dim)
    k = (mix(1) @ params["w_k"]).reshape(B, H, head_dim)
    v = (mix(2) @ params["w_v"]).reshape(B, H, head_dim)
    lw = _decay(params, mix(3)).reshape(B, H, head_dim)
    g = jax.nn.silu(mix(4) @ params["w_g"])
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, S + params["u"][None, :, :, None] * kv)
    S = jnp.exp(lw)[..., None] * S + kv
    y = y.reshape(B, 1, d)
    y = _group_norm(y, params["ln_scale"], H) * g
    return y @ params["w_o"], (S, x)


def channel_mix(params, x: Array, last: Array | None = None):
    """RWKV6 FFN ('channel mixing'). Returns (out, x) — x is the new shift."""
    xs = _token_shift(x, last)
    xk = x + (xs - x) * params["mu_ffn"][0]
    xr = x + (xs - x) * params["mu_ffn"][1]
    kk = jnp.square(jax.nn.relu(xk @ params["w_ffn_k"]))
    return jax.nn.sigmoid(xr @ params["w_ffn_r"]) * (kk @ params["w_ffn_v"]), x


def rwkv6_init_state(B: int, d: int, *, head_dim: int = 64, dtype=jnp.float32):
    H = d // head_dim
    S = jnp.zeros((B, H, head_dim, head_dim), dtype)
    x_prev_tm = jnp.zeros((B, 1, d), dtype)
    x_prev_cm = jnp.zeros((B, 1, d), dtype)
    return S, x_prev_tm, x_prev_cm
