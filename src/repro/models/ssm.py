"""Mamba2 (SSD) block — chunked state-space duality form (arXiv:2405.21060).

Recurrence (per head h, scalar decay):
    h_t = exp(A dt_t) h_{t-1} + dt_t * B_t x_t^T        h: (N, P)
    y_t = C_t h_t + D * x_t

Chunked evaluation (chunk Cs): within a chunk the quadratic form
    Y_intra[t] = sum_{s<=t} (C_t . B_s) exp(l_t - l_s) dt_s x_s,
    l = cumsum(A dt)
is a (Cs x Cs) masked GEMM per head; across chunks a (N, P) state is
carried by a lax.scan. Memory O(B H Cs^2 + B H N P) instead of the
O(B T H N P) a naive associative scan would materialize.

TPU notes: the (Cs x Cs) intra form is MXU-shaped; the chunk scan is the
standard sequential-grid pattern. n_groups = 1 (B/C shared across heads),
matching the Zamba2 configuration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init, rmsnorm, rmsnorm_params

Array = jax.Array


def mamba2_params(key, d: int, *, d_state: int = 64, head_dim: int = 64, expand: int = 2, conv_w: int = 4):
    d_inner = expand * d
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    params = {
        # fused input projection: [z gate | x | B | C | dt]
        "w_in": _init(ks[0], (d, 2 * d_inner + 2 * d_state + n_heads)),
        "conv": _init(ks[1], (conv_w, d_inner + 2 * d_state), scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": rmsnorm_params(d_inner)[0],
        "w_out": _init(ks[2], (d_inner, d), scale=1.0 / (d_inner**0.5)),
    }
    spec = {
        "w_in": ("embed", "ffn"),
        "conv": (None, "ffn"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": {"scale": ("ffn",)},
        "w_out": ("ffn", "embed"),
    }
    return params, spec


def _split_proj(proj, d_inner, d_state, n_heads):
    z = proj[..., :d_inner]
    x = proj[..., d_inner : 2 * d_inner]
    Bmat = proj[..., 2 * d_inner : 2 * d_inner + d_state]
    Cmat = proj[..., 2 * d_inner + d_state : 2 * d_inner + 2 * d_state]
    dt = proj[..., 2 * d_inner + 2 * d_state :]
    return z, x, Bmat, Cmat, dt


def _causal_conv(x: Array, w: Array, carry: Array | None = None):
    """Depthwise causal conv. x: (B, T, C), w: (W, C). carry: (B, W-1, C)."""
    W = w.shape[0]
    if carry is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out), xp[:, -(W - 1) :, :]


def mamba2_forward(params, x_in: Array, *, d_state: int = 64, head_dim: int = 64, chunk: int = 128):
    """Training/prefill path. x_in: (B, T, d) -> (B, T, d)."""
    B, T, d = x_in.shape
    d_inner = params["w_out"].shape[0]
    n_heads = d_inner // head_dim

    proj = x_in @ params["w_in"]
    z, x, Bm, Cm, dt = _split_proj(proj, d_inner, d_state, n_heads)
    xbc, _ = _causal_conv(jnp.concatenate([x, Bm, Cm], axis=-1), params["conv"])
    x, Bm, Cm = (
        xbc[..., :d_inner],
        xbc[..., d_inner : d_inner + d_state],
        xbc[..., d_inner + d_state :],
    )
    dt = jax.nn.softplus(dt + params["dt_bias"])          # (B, T, H)
    A = -jnp.exp(params["A_log"])                         # (H,) negative
    xh = x.reshape(B, T, n_heads, head_dim)

    n_chunks = T // chunk
    assert n_chunks * chunk == T, "T must be divisible by chunk"
    r = lambda t: t.reshape(B, n_chunks, chunk, *t.shape[2:])
    xh_c, B_c, C_c, dt_c = r(xh), r(Bm), r(Cm), r(dt)

    def scan_chunk(state, inputs):
        # state: (B, H, N, P); inputs sliced per chunk.
        xc, bc, cc, dtc = inputs                           # (B,Cs,H,P) (B,Cs,N) ...
        l = jnp.cumsum(A[None, None, :] * dtc, axis=1)     # (B,Cs,H) log-decay
        # intra-chunk: G[t,s] = (C_t.B_s) exp(l_t - l_s) dt_s, s<=t
        cb = jnp.einsum("btn,bsn->bts", cc, bc)            # (B,Cs,Cs)
        decay = jnp.exp(
            jnp.clip(l[:, :, None, :] - l[:, None, :, :], -30.0, 0.0)
        )                                                  # (B,Cs,Cs,H) t>=s
        mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        G = cb[..., None] * decay * dtc[:, None, :, :]     # (B,Cs,Cs,H)
        G = jnp.where(mask[None, :, :, None], G, 0.0)
        y_intra = jnp.einsum("btsh,bshp->bthp", G, xc)
        # inter-chunk: y += C_t exp(l_t) S_prev
        y_inter = jnp.einsum(
            "btn,bth,bhnp->bthp", cc, jnp.exp(l), state
        )
        # state update: S = exp(l_end) S + sum_s exp(l_end - l_s) dt_s B_s x_s^T
        l_end = l[:, -1:, :]                               # (B,1,H)
        w_s = jnp.exp(jnp.clip(l_end - l, -30.0, 0.0)) * dtc  # (B,Cs,H)
        ds = jnp.einsum("bsn,bsh,bshp->bhnp", bc, w_s, xc)
        state = jnp.exp(l_end[:, 0, :])[:, :, None, None] * state + ds
        return state, y_intra + y_inter

    init = jnp.zeros((B, n_heads, d_state, head_dim), x_in.dtype)
    # move chunk axis to scan position
    seq = (
        xh_c.transpose(1, 0, 2, 3, 4),
        B_c.transpose(1, 0, 2, 3),
        C_c.transpose(1, 0, 2, 3),
        dt_c.transpose(1, 0, 2, 3),
    )
    _, ys = jax.lax.scan(scan_chunk, init, seq)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, n_heads, head_dim)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(B, T, d_inner)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    return y @ params["w_out"]


def mamba2_decode(params, x_in: Array, state, *, d_state: int = 64, head_dim: int = 64):
    """One-token decode. x_in: (B, 1, d); state = (ssm (B,H,N,P), conv carry).

    O(H N P) per token — constant in context length (the SSM analogue of the
    paper's O(d^2) collapsed predictor).
    """
    B = x_in.shape[0]
    d_inner = params["w_out"].shape[0]
    n_heads = d_inner // head_dim
    ssm, conv_carry = state

    proj = x_in @ params["w_in"]
    z, x, Bm, Cm, dt = _split_proj(proj, d_inner, d_state, n_heads)
    xbc, conv_carry = _causal_conv(
        jnp.concatenate([x, Bm, Cm], axis=-1), params["conv"], conv_carry
    )
    x, Bm, Cm = (
        xbc[..., :d_inner],
        xbc[..., d_inner : d_inner + d_state],
        xbc[..., d_inner + d_state :],
    )
    dt = jax.nn.softplus(dt + params["dt_bias"])[:, 0]     # (B,H)
    A = -jnp.exp(params["A_log"])
    xh = x.reshape(B, n_heads, head_dim)
    alpha = jnp.exp(A[None, :] * dt)                       # (B,H)
    ssm = alpha[:, :, None, None] * ssm + jnp.einsum(
        "bn,bh,bhp->bhnp", Bm[:, 0], dt, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], ssm)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(params["norm"], y) * jax.nn.silu(z)
    return y @ params["w_out"], (ssm, conv_carry)


def mamba2_init_state(B: int, d: int, *, d_state: int = 64, head_dim: int = 64, expand: int = 2, conv_w: int = 4, dtype=jnp.float32):
    d_inner = expand * d
    n_heads = d_inner // head_dim
    ssm = jnp.zeros((B, n_heads, d_state, head_dim), dtype)
    conv = jnp.zeros((B, conv_w - 1, d_inner + 2 * d_state), dtype)
    return ssm, conv
