"""Decoder-stack assembly for all assigned architecture families.

One module builds params+specs and runs forward (train/prefill) and decode
for: dense/GQA transformers (optionally MoE, optionally QKV-bias),
hybrid Mamba2+shared-attention (Zamba2 pattern), RWKV6, and VLM stacks with
interleaved cross-attention (Llama-3.2-vision pattern).

Layer stacks are lax.scan'd over stacked parameter pytrees so the HLO stays
compact for the 80-cell dry-run; heterogeneous patterns (hybrid / vlm) use a
small Python loop of scanned super-blocks.

Attention backends:
  "softmax"    exact attention (training + the KV-cache decode baseline)
  "maclaurin"  the paper's second-order collapse (state decode; long_500k)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import maclaurin_attention as mac
from repro.models.attention import (
    attention_params,
    cross_attention,
    cross_attention_params,
    decode_attention,
    self_attention,
    _project_qkv,
)
from repro.models.layers import (
    embedding_params,
    embed,
    lm_head,
    lm_head_params,
    rmsnorm,
    rmsnorm_params,
    swiglu,
    swiglu_params,
)
from repro.models.moe import moe_forward, moe_params
from repro.models.rwkv import (
    channel_mix,
    rwkv6_init_state,
    rwkv6_params,
    time_mix_decode,
    time_mix_forward,
)
from repro.models.ssm import (
    mamba2_decode,
    mamba2_forward,
    mamba2_init_state,
    mamba2_params,
)

Array = jax.Array


# ======================================================================
# parameter construction
# ======================================================================


def _stack(fn, key, n: int):
    """Build n copies of (params, spec) and stack the params along axis 0."""
    keys = jax.random.split(key, n)
    ps = [fn(k) for k in keys]
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in ps])
    spec = jax.tree.map(
        lambda s: ("layers",) + s, ps[0][1], is_leaf=lambda x: isinstance(x, tuple)
    )
    return params, spec


def _dense_layer_params(cfg: ModelConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p_attn, s_attn = attention_params(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qkv_bias
    )
    params = {
        "ln1": rmsnorm_params(cfg.d_model)[0],
        "attn": p_attn,
        "ln2": rmsnorm_params(cfg.d_model)[0],
    }
    spec = {
        "ln1": rmsnorm_params(cfg.d_model)[1],
        "attn": s_attn,
        "ln2": rmsnorm_params(cfg.d_model)[1],
    }
    if cfg.moe_num_experts:
        p_moe, s_moe = moe_params(k2, cfg.d_model, cfg.moe_d_ff, cfg.moe_num_experts)
        params["moe"], spec["moe"] = p_moe, s_moe
        if cfg.moe_dense_residual:
            p_ffn, s_ffn = swiglu_params(k3, cfg.d_model, cfg.d_ff)
            params["ffn"], spec["ffn"] = p_ffn, s_ffn
    else:
        p_ffn, s_ffn = swiglu_params(k3, cfg.d_model, cfg.d_ff)
        params["ffn"], spec["ffn"] = p_ffn, s_ffn
    return params, spec


def init_params(cfg: ModelConfig, key):
    """Returns (params, spec) for any family."""
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    p_emb, s_emb = embedding_params(k_emb, cfg.vocab_size, cfg.d_model)
    p_head, s_head = lm_head_params(k_head, cfg.d_model, cfg.vocab_size)
    params = {"embed": p_emb, "lm_head": p_head, "final_ln": rmsnorm_params(cfg.d_model)[0]}
    spec = {"embed": s_emb, "lm_head": s_head, "final_ln": rmsnorm_params(cfg.d_model)[1]}

    if cfg.family == "ssm":  # rwkv6
        p, s = _stack(
            lambda k: rwkv6_params(k, cfg.d_model, cfg.d_ff, head_dim=cfg.rwkv_head_dim),
            k_layers,
            cfg.n_layers,
        )
        params["layers"], spec["layers"] = p, s
    elif cfg.family == "hybrid":  # zamba2: mamba backbone + ONE shared attn block
        p, s = _stack(
            lambda k: mamba2_params(
                k, cfg.d_model, d_state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            ),
            k_layers,
            cfg.n_layers,
        )
        params["layers"], spec["layers"] = p, s
        p_sh, s_sh = _dense_layer_params(cfg, k_extra)
        params["shared_attn"], spec["shared_attn"] = p_sh, s_sh
    elif cfg.family == "vlm":
        n_cross = cfg.n_layers // (cfg.cross_attn_every or cfg.n_layers)
        n_self = cfg.n_layers - n_cross
        k_self, k_cross = jax.random.split(k_layers)
        p_self, s_self = _stack(lambda k: _dense_layer_params(cfg, k), k_self, n_self)
        params["layers"], spec["layers"] = p_self, s_self

        def _cross(k):
            kc, kf = jax.random.split(k)
            pc, sc = cross_attention_params(kc, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
            pf, sf = swiglu_params(kf, cfg.d_model, cfg.d_ff)
            return (
                {"ln1": rmsnorm_params(cfg.d_model)[0], "xattn": pc,
                 "ln2": rmsnorm_params(cfg.d_model)[0], "ffn": pf},
                {"ln1": rmsnorm_params(cfg.d_model)[1], "xattn": sc,
                 "ln2": rmsnorm_params(cfg.d_model)[1], "ffn": sf},
            )

        p_cross, s_cross = _stack(_cross, k_cross, n_cross)
        params["cross_layers"], spec["cross_layers"] = p_cross, s_cross
    else:  # dense / moe / audio — homogeneous stack
        p, s = _stack(lambda k: _dense_layer_params(cfg, k), k_layers, cfg.n_layers)
        params["layers"], spec["layers"] = p, s
    return params, spec


# ======================================================================
# forward (train / prefill)
# ======================================================================


def _attn_forward(cfg: ModelConfig, p_attn, x, positions):
    """Self-attention dispatch over backends/implementations."""
    if cfg.attention_backend == "maclaurin":
        B, T, _ = x.shape
        q, k, v = _project_qkv(
            p_attn, x, cfg.n_heads, cfg.n_kv_heads, cfg.hd, positions, cfg.rope_theta
        )
        out = mac.maclaurin_attention_gqa(q, k, v)
        return out.reshape(B, T, cfg.n_heads * cfg.hd) @ p_attn["w_o"]
    if cfg.attention_impl == "flash":
        from repro.kernels.flash_attn import flash_attention

        B, T, _ = x.shape
        q, k, v = _project_qkv(
            p_attn, x, cfg.n_heads, cfg.n_kv_heads, cfg.hd, positions, cfg.rope_theta
        )
        g = cfg.n_heads // cfg.n_kv_heads
        kq = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)
        vq = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
        out = flash_attention(q.transpose(0, 2, 1, 3), kq, vq)
        out = out.transpose(0, 2, 1, 3).reshape(B, T, cfg.n_heads * cfg.hd)
        return out @ p_attn["w_o"]
    return self_attention(
        p_attn, x,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
        positions=positions, rope_theta=cfg.rope_theta, causal=True,
        scores_dtype=jnp.dtype(cfg.attn_scores_dtype),
    )


def _dense_block(cfg: ModelConfig, p, x, positions):
    """Pre-norm attention + FFN/MoE block. Returns (x, aux_loss)."""
    x = x + _attn_forward(cfg, p["attn"], rmsnorm(p["ln1"], x), positions)
    h = rmsnorm(p["ln2"], x)
    aux = jnp.float32(0.0)
    if cfg.moe_num_experts:
        y, aux = moe_forward(p["moe"], h, top_k=cfg.moe_top_k)
        if cfg.moe_dense_residual:
            y = y + swiglu(p["ffn"], h)
    else:
        y = swiglu(p["ffn"], h)
    return x + y, aux


def _scan_layers(cfg: ModelConfig, block_fn, x, stacked, *extra):
    """lax.scan over a stacked layer pytree, accumulating aux losses.

    The residual stream is re-pinned to batch sharding every layer —
    without this GSPMD tends to inherit the FSDP weights' 'data' sharding
    on the embed dim and silently replicates attention interiors."""
    from repro.sharding.hints import hint

    def body(carry, p):
        x, aux = carry
        # "seq" maps to None by default; SP_RULES maps it to 'model'
        # (sequence parallelism between blocks).
        x = hint(x, "batch", "seq", None)
        x2, a = block_fn(cfg, p, x, *extra)
        x2 = hint(x2, "batch", "seq", None)
        return (x2, aux + a), None

    body = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def forward(cfg: ModelConfig, params, tokens: Array, image_embeds: Array | None = None):
    """Full-sequence forward -> (logits, aux_loss). tokens: (B, T)."""
    from repro.sharding.hints import hint

    dtype = jnp.dtype(cfg.dtype)
    B, T = tokens.shape
    x = embed(params["embed"], tokens).astype(dtype)
    x = hint(x, "batch", None, None)
    positions = jnp.arange(T, dtype=jnp.int32)
    cast = lambda p: jax.tree.map(lambda l: l.astype(dtype), p)

    if cfg.family == "ssm":
        def rwkv_block(cfg, p, x):
            x = x + time_mix_forward(
                p, rmsnorm({"scale": p["ln1"]}, x),
                head_dim=cfg.rwkv_head_dim, chunk=cfg.scan_chunk,
            )
            out, _ = channel_mix(p, rmsnorm({"scale": p["ln2"]}, x))
            return x + out, jnp.float32(0.0)

        x, aux = _scan_layers(cfg, rwkv_block, x, cast(params["layers"]))
    elif cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        L = cfg.n_layers
        n_groups = L // k_every
        stacked = cast(params["layers"])
        grouped = jax.tree.map(
            lambda l: l.reshape(n_groups, k_every, *l.shape[1:]), stacked
        )
        shared = cast(params["shared_attn"])
        positions_ = positions
        aux = jnp.float32(0.0)

        def mamba_block(cfg, p, x):
            return x + mamba2_forward(
                p, x, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                chunk=cfg.scan_chunk,
            ), jnp.float32(0.0)

        for g in range(n_groups):
            grp = jax.tree.map(lambda l: l[g], grouped)
            x, a = _scan_layers(cfg, mamba_block, x, grp)
            aux += a
            x, a = _dense_block(cfg, shared, x, positions_)  # shared weights
            aux += a
    elif cfg.family == "vlm":
        assert image_embeds is not None
        ctx = image_embeds.astype(dtype)
        k_every = cfg.cross_attn_every
        n_cross = cfg.n_layers // k_every
        per_block = (cfg.n_layers - n_cross) // n_cross
        stacked = cast(params["layers"])
        grouped = jax.tree.map(
            lambda l: l.reshape(n_cross, per_block, *l.shape[1:]), stacked
        )
        cross_stacked = cast(params["cross_layers"])
        aux = jnp.float32(0.0)

        def superblock(carry, ps):
            x, aux = carry
            grp, pc = ps
            x, a = _scan_layers(cfg, _dense_block, x, grp, positions)
            h = rmsnorm(pc["ln1"], x)
            x = x + cross_attention(
                pc["xattn"], h, ctx, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.hd,
            )
            x = x + swiglu(pc["ffn"], rmsnorm(pc["ln2"], x))
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(superblock, (x, aux), (grouped, cross_stacked))
    else:
        x, aux = _scan_layers(cfg, _dense_block, x, cast(params["layers"]), positions)

    x = rmsnorm(params["final_ln"], x)
    logits = lm_head(cast(params["lm_head"]), x)
    return hint(logits, "batch", None, "vocab"), aux


# ======================================================================
# decode (serve_step substrate)
# ======================================================================


def _mac_attn_decode(cfg: ModelConfig, p_attn, x, pos, state: mac.MacState):
    """Maclaurin-state decode attention: the paper's O(d^2) collapse.

    state leaves have batch dims (B, Hkv). Extend-then-readout = causal
    inclusive of the current token (matches the kernel/ref semantics).
    """
    B = x.shape[0]
    Hkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(
        p_attn, x, cfg.n_heads, cfg.n_kv_heads, cfg.hd, positions, cfg.rope_theta
    )
    k_bh = k.transpose(0, 2, 1, 3)                      # (B, Hkv, 1, hd)
    v_bh = v.transpose(0, 2, 1, 3)
    state = mac.extend_state(state, k_bh.astype(jnp.float32), v_bh.astype(jnp.float32))
    q_bh = q.reshape(B, 1, Hkv, g, cfg.hd)[:, 0].astype(jnp.float32)  # (B, Hkv, g, hd)
    out, _valid = mac.readout(state, q_bh)              # (B, Hkv, g, hd)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd).astype(x.dtype)
    return out @ p_attn["w_o"], state


def _dense_block_decode(cfg: ModelConfig, p, x, pos, attn_cache):
    """One-token dense block. attn_cache: (ck, cv) | int8 4-tuple | MacState."""
    h = rmsnorm(p["ln1"], x)
    if cfg.attention_backend == "maclaurin":
        attn_out, attn_cache = _mac_attn_decode(cfg, p["attn"], h, pos, attn_cache)
    elif isinstance(attn_cache, tuple) and len(attn_cache) == 4:
        from repro.models.attention import decode_attention_quant

        ck, cv, ks, vs = attn_cache
        attn_out, ck, cv, ks, vs = decode_attention_quant(
            p["attn"], h, ck, cv, ks, vs, pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta,
        )
        attn_cache = (ck, cv, ks, vs)
    else:
        ck, cv = attn_cache
        attn_out, ck, cv = decode_attention(
            p["attn"], h, ck, cv, pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta,
        )
        attn_cache = (ck, cv)
    x = x + attn_out
    h2 = rmsnorm(p["ln2"], x)
    if cfg.moe_num_experts:
        y, _ = moe_forward(p["moe"], h2, top_k=cfg.moe_top_k, return_aux=False)
        if cfg.moe_dense_residual:
            y = y + swiglu(p["ffn"], h2)
    else:
        y = swiglu(p["ffn"], h2)
    return x + y, attn_cache


def init_cache(cfg: ModelConfig, B: int, S: int, image_embeds: Array | None = None,
               params=None, dtype=jnp.bfloat16):
    """Build the decode cache pytree for a context window of S tokens.

    softmax backend: (L, B, S, Hkv, hd) KV tensors — O(S) memory.
    maclaurin backend: MacState with (L, B, Hkv, d^2-ish) leaves — O(d^2),
    INDEPENDENT of S (the paper's collapse; S only bounds positions).
    """
    Hkv, hd = cfg.n_kv_heads, cfg.hd

    def kv(L):
        if cfg.kv_cache_dtype == "int8" and cfg.family not in ("hybrid", "vlm"):
            # int8 values + grouped sub-channel f32 scales (dense archs);
            # group size lives in repro.models.attention (KV_QUANT_GROUP)
            from repro.models.attention import kv_quant_groups

            G = kv_quant_groups(hd)
            return (
                jnp.zeros((L, B, S, Hkv, hd), jnp.int8),
                jnp.zeros((L, B, S, Hkv, hd), jnp.int8),
                jnp.zeros((L, B, S, Hkv, G), jnp.float32),
                jnp.zeros((L, B, S, Hkv, G), jnp.float32),
            )
        return (
            jnp.zeros((L, B, S, Hkv, hd), dtype),
            jnp.zeros((L, B, S, Hkv, hd), dtype),
        )

    def mac_state(L):
        return mac.init_state((L, B, Hkv), hd, hd)

    if cfg.family == "ssm":
        S_, x_tm, x_cm = rwkv6_init_state(B, cfg.d_model, head_dim=cfg.rwkv_head_dim)
        L = cfg.n_layers
        tile = lambda t: jnp.broadcast_to(t[None], (L, *t.shape)).astype(jnp.float32)
        return {"S": tile(S_), "x_tm": tile(x_tm), "x_cm": tile(x_cm)}
    if cfg.family == "hybrid":
        ssm, conv = mamba2_init_state(
            B, cfg.d_model, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand,
        )
        L = cfg.n_layers
        tile = lambda t: jnp.broadcast_to(t[None], (L, *t.shape)).astype(jnp.float32)
        G = cfg.n_layers // cfg.hybrid_attn_every
        attn = mac_state(G) if cfg.attention_backend == "maclaurin" else kv(G)
        return {"ssm": tile(ssm), "conv": tile(conv), "attn": attn}
    if cfg.family == "vlm":
        k_every = cfg.cross_attn_every
        n_cross = cfg.n_layers // k_every
        n_self = cfg.n_layers - n_cross
        out = {"self": mac_state(n_self) if cfg.attention_backend == "maclaurin" else kv(n_self)}
        # Cross-attention context: precompute image K/V (or their Maclaurin
        # state — the paper's fixed-SV-set setting) once per request.
        assert image_embeds is not None and params is not None
        cl = params["cross_layers"]

        def build(pc):
            N = image_embeds.shape[1]
            kx = (image_embeds.astype(dtype) @ pc["xattn"]["w_k"].astype(dtype)).reshape(B, N, Hkv, hd)
            vx = (image_embeds.astype(dtype) @ pc["xattn"]["w_v"].astype(dtype)).reshape(B, N, Hkv, hd)
            if cfg.attention_backend == "maclaurin":
                st = mac.init_state((B, Hkv), hd, hd)
                return mac.extend_state(
                    st, kx.transpose(0, 2, 1, 3).astype(jnp.float32),
                    vx.transpose(0, 2, 1, 3).astype(jnp.float32),
                )
            return (kx, vx)

        out["cross"] = jax.vmap(build)(cl)
        return out
    return {"kv": mac_state(cfg.n_layers) if cfg.attention_backend == "maclaurin" else kv(cfg.n_layers)}


def decode(cfg: ModelConfig, params, tokens: Array, pos, cache,
           image_embeds: Array | None = None):
    """One decode step. tokens: (B, 1) -> (logits (B, 1, V), new cache)."""
    from repro.sharding.hints import hint

    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = embed(params["embed"], tokens).astype(dtype)
    x = hint(x, "batch", None, None)
    cast = lambda p: jax.tree.map(lambda l: l.astype(dtype), p)

    if cfg.family == "ssm":
        def body(x, inp):
            # states are stored f32 (long-horizon accumulation); compute in
            # cfg.dtype; residual stream stays in cfg.dtype.
            p, S_, x_tm, x_cm = inp
            h = rmsnorm({"scale": p["ln1"]}, x)
            out, (S_, x_tm) = time_mix_decode(
                p, h, (S_, x_tm.astype(h.dtype)), head_dim=cfg.rwkv_head_dim
            )
            x = x + out.astype(x.dtype)
            h2 = rmsnorm({"scale": p["ln2"]}, x)
            out2, x_cm = channel_mix(p, h2, x_cm.astype(h2.dtype))
            x = x + out2.astype(x.dtype)
            return x, (
                S_.astype(jnp.float32),
                x_tm.astype(jnp.float32),
                x_cm.astype(jnp.float32),
            )

        x, (S_n, xtm_n, xcm_n) = jax.lax.scan(
            body, x, (cast(params["layers"]), cache["S"], cache["x_tm"], cache["x_cm"])
        )
        cache = {"S": S_n, "x_tm": xtm_n, "x_cm": xcm_n}
    elif cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        G = cfg.n_layers // k_every
        grouped_p = jax.tree.map(
            lambda l: l.reshape(G, k_every, *l.shape[1:]), cast(params["layers"])
        )
        grouped_ssm = cache["ssm"].reshape(G, k_every, *cache["ssm"].shape[1:])
        grouped_conv = cache["conv"].reshape(G, k_every, *cache["conv"].shape[1:])
        shared = cast(params["shared_attn"])
        new_ssm, new_conv, new_attn = [], [], []
        for g in range(G):
            def body(x, inp):
                p, ssm_s, conv_s = inp
                out, (ssm_s, conv_s) = mamba2_decode(
                    p, x, (ssm_s, conv_s), d_state=cfg.ssm_state,
                    head_dim=cfg.ssm_head_dim,
                )
                return x + out.astype(x.dtype), (
                    ssm_s.astype(jnp.float32),
                    conv_s.astype(jnp.float32),
                )

            grp = jax.tree.map(lambda l: l[g], grouped_p)
            x, (s_n, c_n) = jax.lax.scan(body, x, (grp, grouped_ssm[g], grouped_conv[g]))
            new_ssm.append(s_n)
            new_conv.append(c_n)
            ac = jax.tree.map(lambda l: l[g], cache["attn"],
                              is_leaf=lambda l: isinstance(l, jnp.ndarray))
            x, ac = _dense_block_decode(cfg, shared, x, pos, ac)
            new_attn.append(ac)
        cache = {
            "ssm": jnp.concatenate(new_ssm, axis=0).reshape(cache["ssm"].shape),
            "conv": jnp.concatenate(new_conv, axis=0).reshape(cache["conv"].shape),
            "attn": jax.tree.map(lambda *ls: jnp.stack(ls), *new_attn),
        }
    elif cfg.family == "vlm":
        k_every = cfg.cross_attn_every
        n_cross = cfg.n_layers // k_every
        per_block = (cfg.n_layers - n_cross) // n_cross
        grouped_p = jax.tree.map(
            lambda l: l.reshape(n_cross, per_block, *l.shape[1:]), cast(params["layers"])
        )
        grouped_c = jax.tree.map(
            lambda l: l.reshape(n_cross, per_block, *l.shape[1:]), cache["self"]
        )
        cross_p = cast(params["cross_layers"])
        new_self = []
        for g in range(n_cross):
            def body(x, inp):
                p, ac = inp
                x, ac = _dense_block_decode(cfg, p, x, pos, ac)
                return x, ac

            grp = jax.tree.map(lambda l: l[g], grouped_p)
            acg = jax.tree.map(lambda l: l[g], grouped_c)
            x, ac_n = jax.lax.scan(body, x, (grp, acg))
            new_self.append(ac_n)
            pc = jax.tree.map(lambda l: l[g], cross_p)
            cc = jax.tree.map(lambda l: l[g], cache["cross"])
            h = rmsnorm(pc["ln1"], x)
            if cfg.attention_backend == "maclaurin":
                Hkv, gq = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
                q = (h @ pc["xattn"]["w_q"]).reshape(B, 1, cfg.n_heads, cfg.hd)
                q_bh = q.reshape(B, 1, Hkv, gq, cfg.hd)[:, 0].astype(jnp.float32)
                out, _ = mac.readout(cc, q_bh)
                out = out.reshape(B, 1, cfg.n_heads * cfg.hd).astype(x.dtype)
                x = x + out @ pc["xattn"]["w_o"]
            else:
                kx, vx = cc
                from repro.models.attention import _gqa_scores_full
                q = (h @ pc["xattn"]["w_q"]).reshape(B, 1, cfg.n_heads, cfg.hd)
                out = _gqa_scores_full(q, kx.astype(q.dtype), vx.astype(q.dtype), causal=False)
                x = x + out.reshape(B, 1, cfg.n_heads * cfg.hd) @ pc["xattn"]["w_o"]
            x = x + swiglu(pc["ffn"], rmsnorm(pc["ln2"], x))
        cache = {
            # re-flatten (n_cross, per_block, ...) -> (n_self, ...)
            "self": jax.tree.map(
                lambda *ls: jnp.stack(ls).reshape(-1, *ls[0].shape[1:]), *new_self
            ),
            "cross": cache["cross"],
        }
    else:
        def body(x, inp):
            p, ac = inp
            x, ac = _dense_block_decode(cfg, p, x, pos, ac)
            return x, ac

        x, kv_n = jax.lax.scan(body, x, (cast(params["layers"]), cache["kv"]))
        cache = {"kv": kv_n}

    x = rmsnorm(params["final_ln"], x)
    logits = lm_head(cast(params["lm_head"]), x)
    return logits, cache


def cache_spec(cfg: ModelConfig):
    """Logical-axis spec pytree mirroring init_cache's structure (for the
    partitioner). Must stay in lock-step with init_cache."""
    kv_leaf = ("layers", "batch", None, "kv_heads", None)
    if cfg.kv_cache_dtype == "int8" and cfg.family not in ("hybrid", "vlm"):
        kv_tuple = (kv_leaf, kv_leaf, kv_leaf, kv_leaf)  # + per-token scales
    else:
        kv_tuple = (kv_leaf, kv_leaf)

    def mac_spec():
        return mac.MacState(
            s1=("layers", "batch", "kv_heads", None, None),
            s2=("layers", "batch", "kv_heads", None, None),
            k1=("layers", "batch", "kv_heads", None),
            k2=("layers", "batch", "kv_heads", None),
            n=("layers", "batch", "kv_heads", None),
            v0=("layers", "batch", "kv_heads", None),
            max_k_sq=("layers", "batch", "kv_heads", None),
        )

    if cfg.family == "ssm":
        return {
            "S": ("layers", "batch", "heads", None, None),
            "x_tm": ("layers", "batch", None, None),
            "x_cm": ("layers", "batch", None, None),
        }
    if cfg.family == "hybrid":
        attn = mac_spec() if cfg.attention_backend == "maclaurin" else (kv_leaf, kv_leaf)
        return {
            "ssm": ("layers", "batch", "ffn", None, None),
            "conv": ("layers", "batch", None, "ffn"),
            "attn": attn,
        }
    if cfg.family == "vlm":
        self_ = mac_spec() if cfg.attention_backend == "maclaurin" else (kv_leaf, kv_leaf)
        cross = mac_spec() if cfg.attention_backend == "maclaurin" else (kv_leaf, kv_leaf)
        return {"self": self_, "cross": cross}
    return {"kv": mac_spec() if cfg.attention_backend == "maclaurin" else kv_tuple}
