"""Top-k Mixture-of-Experts FFN with sort-based dispatch (EP-shardable).

Dispatch strategy: tokens are routed to their top-k experts by sorting the
(token, expert) assignment list by expert id and packing into a fixed
(E, C, d) buffer (C = capacity per expert). This keeps FLOPs at
E*C*d*d_ff — i.e. ~active-FLOPs x capacity_factor — unlike the GShard
one-hot-dispatch einsum whose dispatch matmul alone would dwarf the expert
compute at our shapes (napkin math in DESIGN.md §2).

Sharding: the (E, C, d) buffer carries the "experts" logical axis (mapped to
the model mesh axis) — GSPMD turns the scatter/gather into an all-to-all,
the EP pattern. Router math stays token-sharded.

Overflowed tokens (beyond capacity) are dropped (standard Switch behaviour);
their combine weight is zero so the residual path carries them unchanged.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _init

Array = jax.Array


def moe_params(key, d: int, d_ff: int, num_experts: int, *, router_noise: bool = False):
    ks = jax.random.split(key, 4)
    params = {
        "router": _init(ks[0], (d, num_experts), scale=0.02),
        "w_gate": _init(ks[1], (num_experts, d, d_ff)),
        "w_up": _init(ks[2], (num_experts, d, d_ff)),
        "w_down": _init(ks[3], (num_experts, d_ff, d), scale=1.0 / (d_ff**0.5)),
    }
    spec = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ffn"),
        "w_up": ("experts", "embed", "ffn"),
        "w_down": ("experts", "ffn", "embed"),
    }
    return params, spec


def _dispatch_row(xr, expert_idx, gate_vals, E: int, top_k: int, C: int):
    """Sort-based dispatch for ONE batch row. xr: (T, d); idx/gates: (T, k).

    Per-row dispatch keeps the argsort/scatter local to the data shard
    (a global sort would force GSPMD to replicate the whole token set —
    measured 212 GiB/device before this change). Returns (buf (E, C, d),
    combine metadata)."""
    T, d = xr.shape
    e_flat = expert_idx.reshape(-1)                      # (T*k,)
    tok_flat = jnp.repeat(jnp.arange(T), top_k)
    gate_flat = gate_vals.reshape(-1).astype(xr.dtype)
    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    gate_sorted = gate_flat[order]
    first_of_expert = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos_in_expert = jnp.arange(T * top_k) - first_of_expert[e_sorted]
    keep = pos_in_expert < C

    buf = jnp.zeros((E, C, d), xr.dtype)
    scatter_e = jnp.where(keep, e_sorted, E)             # OOB rows dropped
    buf = buf.at[scatter_e, jnp.where(keep, pos_in_expert, 0)].add(
        jnp.where(keep[:, None], xr[tok_sorted], 0.0), mode="drop"
    )
    return buf, (e_sorted, tok_sorted, gate_sorted, pos_in_expert, keep)


def _combine_row(y, meta, T: int, d: int, C: int):
    """Scatter expert outputs back to token order for one row. y: (E, C, d)."""
    e_sorted, tok_sorted, gate_sorted, pos_in_expert, keep = meta
    flat_y = y.reshape(-1, d)
    slot = jnp.where(keep, e_sorted * C + pos_in_expert, 0)
    contrib = flat_y[slot] * jnp.where(keep, gate_sorted, 0.0)[:, None]
    return jnp.zeros((T, d), y.dtype).at[tok_sorted].add(contrib)


def moe_forward(
    params,
    x: Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    return_aux: bool = True,
):
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar).

    Routing/sort/pack are vmapped PER BATCH ROW (local to the data shard);
    expert GEMMs are batched (B, E, C) einsums with the experts axis
    model-sharded (EP — GSPMD inserts the all-to-all at the hint below).
    """
    from repro.sharding.hints import hint

    B, T, d = x.shape
    E = params["router"].shape[1]
    logits = x @ params["router"]                        # (B, T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (B, T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    C = max(1, int(capacity_factor * T * top_k / E))
    buf, meta = jax.vmap(
        lambda xr, ei, gv: _dispatch_row(xr, ei, gv, E, top_k, C)
    )(x, expert_idx, gate_vals)                          # buf: (B, E, C, d)
    buf = hint(buf, "batch", "experts", None, None)      # EP all-to-all here

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, params["w_up"])
    y = jnp.einsum("becf,efd->becd", h, params["w_down"])  # (B, E, C, d)
    y = hint(y, "batch", "experts", None, None)

    out = jax.vmap(lambda yr, m: _combine_row(yr, m, T, d, C))(y, meta)

    if not return_aux:
        return out, jnp.float32(0.0)
    # Switch-style load-balancing aux loss (global over B*T tokens).
    me = jnp.mean(probs, axis=(0, 1))                    # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, E), axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return out, aux
