"""GQA attention: training (full causal), prefill, and cached decode.

Head layout convention: activations (B, T, H, hd) with H ("heads"/"kv_heads")
as the model-sharded logical axis — the Megatron TP pattern (shard heads,
all-reduce after the output projection, which GSPMD inserts from the
shardings of w_o).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import _init, apply_rope

Array = jax.Array


def attention_params(key, d: int, n_heads: int, n_kv: int, head_dim: int, qkv_bias: bool):
    ks = jax.random.split(key, 4)
    params = {
        "w_q": _init(ks[0], (d, n_heads * head_dim)),
        "w_k": _init(ks[1], (d, n_kv * head_dim)),
        "w_v": _init(ks[2], (d, n_kv * head_dim)),
        "w_o": _init(ks[3], (n_heads * head_dim, d), scale=1.0 / ((n_heads * head_dim) ** 0.5)),
    }
    spec = {
        "w_q": ("embed", "heads"),
        "w_k": ("embed", "kv_heads"),
        "w_v": ("embed", "kv_heads"),
        "w_o": ("heads", "embed"),
    }
    if qkv_bias:
        params |= {
            "b_q": jnp.zeros((n_heads * head_dim,), jnp.float32),
            "b_k": jnp.zeros((n_kv * head_dim,), jnp.float32),
            "b_v": jnp.zeros((n_kv * head_dim,), jnp.float32),
        }
        spec |= {"b_q": ("heads",), "b_k": ("kv_heads",), "b_v": ("kv_heads",)}
    return params, spec


def _project_qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta):
    B, T, _ = x.shape
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if "b_q" in params:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(B, T, n_heads, head_dim)
    k = k.reshape(B, T, n_kv, head_dim)
    v = v.reshape(B, T, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _gqa_scores_full(q, k, v, causal: bool, chunk: int = 512,
                     scores_dtype=jnp.float32):
    """q: (B,T,Hq,hd), k/v: (B,S,Hkv,hd). Softmax attention, BLOCKWISE over
    query chunks (lax.scan) so the (T x S) score matrix never materializes —
    peak extra memory is one (B,Hkv,g,chunk,S) slab, rematerialized in bwd
    (each chunk body is jax.checkpoint'ed). Full-softmax rows per chunk (S is
    not chunked), so no online-softmax state is needed.
    """
    B, T, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = 1.0 / (hd**0.5)
    qh = q.reshape(B, T, Hkv, g, hd)

    if T <= chunk:
        return _attn_chunk(qh, k, v, 0, causal, scale, T, scores_dtype).reshape(
            B, T, Hq, hd
        )

    n_chunks = T // chunk
    assert n_chunks * chunk == T, f"T={T} not divisible by attention chunk {chunk}"
    q_c = qh.reshape(B, n_chunks, chunk, Hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    @jax.checkpoint
    def body(offset, qc):
        out = _attn_chunk(qc, k, v, offset, causal, scale, T, scores_dtype)
        return offset + chunk, out

    _, outs = jax.lax.scan(body, jnp.int32(0), q_c)       # (n_chunks, B, c, Hkv, g, hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, Hq, hd)
    return out


def _attn_chunk(qc, k, v, offset, causal: bool, scale: float, T: int,
                scores_dtype=jnp.float32):
    """One query chunk against the full key set. qc: (B,c,Hkv,g,hd).

    scores_dtype=bf16 halves the dominant HBM slab; max is exact in bf16,
    exp is elementwise, and the normalizer still accumulates in f32 (the
    convert fuses into the reduction — the slab itself stays bf16)."""
    c = qc.shape[1]
    S = k.shape[1]
    # accumulate via preferred_element_type — NOT by converting the inputs
    # (XLA would hoist the convert over the whole K tensor/cache).
    u = jnp.einsum(
        "bthgd,bshd->bhgts", qc, k, preferred_element_type=scores_dtype
    ) * scale
    if causal:
        rows = offset + jnp.arange(c)[:, None] + (S - T)   # global query positions
        cols = jnp.arange(S)[None, :]
        u = jnp.where(rows >= cols, u, jnp.asarray(-jnp.inf, u.dtype))
    m = jnp.max(u, axis=-1, keepdims=True)
    e = jnp.exp(u - m)
    den = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
    w = (e / den.astype(e.dtype)).astype(qc.dtype)
    return jnp.einsum("bhgts,bshd->bthgd", w, v)


def self_attention(
    params, x, *, n_heads, n_kv, head_dim, positions, rope_theta=10000.0,
    causal=True, scores_dtype=jnp.float32
):
    """Training/prefill path: full attention over the sequence."""
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta)
    out = _gqa_scores_full(q, k, v, causal, scores_dtype=scores_dtype)
    B, T = x.shape[:2]
    return out.reshape(B, T, n_heads * head_dim) @ params["w_o"]


class KVCache(NamedTuple):
    """Per-layer-stacked KV cache: k/v (L, B, S, Hkv, hd), pos scalar int32."""

    k: Array
    v: Array


def decode_attention(
    params, x, cache_k, cache_v, pos, *, n_heads, n_kv, head_dim, rope_theta=10000.0
):
    """One-token cached decode. x: (B, 1, d); cache_k/v: (B, S, Hkv, hd).

    Returns (out (B,1,d), new_k, new_v). Reads the FULL cache (the memory-
    bound op the roofline sees) and writes one slot.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    S = cache_k.shape[1]
    Hkv = cache_k.shape[2]
    g = n_heads // Hkv
    qh = q.reshape(B, 1, Hkv, g, head_dim)
    scale = 1.0 / (head_dim**0.5)
    u = jnp.einsum(
        "bthgd,bshd->bhgts", qh, cache_k, preferred_element_type=jnp.float32
    ) * scale
    valid = (jnp.arange(S) <= pos)[None, None, None, None, :]
    u = jnp.where(valid, u, -jnp.inf)
    w = jax.nn.softmax(u, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", w, cache_v.astype(q.dtype))
    out = out.reshape(B, 1, n_heads * head_dim) @ params["w_o"]
    return out, cache_k, cache_v


# int8 KV quantization granularity: symmetric scale per (token, head,
# KV_QUANT_GROUP-channel group). Per-token-per-head scales (one scale over
# the whole head_dim) lose argmax parity vs the fp path on small models —
# one outlier channel inflates the scale and the other channels' resolution
# collapses; 16-channel groups restore exact argmax agreement on the
# tests/test_serve.py workload at 1/4 the scale overhead of per-channel.
KV_QUANT_GROUP = 16


def _kv_group(head_dim: int) -> int:
    """Channels per scale group: the largest divisor of head_dim that is
    <= KV_QUANT_GROUP (gcd), so grouping works for ANY head_dim — an odd
    width degrades toward finer scales, never toward a reshape error."""
    return math.gcd(head_dim, KV_QUANT_GROUP)


def kv_quant_groups(head_dim: int) -> int:
    """Scale entries per (token, head); init_cache sizes the scale caches
    with this so it stays in lock-step with decode_attention_quant."""
    return head_dim // _kv_group(head_dim)


def decode_attention_quant(
    params, x, cache_k, cache_v, k_scale, v_scale, pos,
    *, n_heads, n_kv, head_dim, rope_theta=10000.0
):
    """Cached decode with an INT8 KV cache (grouped sub-channel symmetric
    scales — the KIVI/KVQuant family). Exactly equivalent math: the cache
    tiles are dequantized group-wise in registers right before the dot,

        k_s = k_int8_s,g * kscale_s,g          (g = 16-channel group)
        sum_s w_ts v_s = sum_s w_ts (v_int8_s,g * vscale_s,g)

    so the int8 tensors are what crosses HBM. Halves cache traffic AND
    capacity vs bf16 (the decode roofline lever identified in
    EXPERIMENTS.md §Roofline notes)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta)
    group = _kv_group(head_dim)
    G = head_dim // group

    def quantize(t):  # (B, 1, Hkv, hd) -> int8 + (B, 1, Hkv, G) group scales
        tg = t.reshape(*t.shape[:-1], G, group)
        s = jnp.max(jnp.abs(tg), axis=-1, keepdims=True) / 127.0 + 1e-9
        q8 = jnp.clip(jnp.round(tg / s), -127, 127).astype(jnp.int8)
        return q8.reshape(t.shape), s[..., 0]

    def dequantize(c8, s):  # (B, S, Hkv, hd) int8 + (B, S, Hkv, G) -> f32
        cg = c8.astype(jnp.float32).reshape(*c8.shape[:-1], G, group)
        return (cg * s[..., None]).reshape(c8.shape)

    kq, ks = quantize(k)
    vq, vs = quantize(v)
    cache_k = jax.lax.dynamic_update_slice(cache_k, kq, (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, vq, (0, pos, 0, 0))
    k_scale = jax.lax.dynamic_update_slice(k_scale, ks.astype(k_scale.dtype), (0, pos, 0, 0))
    v_scale = jax.lax.dynamic_update_slice(v_scale, vs.astype(v_scale.dtype), (0, pos, 0, 0))

    S, Hkv = cache_k.shape[1], cache_k.shape[2]
    g = n_heads // Hkv
    qh = q.reshape(B, 1, Hkv, g, head_dim)
    scale = 1.0 / (head_dim**0.5)
    u = jnp.einsum(
        "bthgd,bshd->bhgts", qh, dequantize(cache_k, k_scale).astype(q.dtype),
        preferred_element_type=jnp.float32,
    ) * scale
    valid = (jnp.arange(S) <= pos)[None, None, None, None, :]
    u = jnp.where(valid, u, -jnp.inf)
    w = jax.nn.softmax(u, axis=-1)
    out = jnp.einsum(
        "bhgts,bshd->bthgd", w.astype(q.dtype),
        dequantize(cache_v, v_scale).astype(q.dtype),
    )
    out = out.reshape(B, 1, n_heads * head_dim) @ params["w_o"]
    return out, cache_k, cache_v, k_scale, v_scale


def cross_attention_params(key, d: int, n_heads: int, n_kv: int, head_dim: int):
    ks = jax.random.split(key, 4)
    params = {
        "w_q": _init(ks[0], (d, n_heads * head_dim)),
        "w_k": _init(ks[1], (d, n_kv * head_dim)),
        "w_v": _init(ks[2], (d, n_kv * head_dim)),
        "w_o": _init(ks[3], (n_heads * head_dim, d), scale=1.0 / ((n_heads * head_dim) ** 0.5)),
    }
    spec = {
        "w_q": ("embed", "heads"),
        "w_k": ("embed", "kv_heads"),
        "w_v": ("embed", "kv_heads"),
        "w_o": ("heads", "embed"),
    }
    return params, spec


def cross_attention(params, x, ctx, *, n_heads, n_kv, head_dim):
    """Queries from x (B,T,d), keys/values from ctx (B,N,d). No mask, no RoPE
    (the Llama-3.2-vision convention for image cross-attention)."""
    B, T, _ = x.shape
    N = ctx.shape[1]
    q = (x @ params["w_q"]).reshape(B, T, n_heads, head_dim)
    k = (ctx @ params["w_k"]).reshape(B, N, n_kv, head_dim)
    v = (ctx @ params["w_v"]).reshape(B, N, n_kv, head_dim)
    out = _gqa_scores_full(q, k, v, causal=False)
    return out.reshape(B, T, n_heads * head_dim) @ params["w_o"]
