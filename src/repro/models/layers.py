"""Shared transformer layer primitives: norms, RoPE, FFN, embeddings.

All parameters are plain dict pytrees. Every creation helper returns
(params, spec) where spec mirrors the params tree with logical-axis tuples
used by repro.sharding.partitioning to derive NamedShardings. Logical axes:

  "vocab"   — vocabulary dim (model-sharded)
  "embed"   — d_model dim (replicated)
  "heads"   — flattened attention head dim (model-sharded)
  "kv_heads"— kv head dim (model-sharded)
  "ffn"     — feed-forward hidden dim (model-sharded)
  "experts" — MoE expert dim (model-sharded)
  None      — replicated
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / (shape[0] ** 0.5)
    return jax.random.normal(key, shape, dtype) * scale


# ---------------------------------------------------------------- norms


def rmsnorm_params(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


def rmsnorm(params, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dtype)


# ---------------------------------------------------------------- RoPE


def rope_frequencies(head_dim: int, max_pos: int, theta: float = 10000.0) -> Array:
    """(max_pos, head_dim//2) complex-free cos/sin table; computed lazily."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(t, inv)  # (max_pos, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (B, T, H, hd); positions: (T,) or (B, T)."""
    hd = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., T, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if cos.ndim == 2:  # (T, hd/2) -> broadcast over batch
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, T, hd/2)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------- FFN


def swiglu_params(key, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_gate": _init(k1, (d, d_ff)),
        "w_up": _init(k2, (d, d_ff)),
        "w_down": _init(k3, (d_ff, d), scale=1.0 / (d_ff**0.5)),
    }
    spec = {
        "w_gate": ("embed", "ffn"),
        "w_up": ("embed", "ffn"),
        "w_down": ("ffn", "embed"),
    }
    return params, spec


def swiglu(params, x: Array) -> Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------- embeddings


def embedding_params(key, vocab: int, d: int):
    return (
        {"table": _init(key, (vocab, d), scale=0.02)},
        {"table": ("vocab", "embed")},
    )


def embed(params, tokens: Array) -> Array:
    return params["table"][tokens]


def unembed(params, x: Array) -> Array:
    """Tied readout: logits over the (model-sharded) vocab axis."""
    return x @ params["table"].T


def lm_head_params(key, d: int, vocab: int):
    return {"w": _init(key, (d, vocab), scale=0.02)}, {"w": ("embed", "vocab")}


def lm_head(params, x: Array) -> Array:
    return x @ params["w"]


# ---------------------------------------------------------------- losses


def softmax_xent(logits: Array, labels: Array) -> Array:
    """Mean token cross-entropy; stable logsumexp; logits (B,T,V) f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
