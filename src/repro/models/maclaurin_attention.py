"""Maclaurin linear attention as a drop-in decoder-attention backend.

This is the paper's technique operating as attention (DESIGN.md §4):
the KV set plays the support vectors, the query plays the test instance,
and the running moment state (S0..S2) is the (c, v, M) quadratic form.
Decode cost/state is O(d_k^2 d_v) per head — independent of context length,
exactly as the paper's predictor is independent of n_sv.

State layout per (batch, kv-head):
    s1  (d_k, d_v)      sum_j k_j v_j^T          — the paper's  v = Xw
    s2  (d_k^2, d_v)    sum_j phi2(k_j) v_j^T    — the paper's  M = XDX^T
    k1  (d_k,)          sum_j k_j                |
    k2  (d_k^2,)        sum_j phi2(k_j)          |- normalizer moments
    n   ()              count                    |
    v0  (d_v,)          sum_j v_j                — order-0 numerator

The Eq 3.11 analogue: validity needs |q.k|/sqrt(d) < 1/2; we track
max ||k||^2 in the state so serving can check  ||q||^2 max||k||^2 < d/4
per query at no extra cost (`readout` returns the flag).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class MacState(NamedTuple):
    s1: Array   # (..., d_k, d_v)
    s2: Array   # (..., d_k*d_k, d_v)
    k1: Array   # (..., d_k)
    k2: Array   # (..., d_k*d_k)
    n: Array    # (..., 1)
    v0: Array   # (..., d_v)
    max_k_sq: Array  # (..., 1)


def init_state(batch_dims: tuple[int, ...], d_k: int, d_v: int, dtype=jnp.float32) -> MacState:
    z = lambda *s: jnp.zeros(batch_dims + s, dtype)
    return MacState(
        s1=z(d_k, d_v), s2=z(d_k * d_k, d_v), k1=z(d_k), k2=z(d_k * d_k),
        n=z(1), v0=z(d_v), max_k_sq=z(1),
    )


def _phi2(x: Array) -> Array:
    """vec(x x^T) over the last axis: (..., d) -> (..., d*d)."""
    d = x.shape[-1]
    return (x[..., :, None] * x[..., None, :]).reshape(*x.shape[:-1], d * d)


def extend_state(state: MacState, k: Array, v: Array) -> MacState:
    """Absorb a block of tokens. k: (..., T, d_k), v: (..., T, d_v)."""
    k2f = _phi2(k)
    t = k.shape[-2]
    return MacState(
        s1=state.s1 + jnp.einsum("...td,...tv->...dv", k, v),
        s2=state.s2 + jnp.einsum("...tp,...tv->...pv", k2f, v),
        k1=state.k1 + jnp.sum(k, axis=-2),
        k2=state.k2 + jnp.sum(k2f, axis=-2),
        n=state.n + jnp.float32(t),
        v0=state.v0 + jnp.sum(v, axis=-2),
        max_k_sq=jnp.maximum(
            state.max_k_sq, jnp.max(jnp.sum(k * k, axis=-1), axis=-1, keepdims=True)
        ),
    )


def readout(state: MacState, q: Array, scale: float | None = None):
    """Evaluate the quadratic form for queries q (..., T, d_k).

    Returns (out (..., T, d_v), valid (..., T)) — `valid` is the Eq 3.11
    analogue computed from ||q||^2 · max||k||^2 · scale^2 < 1/4.
    """
    d_k = q.shape[-1]
    if scale is None:
        scale = 1.0 / float(d_k) ** 0.5
    q2 = _phi2(q)
    num = (
        state.v0[..., None, :]
        + scale * jnp.einsum("...td,...dv->...tv", q, state.s1)
        + (0.5 * scale * scale) * jnp.einsum("...tp,...pv->...tv", q2, state.s2)
    )
    den = (
        state.n
        + scale * jnp.einsum("...td,...d->...t", q, state.k1)
        + (0.5 * scale * scale) * jnp.einsum("...tp,...p->...t", q2, state.k2)
    )
    q_sq = jnp.sum(q * q, axis=-1)
    valid = (scale * scale) * q_sq * state.max_k_sq < 0.25
    return num / den[..., None], valid


def maclaurin_attention_gqa(
    q: Array, k: Array, v: Array, scale: float | None = None, use_kernel: bool = False
):
    """Full-sequence causal maclaurin attention with GQA head layout.

    q: (B, T, Hq, hd), k/v: (B, T, Hkv, hd) -> (B, T, Hq, hd).

    ``use_kernel=True`` routes through the chunked Pallas kernel (O(chunk*d^2)
    working set — the production path); the default is the O(T^2)-scores jnp
    form, identical math, used for tests and short prefills and safe to
    lower under GSPMD.
    """
    B, T, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    # Expand kv heads to query heads (GQA) and move to (B, H, T, d).
    kq = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)
    vq = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
    qq = q.transpose(0, 2, 1, 3)
    if use_kernel:
        from repro.kernels.maclaurin_attn import maclaurin_attention

        out = maclaurin_attention(qq, kq, vq, scale=scale)
    elif T >= 1024:
        # long sequences: chunked state form (GSPMD-shardable, O(c^2+d^2 dv))
        out = maclaurin_attention_chunked(qq, kq, vq, scale=scale)
    else:
        from repro.kernels.maclaurin_attn.ref import maclaurin_attention_ref

        out = maclaurin_attention_ref(qq, kq, vq, scale=scale)
    return out.transpose(0, 2, 1, 3)


def maclaurin_attention_chunked(
    q: Array, k: Array, v: Array, scale: float | None = None, chunk: int = 256
):
    """Chunked causal Maclaurin attention in pure jnp (GSPMD-shardable).

    Same math as the Pallas kernel (intra-chunk exact quadratic + inter-chunk
    moment state), expressed with a lax.scan so it lowers under pjit for the
    dry-run and long-context TRAINING. Working set per step:
    O(chunk^2 + d_k^2 d_v) instead of O(T^2).

    q,k,v: (B, H, T, d) -> (B, H, T, d_v).
    """
    B, H, T, d = q.shape
    dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    n_chunks = T // chunk
    assert n_chunks * chunk == T, f"T={T} % chunk={chunk}"
    rs = lambda t: t.reshape(B, H, n_chunks, chunk, -1).transpose(2, 0, 1, 3, 4)
    q_c, k_c, v_c = rs(q), rs(k), rs(v)

    def body(state, inp):
        s1, s2, k1, k2, n, v0 = state
        qc, kc, vc = inp                                  # (B,H,c,d)
        u = scale * jnp.einsum("bhtd,bhsd->bhts", qc, kc)
        w = 1.0 + u + 0.5 * u * u
        tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        w = jnp.where(tri, w, 0.0)
        num = jnp.einsum("bhts,bhsv->bhtv", w, vc)
        den = jnp.sum(w, axis=-1)
        q2 = _phi2(qc)
        num = num + v0[:, :, None, :]
        num = num + scale * jnp.einsum("bhtd,bhdv->bhtv", qc, s1)
        num = num + 0.5 * scale * scale * jnp.einsum("bhtp,bhpv->bhtv", q2, s2)
        den = den + n[..., None]
        den = den + scale * jnp.einsum("bhtd,bhd->bht", qc, k1)
        den = den + 0.5 * scale * scale * jnp.einsum("bhtp,bhp->bht", q2, k2)
        out = num / den[..., None]
        k2f = _phi2(kc)
        state = (
            s1 + jnp.einsum("bhtd,bhtv->bhdv", kc, vc),
            s2 + jnp.einsum("bhtp,bhtv->bhpv", k2f, vc),
            k1 + jnp.sum(kc, axis=2),
            k2 + jnp.sum(k2f, axis=2),
            n + jnp.float32(chunk),
            v0 + jnp.sum(vc, axis=2),
        )
        return state, out

    z = lambda *s: jnp.zeros((B, H) + s, jnp.float32)
    init = (z(d, dv), z(d * d, dv), z(d), z(d * d), z(1)[..., 0], z(dv))
    qf, kf, vf = q_c.astype(jnp.float32), k_c.astype(jnp.float32), v_c.astype(jnp.float32)
    _, outs = jax.lax.scan(body, init, (qf, kf, vf))
    return outs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, dv).astype(v.dtype)
