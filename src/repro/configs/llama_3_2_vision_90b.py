"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision scaled] —
100 layers: cross-attention to image tokens every 5th layer (20 cross +
80 self). Vision frontend is a STUB: input_specs() provides precomputed
patch embeddings (B, 4096, d)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,   # 100 // 5 = 20 cross-attn layers
    n_image_tokens=4096,
)
