"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128-expert top-8 MoE,
per-expert FFN hidden 768, GQA 32/4, head_dim 128."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,             # listed d_ff == per-expert hidden
    vocab_size=151936,
    moe_num_experts=128,
    moe_top_k=8,
    moe_d_ff=768,
)
