"""Model/run configuration dataclasses + the input-shape suite.

Every assigned architecture is a ``ModelConfig`` in its own module
(src/repro/configs/<id>.py) built from the public-literature numbers in the
brief. ``reduced()`` shrinks any config to a CPU-smoke-testable size while
preserving the family topology (MoE stays MoE, hybrid stays hybrid, ...).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    hybrid_attn_every: int = 0      # zamba2: shared attn block every k mamba layers
    # --- rwkv ---
    rwkv_head_dim: int = 64
    # --- vlm ---
    cross_attn_every: int = 0       # 1 cross-attn layer per k self-attn layers
    n_image_tokens: int = 0
    # --- execution ---
    attention_backend: str = "softmax"  # softmax | maclaurin (paper technique)
    remat: bool = True
    dtype: str = "bfloat16"
    scan_chunk: int = 128           # SSD / linear-attn chunk length
    attn_scores_dtype: str = "float32"  # float32 | bfloat16 (perf option:
    # halves the dominant HBM term of the unfused blockwise attention;
    # softmax stats still accumulate in f32 — see EXPERIMENTS.md §Perf)
    attention_impl: str = "blockwise"   # blockwise (jnp, GSPMD-shardable) |
    # flash (fused Pallas kernel kernels/flash_attn — single-device or
    # shard_map contexts; removes the score-slab HBM term entirely)
    kv_cache_dtype: str = "bfloat16"    # bfloat16 | int8 (grouped sub-channel
    # symmetric scales, one per (token, head, KV_QUANT_GROUP channels) —
    # see models/attention.py; ~2x on the decode memory term — §Perf)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def with_backend(self, backend: str) -> "ModelConfig":
        return dataclasses.replace(self, attention_backend=backend)

    def reduced(self) -> "ModelConfig":
        """Family-preserving shrink for CPU smoke tests."""
        r_hybrid_every = min(self.hybrid_attn_every, 2) if self.hybrid_attn_every else 0
        r_cross_every = min(self.cross_attn_every, 2) if self.cross_attn_every else 0
        if self.family == "hybrid":
            n_layers = 2 * r_hybrid_every      # 2 groups of mamba + shared attn
        elif self.family == "vlm":
            n_layers = 2 * r_cross_every       # 2 super-blocks (self+cross)
        else:
            n_layers = 2
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            moe_num_experts=min(self.moe_num_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=128 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            rwkv_head_dim=32,
            n_image_tokens=16 if self.n_image_tokens else 0,
            hybrid_attn_every=r_hybrid_every,
            cross_attn_every=r_cross_every,
            scan_chunk=16,
            dtype="float32",
            remat=False,
        )

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.hd
        emb = V * d * 2  # embed + head
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            per_layer = 4 * d * d + d * d + 2 * d * 64 + 2 * d * self.d_ff + d * d
        else:
            attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
            if self.family == "hybrid":
                d_in = self.ssm_expand * d
                mamba = d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * d
                shared = attn + 3 * d * self.d_ff
                return emb + L * mamba + shared
            if self.moe_num_experts:
                ffn = 3 * d * self.moe_d_ff * self.moe_num_experts + d * self.moe_num_experts
                if self.moe_dense_residual:
                    ffn += 3 * d * self.d_ff
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn
            if self.cross_attn_every:
                # every k-th layer is cross-attn (same shapes as self-attn + ffn)
                pass
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if not self.moe_num_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.hd
        emb = self.vocab_size * d * 2
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        ffn = 3 * d * self.moe_d_ff * self.moe_top_k + d * self.moe_num_experts
        if self.moe_dense_residual:
            ffn += 3 * d * self.d_ff
        return emb + L * (attn + ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the evaluation grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
