"""Architecture registry: ``get_config(name)`` / ``ARCHS``."""

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.configs import (
    zamba2_2_7b,
    phi3_mini_3_8b,
    smollm_135m,
    yi_34b,
    qwen2_0_5b,
    rwkv6_7b,
    qwen3_moe_30b_a3b,
    arctic_480b,
    llama_3_2_vision_90b,
    musicgen_medium,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        zamba2_2_7b.CONFIG,
        phi3_mini_3_8b.CONFIG,
        smollm_135m.CONFIG,
        yi_34b.CONFIG,
        qwen2_0_5b.CONFIG,
        rwkv6_7b.CONFIG,
        qwen3_moe_30b_a3b.CONFIG,
        arctic_480b.CONFIG,
        llama_3_2_vision_90b.CONFIG,
        musicgen_medium.CONFIG,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_config"]
