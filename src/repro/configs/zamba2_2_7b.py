"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + ONE shared
attention+MLP block applied every 6 Mamba layers (weight-shared)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,          # 2560 / 32
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,  # 54 mamba layers -> 9 shared-attn applications
)
