"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense FFN
residual in PARALLEL with a 128-expert top-2 MoE per layer."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,            # the dense-residual FFN hidden
    vocab_size=32000,
    moe_num_experts=128,
    moe_top_k=2,
    moe_d_ff=4864,
    moe_dense_residual=True,
)
