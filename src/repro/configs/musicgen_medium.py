"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only transformer over
EnCodec tokens (vocab 2048). The EnCodec codec frontend is a STUB: the
model consumes the post-codec token stream (codebook-interleaved)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
)
