"""RWKV6-7B 'Finch' [arXiv:2404.05892; hf] — attention-free, data-dependent
decay. The paper's Maclaurin technique is INAPPLICABLE here (DESIGN.md §7):
no exponential-of-inner-product exists; decode is already O(d) state."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # 4096 / 64 rwkv heads (bookkeeping only)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
)
