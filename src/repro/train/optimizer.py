"""Optimizers built from scratch (no optax in this container).

AdamW — the default. Adafactor (beta1=0, factored second moment over the
last two axes) — for the 480B-class models where full Adam moments blow the
per-device HBM budget even at 256-way sharding (napkin math in DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ------------------------------------------------------------- schedules


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup)
    frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda l: (l * scale).astype(l.dtype), grads), gnorm


# ------------------------------------------------------------- AdamW


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1
):
    count = state["count"] + 1
    c = count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**c)
        vh = v / (1 - b2**c)
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}


# ------------------------------------------------------------- Adafactor


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),       # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"v": jax.tree.map(init, params), "count": jnp.zeros((), jnp.int32)}


def adafactor_update(
    params, grads, state, lr, *, b2=0.999, eps=1e-30, weight_decay=0.0, clip=1.0
):
    count = state["count"] + 1

    def upd(p, g, s):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            vr = b2 * s["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * s["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
            u = g * jax.lax.rsqrt(vhat + eps)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = b2 * s["v"] + (1 - b2) * g2
            u = g * jax.lax.rsqrt(v + eps)
            new_s = {"v": v}
        # update clipping (Adafactor's RMS rule)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip)
        newp = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), new_s

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["v"])
    res = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = tdef.unflatten([r[0] for r in res])
    new_v = tdef.unflatten([r[1] for r in res])
    return new_params, {"v": new_v, "count": count}
