from repro.train.optimizer import (
    adamw_init, adamw_update, adafactor_init, adafactor_update,
    cosine_schedule, clip_by_global_norm,
)
from repro.train.train_step import make_train_step, make_eval_step

__all__ = [
    "adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
    "cosine_schedule", "clip_by_global_norm", "make_train_step", "make_eval_step",
]
