"""Train / eval step factories.

make_train_step builds a pure (params, opt_state, batch, step) ->
(params, opt_state, metrics) function ready for jax.jit with NamedSharding
in/out specs (see launch/dryrun.py and launch/train.py). Features:

  * token cross-entropy + MoE aux loss
  * microbatch gradient accumulation (lax.scan over microbatches)
  * global-norm clipping
  * AdamW or Adafactor (cfg-selected)
  * optional int8 error-feedback gradient compression (cross-pod wire
    format; see train/compression.py)

The remat policy lives inside the model (cfg.remat -> jax.checkpoint per
layer inside the scan).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import softmax_xent
from repro.models.transformer import forward
from repro.train import optimizer as opt
from repro.train import compression

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | adafactor
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    aux_loss_weight: float = 0.01
    microbatches: int = 1
    compress_grads: bool = False  # int8 error-feedback (cross-pod wire)


def make_loss_fn(cfg: ModelConfig, aux_weight: float = 0.01) -> Callable:
    from repro.sharding.hints import hint

    def loss_fn(params, batch):
        logits, aux = forward(
            cfg, params, batch["tokens"], batch.get("image_embeds")
        )
        # keep the (B, T, V) slab sharded over batch AND vocab — GSPMD turns
        # the logsumexp/gather in the loss into local ops + tiny collectives
        logits = hint(logits, "batch", None, "vocab")
        xent = softmax_xent(logits, batch["labels"])
        return xent + aux_weight * aux, {"xent": xent, "aux": aux}

    return loss_fn


def init_opt_state(ocfg: OptimizerConfig, params):
    state = (
        opt.adafactor_init(params) if ocfg.name == "adafactor" else opt.adamw_init(params)
    )
    if ocfg.compress_grads:
        state["ef"] = compression.init_error_feedback(params)
    return state


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig) -> Callable:
    loss_fn = make_loss_fn(cfg, ocfg.aux_loss_weight)

    def train_step(params, opt_state, batch, step):
        if ocfg.microbatches > 1:
            n = ocfg.microbatches
            split = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
            )

            def micro(acc, mb):
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                acc_g, acc_l = acc
                return (
                    jax.tree.map(jnp.add, acc_g, grads),
                    acc_l + loss / n,
                ), metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(micro, (zeros, 0.0), split)
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )

        if ocfg.compress_grads:
            grads, new_ef = compression.compress_decompress(
                grads, opt_state["ef"]
            )
        grads, gnorm = opt.clip_by_global_norm(grads, ocfg.clip_norm)
        lr = opt.cosine_schedule(
            step, peak_lr=ocfg.peak_lr, warmup=ocfg.warmup, total=ocfg.total_steps
        )
        if ocfg.name == "adafactor":
            new_params, new_state = opt.adafactor_update(
                params, grads, opt_state, lr, weight_decay=ocfg.weight_decay
            )
        else:
            new_params, new_state = opt.adamw_update(
                params, grads, opt_state, lr, weight_decay=ocfg.weight_decay
            )
        if ocfg.compress_grads:
            new_state["ef"] = new_ef
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(cfg, 0.0)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return dict(metrics, loss=loss)

    return eval_step
