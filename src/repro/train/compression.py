"""Int8 error-feedback gradient compression (cross-pod wire format).

At 512+ chips the cross-pod hop rides DCN, ~10x slower than ICI; 4x smaller
gradients is a direct 4x on that term. We use per-tensor symmetric int8
quantization with error feedback (Seide et al. 2014; 1-bit Adam lineage):
the quantization residual is carried into the next step, so the *average*
gradient is unbiased and convergence is preserved (tested in
tests/test_train.py::test_compressed_training_converges).

Deployment note (honesty ledger, DESIGN.md §9): inside a single jit program
GSPMD chooses the collective implementation; the quantize/dequantize pair
here expresses the wire format and its numerics. On a real multi-pod run the
pair brackets the cross-pod all-reduce via a custom lowering rule or a
shard_map'd collective; here we apply it to the assembled gradient, which is
numerically identical for a single reduction step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, ef):
    """Returns (decompressed grads, new error feedback)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    out = jax.tree.map(one, grads, ef)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_ef


def wire_bytes(params) -> int:
    """Bytes on the cross-pod wire per step with int8 (vs 4 bytes f32)."""
    return sum(l.size for l in jax.tree.leaves(params))
