"""Checkpointing: atomic, manifest-versioned, async-capable, reshard-on-restore.

Layout:
    <dir>/step_<N>/arrays.npz      flattened param/opt pytree ('/'-joined keys)
    <dir>/step_<N>/manifest.json   step, tree structure, shapes, dtypes
    <dir>/LATEST                   atomic pointer file (rename-committed)

Fault-tolerance contract (DESIGN.md §6):
  * save is crash-safe: written to step_<N>.tmp, fsync'd, renamed; LATEST is
    updated last, also by rename. A death at any point leaves a valid
    previous checkpoint.
  * restore(mesh, shardings) device_puts each array with the CURRENT mesh's
    NamedSharding — restoring onto a different topology (elastic downsize
    after a node failure) is the same code path.
  * async_save offloads serialization to a worker thread; training continues
    (the arrays are snapshotted to host first — consistent point-in-time).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"#{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Synchronous crash-safe save. Returns the committed directory."""
    flat = _flatten(tree)
    treedef = jax.tree.structure(tree)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        os.rename(final, final + ".old")
    os.rename(tmp, final)
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    old = final + ".old"
    if os.path.exists(old):
        import shutil

        shutil.rmtree(old)
    return final


class AsyncCheckpointer:
    """One-in-flight async saver: snapshot to host, write on a thread."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None
        self.last_committed: int | None = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # point-in-time snapshot

        def work():
            save(self.ckpt_dir, step, host_tree)
            self.last_committed = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip())
    except FileNotFoundError:
        return None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any | None = None) -> Any:
    """Restore into the structure of ``like``; optionally device_put with new
    shardings (elastic-remesh path)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat = {k: data[k] for k in data.files}
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree.structure(like)
    out = []
    for p, leaf in leaves_with_path:
        key = SEP.join(_path_str(x) for x in p)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), f"{key}: {arr.shape} != {leaf.shape}"
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree
