"""Degree-2 polynomial kernel models and their exact quadratic-form expansion.

Section 3.2 of the paper contrasts the *approximated* RBF model with an
*exact* degree-2 polynomial kernel model

    kappa(x_i, x_j) = (gamma x_i^T x_j + beta)^2            (Eq 3.12)

whose decision function expands exactly (Eqs 3.13-3.16, beta fixed at 1
to expose the correspondence) into the same quadratic form minus the
exp(-gamma ||z||^2) envelope and with different 2nd-order weighting:

    RBF approx:  w_i = 2 gamma a_i e^{-g||x_i||^2},  D_ii = 2 gamma^2 a_i e^{-g||x_i||^2}
    poly-2:      w_i = 2 beta gamma a_i,             D_ii = gamma^2 a_i

This module implements both the kernel-sum form and the collapsed quadratic
form of the poly-2 model (the collapse is *exact* here), used in tests to
verify the §3.2 equivalences.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.maclaurin import ApproxModel

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Poly2Model:
    """Exact kernel-expansion model with the degree-2 polynomial kernel."""

    X: Array          # (n_sv, d)
    alpha_y: Array    # (n_sv,)
    b: Array
    gamma: Array
    beta: Array


def poly2_kernel(Xa: Array, Xb: Array, gamma: Array, beta: Array) -> Array:
    return (gamma * (Xa @ Xb.T) + beta) ** 2


@jax.jit
def decision_function(model: Poly2Model, Z: Array) -> Array:
    """Exact kernel-sum form: O(n_sv d) per row."""
    K = poly2_kernel(Z, model.X, model.gamma, model.beta)
    return K @ model.alpha_y + model.b


@jax.jit
def collapse(model: Poly2Model) -> ApproxModel:
    """Exact O(d^2) collapse of a poly-2 model (Eqs 3.14-3.16, general beta).

    (gamma x^T z + beta)^2 = beta^2 + 2 beta gamma x^T z + gamma^2 (x^T z)^2
      c = beta^2 sum_i a_i
      w_i = 2 beta gamma a_i      -> v = X^T w
      D_ii = gamma^2 a_i          -> M = X^T D X

    Returned as an ApproxModel with gamma=0 so that the exp(-gamma ||z||^2)
    envelope in approx_decision_function degenerates to 1 — making the
    relation of §3.2 executable: the ONLY differences vs an approximated RBF
    model are the envelope and the (2x, e^{-g||x||^2}) re-weightings.
    """
    X, ay = model.X, model.alpha_y
    c = model.beta**2 * jnp.sum(ay)
    w = 2.0 * model.beta * model.gamma * ay
    v = X.T @ w
    dvals = model.gamma**2 * ay
    M = jnp.einsum("i,ij,ik->jk", dvals, X, X)
    sv_sq = jnp.sum(X * X, axis=-1)
    return ApproxModel(
        c=c,
        v=v,
        M=M,
        b=model.b,
        gamma=jnp.zeros_like(model.gamma),  # kills the envelope: exp(0)=1
        max_sv_sq_norm=jnp.max(sv_sq),
    )


@jax.jit
def collapse_rbf_as_poly2(model) -> ApproxModel:
    """Approximate an exact RBF model by the §3.2 poly-2 expansion.

    The remark under Eq 3.16 run in reverse: fold the SV-side exponential
    into the support values (``equivalent_poly2_alphas``), expand
    e^{2 gamma x^T z} as (1 + gamma x^T z)^2 — the beta = 1 poly-2 kernel —
    and KEEP the exp(-gamma ||z||^2) envelope:

        f(z) ~ e^{-g||z||^2} sum_i a_i' (1 + 2 g x_i^T z + g^2 (x_i^T z)^2) + b

        c = sum_i a_i',  w_i = 2 gamma a_i',  D_ii = gamma^2 a_i'

    Identical serving cost to the Maclaurin collapse (same quadratic form,
    same Eq 3.11 envelope check) but the per-term relative error bound is
    ``POLY2_REL_ERR_AT_HALF`` (7.26%) instead of 3.05% — the second-order
    coefficient is x^2/4, not x^2/2. This is the second point of the
    approximation-family axis, not a replacement for ``collapse`` (which
    is the EXACT collapse of a genuinely poly-2-trained model).
    """
    X, gamma = model.X, model.gamma
    sv_sq = jnp.sum(X * X, axis=-1)
    a2 = equivalent_poly2_alphas(model.alpha_y, sv_sq, gamma)
    c = jnp.sum(a2)
    v = X.T @ (2.0 * gamma * a2)
    M = jnp.einsum("i,ij,ik->jk", gamma**2 * a2, X, X)
    return ApproxModel(
        c=c,
        v=v,
        M=M,
        b=model.b,
        gamma=gamma,                       # envelope + Eq 3.11 check stay live
        max_sv_sq_norm=jnp.max(sv_sq),
    )


def equivalent_poly2_alphas(alpha_y_rbf: Array, sv_sq_norms: Array, gamma: Array) -> Array:
    """The paper's remark: alpha_i^(2D) = alpha_i^(RBF) e^{-gamma ||x_i||^2}.

    Folding the SV-side exponential scaling into the poly-2 support values
    makes the two models' c/v terms (beta=1) match up to the documented
    2x second-order weighting and the test-side envelope.
    """
    return alpha_y_rbf * jnp.exp(-gamma * sv_sq_norms)
