"""``compile_model`` — the paper's §4 verification protocol as the
entry point of the serving stack.

The paper validates the Maclaurin approximation BEFORE deploying it by
scoring sample data against the exact model. ``compile_model`` runs that
protocol across every registered approximation family: compile each
candidate, measure its error against the exact expansion and its serving
latency on the live device, and return the CHEAPEST artifact whose
error meets the budget. The full per-family report ships inside the
winner's meta (``compile_report``) so the decision is auditable from the
artifact file alone.

Latency is measured, not modeled (the paper's own methodology — and the
ordering genuinely differs across hosts: the quadform families win at
small d, fourier's O(F d) can win at large d where d^2 explodes, and on
TPU the fused kernels shift the crossover again).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.families.base import CompiledArtifact, stack_heads
from repro.core.rbf import SVMModel, rbf_kernel
from repro.kernels.common import autotune


@dataclasses.dataclass(frozen=True)
class Budget:
    """The accuracy envelope a servable artifact must meet.

    ``max_err`` bounds the chosen error ``metric`` ("mean_abs" or
    "max_abs") of family scores vs the exact expansion on the
    verification sample. ``relative=True`` scales the bound by the mean
    |exact score| so one budget works across differently-scaled models.

    ``min_valid`` (optional) additionally requires the candidate's §4
    validity verdict to cover at least that fraction of the sample rows.
    Error and validity are different axes: a Maclaurin artifact can
    score a drifted sample accurately yet flag every row invalid — at
    serve time all of it would route through the exact fallback, so the
    artifact is "correct" but never FAST on that traffic. A caller whose
    goal is fast-path coverage (e.g. the ``DriftGuard`` recompiling
    against drifted traffic) sets ``min_valid`` to make the search skip
    such candidates in favor of one whose envelope fits the sample.
    """

    max_err: float
    metric: str = "mean_abs"
    relative: bool = False
    min_valid: float | None = None

    def __post_init__(self):
        if self.metric not in ("mean_abs", "max_abs"):
            raise ValueError(f"unknown budget metric {self.metric!r}")
        if self.min_valid is not None and not 0.0 <= self.min_valid <= 1.0:
            raise ValueError(f"min_valid must be in [0, 1], got {self.min_valid}")

    def limit(self, exact_scale: float) -> float:
        return self.max_err * (exact_scale if self.relative else 1.0)


def compile_model(
    svm: SVMModel,
    budget: Budget,
    *,
    sample=None,
    sample_n: int = 256,
    families: tuple[str, ...] | None = None,
    dtypes: tuple[str, ...] = ("float32", "int8"),
    seed: int = 0,
    family_opts: dict | None = None,
    timing_repeats: int = 5,
    cost_margin: float | None = 4.0,
) -> CompiledArtifact:
    """Compile ``svm`` under every candidate (family, dtype); return the
    fastest artifact meeting ``budget`` on the verification sample.

    Quantized variants are CANDIDATE POINTS in the same search: each
    family is compiled at every entry of ``dtypes`` (int8 adds its
    measured quantization error on top of the approximation error, and
    the combined error vs the exact expansion is what the budget gates),
    so a caller who can absorb the extra ~1e-3 error gets the ~4x smaller
    artifact without asking. ``sample=None`` synthesizes held-out points
    around the support vectors (``fourier.holdout_sample`` —
    deterministic in ``seed``). ``family_opts`` maps family name -> extra
    compile kwargs (e.g. ``{"fourier": {"num_features": 4096,
    "structured": True}}``); combinations a family rejects are skipped
    and noted in the report — the grid always carries a row (measured,
    pruned or typed-skip) for every (family, dtype) cell.
    Raises ``ValueError`` listing every measured error when no candidate
    fits the budget — the caller's recourse is a bigger fourier basis, a
    looser budget, or serving the exact model.

    ``cost_margin`` enables analytic cost PRE-pruning: once some measured
    candidate meets the budget, a later candidate whose roofline-predicted
    cost (``repro.launch.roofline.family_candidate_seconds``) exceeds
    ``cost_margin`` x the predicted cost of the best budget-meeting
    candidate so far is skipped without compiling or timing it. Predicted
    costs are compared only to OTHER predicted costs (never to measured
    milliseconds — the prior's absolute scale is hardware-fantasy, its
    RANKING is what's trusted), pruning never fires before a real
    candidate exists, and candidates the prior cannot model are always
    measured. ``cost_margin=None`` disables pruning (exhaustive search).
    """
    from repro.core import families as _families
    from repro.core.families import quantize
    from repro.launch import roofline

    names = families or tuple(_families.FAMILIES)
    for dt in dtypes:
        quantize.check_dtype(dt)
    opts = family_opts or {}

    if sample is None:
        sample = _families.fourier.holdout_sample(svm, seed, sample_n)
    Z = jnp.asarray(np.asarray(sample, np.float32))

    ay2, b, k_heads, _ = stack_heads(svm)
    exact = rbf_kernel(Z, svm.X, svm.gamma) @ ay2.T + b[None, :]   # (n, K)
    exact_scale = float(jnp.mean(jnp.abs(exact)))
    limit = budget.limit(exact_scale)

    n_sample, d_in = int(Z.shape[0]), int(Z.shape[1])
    best_predicted: float | None = None   # cheapest predicted cost among
    report = []                           # budget-meeting MEASURED candidates
    candidates: list[tuple[float, CompiledArtifact]] = []
    for name in names:
        fam = _families.get_family(name)
        for dt in dtypes:
            predicted = None
            if cost_margin is not None:
                predicted = roofline.family_candidate_seconds(
                    name, dt, n=n_sample, d=d_in, k=int(k_heads),
                    num_features=opts.get(name, {}).get("num_features"),
                    structured=bool(opts.get(name, {}).get("structured")),
                )
            if (
                cost_margin is not None
                and predicted is not None
                and best_predicted is not None
                and predicted > cost_margin * best_predicted
            ):
                report.append({
                    "family": name, "dtype": dt,
                    "skipped": "pruned_by_cost",
                    "predicted_cost_s": predicted,
                    "meets_budget": False,
                })
                continue
            # caller opts override the defaults (so family_opts={'fourier':
            # {'seed': 7}} is legal); the shared sample doubles as fourier's
            # held-out set so it is not regenerated and re-scored inside
            # compile. Families that need neither absorb them via **_opts.
            try:
                art = fam.compile(
                    svm,
                    **{
                        "seed": seed,
                        "holdout": np.asarray(Z),
                        "dtype": dt,
                        **opts.get(name, {}),
                    },
                )
            except NotImplementedError as e:
                report.append({
                    "family": name, "dtype": dt, "skipped": str(e),
                    "meets_budget": False,
                })
                continue
            scores, valid = fam.score(art, Z)
            err = jnp.abs(scores - exact)
            measured = {
                "mean_abs": float(jnp.mean(err)),
                "max_abs": float(jnp.max(err)),
            }
            # fraction of sample rows the candidate would fast-path at
            # serve time (per-row mask for the quadform families, the
            # per-artifact verdict broadcast for fourier)
            valid_fraction = float(jnp.mean(jnp.asarray(valid, jnp.float32)))
            step = jax.jit(lambda Zb, _f=fam, _a=art: _f.score(_a, Zb)[0])
            latency_ms = 1e3 * autotune.measure(
                lambda: step(Z), repeats=timing_repeats, warmup=2
            )
            ok = measured[budget.metric] <= limit and (
                budget.min_valid is None or valid_fraction >= budget.min_valid
            )
            row = {
                "family": name,
                "dtype": art.dtype,
                **measured,
                "valid_fraction": round(valid_fraction, 4),
                "latency_ms": round(latency_ms, 4),
                # in-memory array bytes: constant-time, and the serialized
                # npz tracks it within ~2 KB of header (measured per
                # variant in the model_size benchmark) — serializing all
                # six candidates just to report file sizes would copy
                # tens of MB per compile for large models
                "artifact_bytes": art.nbytes(),
                "meets_budget": ok,
            }
            if predicted is not None:
                row["predicted_cost_s"] = predicted
            for key in ("quant_mean_abs_err", "quant_max_abs_err"):
                if key in art.meta:
                    row[key] = art.meta[key]
            report.append(row)
            if ok:
                candidates.append((latency_ms, art))
                if predicted is not None and (
                    best_predicted is None or predicted < best_predicted
                ):
                    best_predicted = predicted

    if not candidates:
        raise ValueError(
            f"no family meets {budget} (limit {limit:.4g}) on the "
            f"verification sample: "
            + ", ".join(
                f"{r['family']}[{r.get('dtype', '?')}]: "
                + (f"{r[budget.metric]:.4g}" if budget.metric in r else "skipped")
                for r in report
            )
        )
    latency_ms, winner = min(candidates, key=lambda t: t[0])
    return winner.with_meta(
        compile_report={
            "budget": dataclasses.asdict(budget),
            "limit": limit,
            "exact_mean_abs_score": exact_scale,
            "sample_n": int(Z.shape[0]),
            "families": report,
            "chosen": winner.family,
            "chosen_dtype": winner.dtype,
        }
    )
