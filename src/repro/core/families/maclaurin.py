"""The ``maclaurin`` family — the paper's §3 quadratic-form collapse as a
compiled artifact.

Compiles an exact RBF ``SVMModel`` (binary or K-head OvR) into the
(c, v, M) quadratic form of Eq 3.8 and serves it through the fused
``quadform_heads`` backend path. Prediction is O(K d^2) per row,
independent of n_sv; validity is the per-row Eq 3.11 envelope with the
paper's 3.05% per-term relative-error guarantee
(``bounds.REL_ERR_AT_HALF``).

Artifact layout (all f32):

    M (K, d, d)  stacked Hessians        c, b, gamma, msq (K,) scalars
    v (K, d)     gradient terms

``from_approx`` wraps an already-built ``ApproxModel`` (the pre-families
API) into the same artifact so existing callers keep working.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.core.bounds import REL_ERR_AT_HALF
from repro.core.families.base import CompiledArtifact, base_meta, stack_heads
from repro.core.maclaurin import ApproxModel, approximate
from repro.core.rbf import SVMModel
from repro.kernels.common import TileConfig, tuning

NAME = "maclaurin"
TILE_KERNEL = "quadform"        # tuning-registry family the scorer keys on


def compile(svm: SVMModel, **_opts) -> CompiledArtifact:      # noqa: A001
    """Collapse every head of ``svm`` (Eq 3.7); one GEMM per head."""
    ay2, b, k, multiclass = stack_heads(svm)

    def one(ay_k, b_k):
        return approximate(SVMModel(X=svm.X, alpha_y=ay_k, b=b_k, gamma=svm.gamma))

    return _quadform_artifact(
        NAME, jax.vmap(one)(ay2, b), multiclass, rel_err_at_half=REL_ERR_AT_HALF
    )


def from_approx(approx: ApproxModel) -> CompiledArtifact:
    """Wrap a (possibly vmap-stacked) ``ApproxModel`` without recomputing."""
    multiclass = approx.v.ndim == 2
    stacked = approx if multiclass else jax.tree_util.tree_map(
        lambda x: jnp.asarray(x)[None], approx
    )
    return _quadform_artifact(
        NAME, stacked, multiclass, rel_err_at_half=REL_ERR_AT_HALF
    )


def _quadform_artifact(
    family: str, stacked: ApproxModel, multiclass: bool, **extra_meta
) -> CompiledArtifact:
    """Shared packer for every quadratic-form family (maclaurin, poly2)."""
    k, d = stacked.v.shape
    flat = lambda x: jnp.reshape(jnp.asarray(x, jnp.float32), (k,))
    arrays = {
        "M": jnp.asarray(stacked.M, jnp.float32),
        "v": jnp.asarray(stacked.v, jnp.float32),
        "c": flat(stacked.c),
        "b": flat(stacked.b),
        "gamma": flat(stacked.gamma),
        "msq": flat(stacked.max_sv_sq_norm),
    }
    return CompiledArtifact(
        family=family,
        arrays=arrays,
        meta=base_meta(
            d=d, num_heads=k, multiclass=multiclass,
            kind="quadform", validity="per-row", **extra_meta,
        ),
    )


def score(
    artifact: CompiledArtifact, Z, *, config: TileConfig | None = None
):
    """(scores (n, K), valid_rows (n,)) through the fused quadform path.

    ``valid_rows[i]`` is the Eq 3.11 envelope check over ALL heads — a row
    is servable by the fast path only if every head's bound holds.
    """
    a = artifact.arrays
    scores, _, valid = backend.quadform_heads(
        Z, a["M"], a["v"], a["c"], a["b"], a["gamma"], a["msq"], config=config
    )
    return scores, jnp.all(valid, axis=-1)


def tile_lookup(artifact: CompiledArtifact, bucket: int) -> tuple[str, str]:
    """(kernel, shape_key) the tuning registry resolves for this bucket."""
    return TILE_KERNEL, tuning.shape_key(
        d=artifact.d, k=artifact.num_heads, n=bucket
    )
