"""The ``maclaurin`` family — the paper's §3 quadratic-form collapse as a
compiled artifact.

Compiles an exact RBF ``SVMModel`` (binary or K-head OvR) into the
(c, v, M) quadratic form of Eq 3.8 and serves it through the fused
``quadform_heads`` backend path. Prediction is O(K d^2) per row,
independent of n_sv; validity is the per-row Eq 3.11 envelope with the
paper's 3.05% per-term relative-error guarantee
(``bounds.REL_ERR_AT_HALF``).

Artifact layout:

    f32:  M (K, d, d) stacked Hessians     c, b, gamma, msq (K,) scalars
          v (K, d)    gradient terms

    int8 (``compile(..., dtype="int8")``): M stored int8 with per-(head,
          16-column-group) f32 scales ``M_scale`` (K, G); v stored int8
          with per-head scales ``v_scale`` (K,); scalars stay f32. The
          measured quantization error vs the f32 parent ships in the meta
          (``quant_mean_abs_err`` / ``quant_max_abs_err``) and the scales
          fold into the serving GEMMs (``backend.quadform_heads_q8``).

``from_approx`` wraps an already-built ``ApproxModel`` (the pre-families
API) into the same artifact so existing callers keep working.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend
from repro.core.bounds import REL_ERR_AT_HALF
from repro.core.families import quantize
from repro.core.families.base import (
    PAD_HEAD_BIAS,
    CompiledArtifact,
    base_meta,
    stack_heads,
)
from repro.core.maclaurin import ApproxModel, approximate
from repro.core.rbf import SVMModel
from repro.kernels.common import TileConfig, tuning

NAME = "maclaurin"
TILE_KERNEL = "quadform"        # tuning-registry family the scorer keys on
TILE_KERNEL_Q8 = "quadform_q8"  # ...and its int8-Hessian variant


def compile(                                                   # noqa: A001
    svm: SVMModel,
    *,
    dtype: str = "float32",
    seed: int = 0,
    holdout=None,
    holdout_n: int = 256,
    **_opts,
) -> CompiledArtifact:
    """Collapse every head of ``svm`` (Eq 3.7); one GEMM per head.

    ``dtype="int8"`` additionally quantizes the collapsed weights
    (``quantize_quadform_artifact``) and measures the quantization error
    on a deterministic held-out sample (``holdout``/``seed``) so the
    artifact carries its own error report.
    """
    quantize.check_dtype(dtype)
    ay2, b, k, multiclass = stack_heads(svm)

    def one(ay_k, b_k):
        return approximate(SVMModel(X=svm.X, alpha_y=ay_k, b=b_k, gamma=svm.gamma))

    art = _quadform_artifact(
        NAME, jax.vmap(one)(ay2, b), multiclass, rel_err_at_half=REL_ERR_AT_HALF
    )
    if dtype == quantize.INT8_DTYPE:
        art = quantize_quadform_artifact(
            art, svm, seed=seed, holdout=holdout, holdout_n=holdout_n
        )
    return art


def from_approx(approx: ApproxModel) -> CompiledArtifact:
    """Wrap a (possibly vmap-stacked) ``ApproxModel`` without recomputing."""
    multiclass = approx.v.ndim == 2
    stacked = approx if multiclass else jax.tree_util.tree_map(
        lambda x: jnp.asarray(x)[None], approx
    )
    return _quadform_artifact(
        NAME, stacked, multiclass, rel_err_at_half=REL_ERR_AT_HALF
    )


def _quadform_artifact(
    family: str, stacked: ApproxModel, multiclass: bool, **extra_meta
) -> CompiledArtifact:
    """Shared packer for every quadratic-form family (maclaurin, poly2)."""
    k, d = stacked.v.shape
    flat = lambda x: jnp.reshape(jnp.asarray(x, jnp.float32), (k,))  # noqa: E731
    arrays = {
        "M": jnp.asarray(stacked.M, jnp.float32),
        "v": jnp.asarray(stacked.v, jnp.float32),
        "c": flat(stacked.c),
        "b": flat(stacked.b),
        "gamma": flat(stacked.gamma),
        "msq": flat(stacked.max_sv_sq_norm),
    }
    return CompiledArtifact(
        family=family,
        arrays=arrays,
        meta=base_meta(
            d=d, num_heads=k, multiclass=multiclass,
            kind="quadform", validity="per-row", **extra_meta,
        ),
    )


def quantize_quadform_artifact(
    art: CompiledArtifact,
    svm: SVMModel | None = None,
    *,
    seed: int = 0,
    holdout=None,
    holdout_n: int = 256,
) -> CompiledArtifact:
    """Int8 variant of a compiled quadform artifact (maclaurin or poly2).

    The stacked Hessian — the O(K d^2) bulk of the artifact — goes int8
    with per-(head, column-group) scales; v goes int8 with per-head
    scales; the four (K,) scalar vectors stay f32. The quantization error
    vs the f32 parent is measured on ``holdout`` (or a deterministic
    sample around the SVs when ``svm`` is given) and rides in the meta.
    """
    a = art.arrays
    m_q, m_scale = quantize.quantize_col_groups(a["M"])     # (K,d,d), (K,G)
    v_q, v_scale = quantize.quantize_rows(a["v"])           # (K,d), (K,)
    q_art = CompiledArtifact(
        family=art.family,
        arrays={
            "M": m_q, "M_scale": m_scale,
            "v": v_q, "v_scale": v_scale,
            "c": a["c"], "b": a["b"], "gamma": a["gamma"], "msq": a["msq"],
        },
        meta={
            **art.meta,
            "dtype": quantize.INT8_DTYPE,
            "group_size": quantize.GROUP_SIZE,
        },
    )
    Z = holdout
    if Z is None and svm is not None:
        from repro.core.families import fourier

        Z = fourier.holdout_sample(svm, seed, holdout_n)
    if Z is not None:
        Z = jnp.asarray(np.asarray(Z, np.float32))
        q_art = q_art.with_meta(**quantize.measure_quant_error(art, q_art, Z))
    return q_art


def score(
    artifact: CompiledArtifact, Z, *, config: TileConfig | None = None
):
    """(scores (n, K), valid_rows (n,)) through the fused quadform path.

    ``valid_rows[i]`` is the Eq 3.11 envelope check over ALL heads — a row
    is servable by the fast path only if every head's bound holds. The
    envelope depends only on ||z||^2, gamma and msq, so the int8 variant
    keeps the SAME validity contract as its f32 parent.
    """
    a = artifact.arrays
    if artifact.dtype == quantize.INT8_DTYPE:
        col_scale = quantize.expand_group_scales(
            a["M_scale"], artifact.d, int(artifact.meta["group_size"])
        )                                                   # (K, d)
        v = a["v"].astype(jnp.float32) * a["v_scale"][:, None]
        scores, _, valid = backend.quadform_heads_q8(
            Z, a["M"], col_scale, v, a["c"], a["b"], a["gamma"], a["msq"],
            config=config,
        )
    else:
        scores, _, valid = backend.quadform_heads(
            Z, a["M"], a["v"], a["c"], a["b"], a["gamma"], a["msq"], config=config
        )
    return scores, jnp.all(valid, axis=-1)


def pad_heads(artifact: CompiledArtifact, multiple: int) -> CompiledArtifact:
    """Pad the head axis up to a multiple of ``multiple`` (head sharding).

    Padding heads are VALIDITY-NEUTRAL and ARGMAX-NEUTRAL by
    construction: msq = 0 satisfies the Eq 3.11 envelope for every row
    (padding can never push a row to the exact path), and the
    ``PAD_HEAD_BIAS`` bias can never win an argmax. ``meta.num_heads``
    keeps the REAL head count — the engine slices scores back down at
    materialization; ``meta.padded_heads`` records the served width.
    The padded artifact is engine-internal: it is never registered
    (padding would change the content digest).

    Int8 artifacts pad the same way: zero int8 codes dequantize to exact
    zeros under ANY scale, so padded M/v slabs carry scale 1 and the
    scale-epilogue stays harmless on the padding.
    """
    k, d = artifact.num_heads, artifact.d
    pad = (-k) % max(1, int(multiple))
    if pad == 0:
        return artifact
    a = artifact.arrays
    f32 = jnp.float32
    arrays = {
        "c": jnp.concatenate([a["c"], jnp.zeros((pad,), f32)]),
        "b": jnp.concatenate([a["b"], jnp.full((pad,), PAD_HEAD_BIAS, f32)]),
        "gamma": jnp.concatenate([a["gamma"], jnp.ones((pad,), f32)]),
        "msq": jnp.concatenate([a["msq"], jnp.zeros((pad,), f32)]),
    }
    if artifact.dtype == quantize.INT8_DTYPE:
        g = a["M_scale"].shape[-1]
        arrays.update(
            M=jnp.concatenate([a["M"], jnp.zeros((pad, d, d), jnp.int8)]),
            M_scale=jnp.concatenate([a["M_scale"], jnp.ones((pad, g), f32)]),
            v=jnp.concatenate([a["v"], jnp.zeros((pad, d), jnp.int8)]),
            v_scale=jnp.concatenate([a["v_scale"], jnp.ones((pad,), f32)]),
        )
    else:
        arrays.update(
            M=jnp.concatenate([a["M"], jnp.zeros((pad, d, d), f32)]),
            v=jnp.concatenate([a["v"], jnp.zeros((pad, d), f32)]),
        )
    return CompiledArtifact(
        family=artifact.family,
        arrays=arrays,
        meta={**artifact.meta, "padded_heads": k + pad},
    )


def score_sharded(
    artifact: CompiledArtifact, Z, *, mesh, config: TileConfig | None = None
):
    """``score`` with the K heads partitioned over ``mesh``'s first axis.

    The (K, d, d) stacked Hessian — O(K d^2), the operand that outgrows
    one device in the extreme-multiclass regime — lives shard-by-shard;
    every device scores its K/shards heads with the same fused per-shard
    primitive. Scores come back head-sharded (the engine's argmax
    reduces across shards without a gather); the row-validity AND over
    heads is likewise a cross-shard reduction XLA inserts. The head
    count must already divide the axis size (``pad_heads``).

    Int8 artifacts shard identically — the per-head column-scale
    epilogue and the dequantized v fold inside each shard's fused
    primitive, so no f32 copy of M ever materializes on any device.
    """
    a = artifact.arrays
    if artifact.dtype == quantize.INT8_DTYPE:
        col_scale = quantize.expand_group_scales(
            a["M_scale"], artifact.d, int(artifact.meta["group_size"])
        )                                                   # (K, d)
        v = a["v"].astype(jnp.float32) * a["v_scale"][:, None]
        scores, valid = backend.quadform_heads_q8_sharded(
            Z, a["M"], col_scale, v, a["c"], a["b"], a["gamma"], a["msq"],
            mesh=mesh, config=config,
        )
    else:
        scores, valid = backend.quadform_heads_sharded(
            Z, a["M"], a["v"], a["c"], a["b"], a["gamma"], a["msq"],
            mesh=mesh, config=config,
        )
    return scores, jnp.all(valid, axis=-1)


def tile_lookup(artifact: CompiledArtifact, bucket: int) -> tuple[str, str]:
    """(kernel, shape_key) the tuning registry resolves for this bucket."""
    kernel = (
        TILE_KERNEL_Q8 if artifact.dtype == quantize.INT8_DTYPE else TILE_KERNEL
    )
    return kernel, tuning.shape_key(
        d=artifact.d, k=artifact.num_heads, n=bucket
    )
