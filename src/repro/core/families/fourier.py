"""The ``fourier`` family — random Fourier features for the Gaussian kernel.

Rahimi & Recht's estimator: with frequencies W ~ N(0, 2 gamma I) and
phases p ~ U[0, 2 pi),

    k(x, z) = e^{-gamma ||x - z||^2}  ~  (2/F) sum_f cos(w_f.x + p_f) cos(w_f.z + p_f)

so the whole expansion collapses into per-head weight vectors at compile
time:

    weights[k, f] = (2/F) sum_i alpha_y[k, i] cos(w_f . x_i + p_f)
    f_k(z)       ~  weights[k] . cos(W z + p) + b_k

Prediction is O(F d) (dense) or O(F log d) with ``structured=True`` — the
Fastfood construction (Le et al. 2013): W is never materialized; each
stack of d' = 2^ceil(log2 d) features is S H G Pi H B with diagonal
B (signs), G (Gaussian), scaling S and a permutation Pi, applied via the
in-place Walsh-Hadamard transform. Construction cost drops from O(F d)
memory to O(F), the projection from O(F d) to O(F log d) FLOPs.

Unlike the quadform families there is NO per-row validity bound — the
estimator's error is probabilistic in F, uniform over the whole domain
rather than gated by an envelope around the origin. The accuracy contract
is therefore established at COMPILE time, paper-§4 style: a held-out
sample (caller-provided or synthesized around the SVs) is scored against
the exact expansion and the measured error ships in the artifact meta
(``holdout_mean_abs_err`` / ``holdout_max_abs_err``). The serving engine
falls back per ARTIFACT, not per row: if the estimate violates
``err_tolerance`` every row takes the exact path.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import backend
from repro.core.families import quantize
from repro.core.families.base import (
    PAD_HEAD_BIAS,
    CompiledArtifact,
    base_meta,
    stack_heads,
)
from repro.core.rbf import SVMModel, rbf_kernel
from repro.kernels.common import TileConfig, tuning
from repro.kernels.fwht import ref as _fwht_ref

NAME = "fourier"
TILE_KERNEL = "rff_score"
TILE_KERNEL_Q8 = "rff_score_q8"
TILE_KERNEL_FF = "fwht"
TILE_KERNEL_FF_Q8 = "fwht_q8"

DEFAULT_NUM_FEATURES = 1024
DEFAULT_HOLDOUT_N = 256


# ------------------------------------------------------------ construction


def compile(                                                   # noqa: A001
    svm: SVMModel,
    *,
    num_features: int = DEFAULT_NUM_FEATURES,
    structured: bool = False,
    dtype: str = "float32",
    seed: int = 0,
    err_tolerance: float | None = None,
    holdout=None,
    holdout_n: int = DEFAULT_HOLDOUT_N,
    **_opts,
) -> CompiledArtifact:
    """Sample features, fold the expansion into per-head weights, measure
    the held-out error, and pack the servable arrays.

    ``structured=True`` rounds ``num_features`` up to a whole number of
    Fastfood stacks (each 2^ceil(log2 d) wide). ``dtype="int8"``
    quantizes the big operands — dense: the projection matrix
    (per-feature-row scales) and the (K, F) readout (per-head scales);
    structured: the G/S diagonals (per-stack scales, folded into one
    combined multiplier), the readout, plus lossless narrowing of the
    sign diagonal, permutation indices and phase — and the held-out
    error below is then measured on the QUANTIZED artifact, so the
    meta's accuracy contract describes what actually ships.
    """
    quantize.check_dtype(dtype)
    X = np.asarray(svm.X, np.float32)
    gamma = float(svm.gamma)
    ay2, b, k, multiclass = stack_heads(svm)
    d = X.shape[1]
    rng = np.random.default_rng(seed)

    if structured:
        arrays, f, proj_meta = _fastfood_arrays(rng, d, num_features, gamma)
        proj_x = _fastfood_project(
            jnp.asarray(X), arrays["ff_b"], arrays["ff_g"],
            arrays["ff_perm"], arrays["ff_scale"],
        )
    else:
        f = int(num_features)
        W = rng.normal(0.0, np.sqrt(2.0 * gamma), size=(f, d)).astype(np.float32)
        arrays = {"W": jnp.asarray(W)}
        proj_x = jnp.asarray(X) @ arrays["W"].T
        proj_meta = {"projection": "dense"}

    phase = jnp.asarray(
        rng.uniform(0.0, 2.0 * np.pi, size=(f,)).astype(np.float32)
    )
    phi_x = jnp.cos(proj_x + phase[None, :])                   # (n_sv, F)
    weights = (2.0 / f) * (ay2.astype(jnp.float32) @ phi_x)    # (K, F)

    arrays.update(
        phase=phase, weights=weights, b=b.astype(jnp.float32)
    )
    art = CompiledArtifact(
        family=NAME,
        arrays=arrays,
        meta=base_meta(
            d=d, num_heads=k, multiclass=multiclass,
            kind="rff", validity="global", num_features=f, seed=int(seed),
            **proj_meta,
        ),
    )

    Zh = holdout if holdout is not None else holdout_sample(svm, seed, holdout_n)
    Zh = jnp.asarray(np.asarray(Zh, np.float32))
    if dtype == quantize.INT8_DTYPE:
        art = quantize_rff_artifact(art, holdout=Zh)

    # §4-style pre-serving verification: measure the estimator on held-out
    # points and ship the verdict with the artifact. For int8 the verdict
    # is measured on the QUANTIZED artifact — the accuracy contract must
    # describe the arrays being served, not their f32 parent.
    exact = rbf_kernel(Zh, jnp.asarray(X), svm.gamma) @ ay2.T + b[None, :]
    approx, _ = score(art, Zh)
    err = jnp.abs(approx - exact)
    mean_err = float(jnp.mean(err))
    max_err = float(jnp.max(err))
    return art.with_meta(
        holdout_n=int(Zh.shape[0]),
        holdout_mean_abs_err=mean_err,
        holdout_max_abs_err=max_err,
        err_tolerance=err_tolerance,
        valid_globally=bool(err_tolerance is None or mean_err <= err_tolerance),
    )


def quantize_rff_artifact(
    art: CompiledArtifact, *, holdout=None
) -> CompiledArtifact:
    """Int8 variant of a dense-projection RFF artifact.

    W — the O(F d) bulk — goes int8 with one scale per feature row (each
    row's scale folds onto its projection column post-GEMM); the per-head
    readout weights go int8 with per-head scales (the feature axis is the
    readout's CONTRACTION axis, so any finer grouping could not fold);
    phase and bias stay f32. Measured quantization error vs the f32
    parent rides in the meta when ``holdout`` is given. Fastfood-
    projection artifacts route to ``quantize_fastfood_artifact``.
    """
    if art.meta.get("projection") == "fastfood":
        return quantize_fastfood_artifact(art, holdout=holdout)
    a = art.arrays
    w_q, w_scale = quantize.quantize_rows(a["W"])            # (F,d), (F,)
    wt_q, wt_scale = quantize.quantize_rows(a["weights"])    # (K,F), (K,)
    q_art = CompiledArtifact(
        family=art.family,
        arrays={
            "W": w_q, "W_scale": w_scale,
            "weights": wt_q, "weights_scale": wt_scale,
            "phase": a["phase"], "b": a["b"],
        },
        meta={**art.meta, "dtype": quantize.INT8_DTYPE},
    )
    if holdout is not None:
        q_art = q_art.with_meta(
            **quantize.measure_quant_error(art, q_art, holdout)
        )
    return q_art


def quantize_fastfood_artifact(
    art: CompiledArtifact, *, holdout=None
) -> CompiledArtifact:
    """Int8 variant of a structured (Fastfood) RFF artifact.

    A Fastfood artifact has no O(F d) operand, so the footprint win comes
    from narrowing EVERY array that scales with F or K:

      * ``ff_b``: exact +-1 signs -> int8, lossless, no scale;
      * ``ff_g`` / ``ff_scale``: int8 with one scale per stack row
        (``quantize_rows``). Both diagonals multiply elementwise on the
        same transform columns, so their per-stack scale PRODUCT folds
        once per stack on the transform output (``ff_stack_scale``, the
        analogue of rff_score_q8's post-GEMM fold) — the per-element int8
        codes reconstruct the shape, one f32 multiplier per stack
        reconstructs the magnitude;
      * ``ff_perm``: int16 when d' fits (lossless narrowing);
      * ``phase``: float16 — a phase offset into cos() needs ~1e-3 rad
        absolute accuracy, which f16 delivers over [0, 2 pi);
      * ``weights`` (K, F): int8 with per-head scales, exactly like the
        dense readout; ``b`` stays f32 (K values, argmax-critical).

    Codes and scales are computed on host in float64 with round-half-even
    (see ``quantize``), so the serialized bytes are deterministic and
    content-addressing survives. Measured quantization error vs the f32
    parent rides in the meta when ``holdout`` is given.
    """
    if art.meta.get("projection") != "fastfood":
        raise ValueError("not a fastfood-projection artifact")
    a = art.arrays
    g_q, g_scale = quantize.quantize_rows(a["ff_g"])         # (S,dd), (S,)
    s_q, s_scale = quantize.quantize_rows(a["ff_scale"])     # (S,dd), (S,)
    wt_q, wt_scale = quantize.quantize_rows(a["weights"])    # (K,F), (K,)
    stack_scale = (
        np.asarray(g_scale, np.float64) * np.asarray(s_scale, np.float64)
    ).astype(np.float32)
    q_art = CompiledArtifact(
        family=art.family,
        arrays={
            "ff_b": quantize.quantize_signs(a["ff_b"]),
            "ff_g": g_q,
            "ff_scale": s_q,
            "ff_stack_scale": jnp.asarray(stack_scale),
            "ff_perm": quantize.compact_perm(a["ff_perm"]),
            "phase": jnp.asarray(a["phase"], jnp.float16),
            "weights": wt_q, "weights_scale": wt_scale,
            "b": a["b"],
        },
        meta={**art.meta, "dtype": quantize.INT8_DTYPE},
    )
    if holdout is not None:
        q_art = q_art.with_meta(
            **quantize.measure_quant_error(art, q_art, holdout)
        )
    return q_art


def holdout_sample(svm: SVMModel, seed: int, n: int = DEFAULT_HOLDOUT_N):
    """Deterministic held-out points near the data manifold: SVs plus
    per-feature-scaled Gaussian jitter. Derived from ``seed`` so the
    compile-time verdict is reproducible from the artifact meta alone."""
    X = np.asarray(svm.X, np.float32)
    rng = np.random.default_rng(np.uint32(seed) ^ np.uint32(0x5EED))
    idx = rng.integers(0, X.shape[0], size=n)
    sigma = X.std(axis=0) + 1e-6
    return X[idx] + 0.5 * sigma[None, :] * rng.standard_normal(
        (n, X.shape[1])
    ).astype(np.float32)


def _fastfood_arrays(rng, d: int, num_features: int, gamma: float):
    """Sample the diagonal operators for ceil(F / d') Fastfood stacks.

    Each stack realizes d' = 2^ceil(log2 d) frequency rows S H G Pi H B
    whose norms match W ~ N(0, 2 gamma I): rows of H G Pi H B have norm
    ||g|| sqrt(d'), so S_ii = sqrt(2 gamma) chi_i / (||g|| sqrt(d')) with
    chi_i ~ chi(d') gives ||w_i|| = sqrt(2 gamma) chi_i, the Gaussian
    row-norm distribution.
    """
    dd = 1 << max(1, (d - 1).bit_length())                     # next pow2 >= d
    stacks = -(-int(num_features) // dd)
    f = stacks * dd
    B = rng.choice(np.float32([-1.0, 1.0]), size=(stacks, dd))
    G = rng.standard_normal((stacks, dd)).astype(np.float32)
    perm = np.stack([rng.permutation(dd) for _ in range(stacks)]).astype(np.int32)
    chi = np.sqrt(rng.chisquare(dd, size=(stacks, dd))).astype(np.float32)
    g_norm = np.linalg.norm(G, axis=-1, keepdims=True)
    scale = np.sqrt(2.0 * gamma) * chi / (g_norm * np.sqrt(dd))
    arrays = {
        "ff_b": jnp.asarray(B),
        "ff_g": jnp.asarray(G),
        "ff_perm": jnp.asarray(perm),
        "ff_scale": jnp.asarray(scale.astype(np.float32)),
    }
    return arrays, f, {"projection": "fastfood", "dd": dd, "stacks": stacks}


# The transform arithmetic lives in ``repro.kernels.fwht.ref`` — ONE
# butterfly implementation shared by the XLA formulation, the Pallas
# kernel body, and the compile-time projection here. These aliases keep
# the long-standing family-level names working.
fwht = _fwht_ref.fwht
_fastfood_project = _fwht_ref.fastfood_project


# ---------------------------------------------------------------- serving


def score(
    artifact: CompiledArtifact, Z, *, config: TileConfig | None = None
):
    """(scores (n, K), valid_rows (n,)).

    Every (projection, dtype) combination dispatches through the
    ``core/backend`` seam: dense via ``rff_score`` / ``rff_score_q8``,
    Fastfood via ``fastfood_score`` / ``fastfood_score_q8`` — the fused
    FWHT Pallas kernel on TPU, the algebraically identical XLA
    formulation elsewhere.

    ``valid_rows`` is the compile-time held-out verdict broadcast over
    the batch: there is no per-row envelope for RFF, so either every row
    is inside the accuracy contract or none is (engine falls back per
    artifact).
    """
    a = artifact.arrays
    if artifact.meta.get("projection") == "fastfood":
        if artifact.dtype == quantize.INT8_DTYPE:
            scores = backend.fastfood_score_q8(
                Z, a["ff_b"], a["ff_g"], a["ff_perm"], a["ff_scale"],
                a["ff_stack_scale"], a["phase"],
                a["weights"], a["weights_scale"], a["b"], config=config,
            )
        else:
            scores = backend.fastfood_score(
                Z, a["ff_b"], a["ff_g"], a["ff_perm"], a["ff_scale"],
                a["phase"], a["weights"], a["b"], config=config,
            )
    elif artifact.dtype == quantize.INT8_DTYPE:
        scores = backend.rff_score_q8(
            Z, a["W"], a["W_scale"], a["phase"],
            a["weights"], a["weights_scale"], a["b"], config=config,
        )
    else:
        scores = backend.rff_score(
            Z, a["W"], a["phase"], a["weights"], a["b"], config=config
        )
    valid = jnp.full(
        (scores.shape[0],), bool(artifact.meta.get("valid_globally", True))
    )
    return scores, valid


def pad_heads(artifact: CompiledArtifact, multiple: int) -> CompiledArtifact:
    """Pad the head axis up to a multiple of ``multiple`` (head sharding).

    Only the (K, F) readout, its per-head scales (int8) and the (K,)
    bias carry a head axis; padding heads get zero weights (int8 zero
    codes dequantize to exact zeros under any scale — scale 1 keeps the
    epilogue fold harmless) and the argmax-neutral ``PAD_HEAD_BIAS``.
    RFF validity is a per-artifact verdict, so padding cannot perturb it.
    """
    k = artifact.num_heads
    pad = (-k) % max(1, int(multiple))
    if pad == 0:
        return artifact
    a = artifact.arrays
    f = int(artifact.meta["num_features"])
    arrays = dict(a)
    if artifact.dtype == quantize.INT8_DTYPE:
        arrays["weights"] = jnp.concatenate(
            [a["weights"], jnp.zeros((pad, f), jnp.int8)]
        )
        arrays["weights_scale"] = jnp.concatenate(
            [a["weights_scale"], jnp.ones((pad,), jnp.float32)]
        )
    else:
        arrays["weights"] = jnp.concatenate(
            [a["weights"], jnp.zeros((pad, f), jnp.float32)]
        )
    arrays["b"] = jnp.concatenate(
        [a["b"], jnp.full((pad,), PAD_HEAD_BIAS, jnp.float32)]
    )
    return CompiledArtifact(
        family=NAME,
        arrays=arrays,
        meta={**artifact.meta, "padded_heads": k + pad},
    )


def score_sharded(
    artifact: CompiledArtifact, Z, *, mesh, config: TileConfig | None = None
):
    """``score`` with the (K, F) readout partitioned over ``mesh``.

    All four (projection, dtype) combinations serve: the per-row
    projection work — the dense GEMM, or Fastfood's O(F log d')
    butterflies, strictly cheaper to replicate — runs per shard, while
    the (K, F) readout, its int8 per-head scale epilogue and the bias
    partition over the mesh's first axis. The validity verdict is
    per-artifact meta, computed OUTSIDE the sharded region.
    """
    a = artifact.arrays
    if artifact.meta.get("projection") == "fastfood":
        if artifact.dtype == quantize.INT8_DTYPE:
            scores = backend.fastfood_score_q8_sharded(
                Z, a["ff_b"], a["ff_g"], a["ff_perm"], a["ff_scale"],
                a["ff_stack_scale"], a["phase"],
                a["weights"], a["weights_scale"], a["b"],
                mesh=mesh, config=config,
            )
        else:
            scores = backend.fastfood_score_sharded(
                Z, a["ff_b"], a["ff_g"], a["ff_perm"], a["ff_scale"],
                a["phase"], a["weights"], a["b"], mesh=mesh, config=config,
            )
    elif artifact.dtype == quantize.INT8_DTYPE:
        scores = backend.rff_score_q8_sharded(
            Z, a["W"], a["W_scale"], a["phase"],
            a["weights"], a["weights_scale"], a["b"],
            mesh=mesh, config=config,
        )
    else:
        scores = backend.rff_score_sharded(
            Z, a["W"], a["phase"], a["weights"], a["b"],
            mesh=mesh, config=config,
        )
    valid = jnp.full(
        (scores.shape[0],), bool(artifact.meta.get("valid_globally", True))
    )
    return scores, valid


def tile_lookup(artifact: CompiledArtifact, bucket: int) -> tuple[str, str]:
    q8 = artifact.dtype == quantize.INT8_DTYPE
    if artifact.meta.get("projection") == "fastfood":
        kernel = TILE_KERNEL_FF_Q8 if q8 else TILE_KERNEL_FF
    else:
        kernel = TILE_KERNEL_Q8 if q8 else TILE_KERNEL
    return kernel, tuning.shape_key(
        d=artifact.d, f=int(artifact.meta["num_features"]), n=bucket
    )
