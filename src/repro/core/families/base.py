"""``CompiledArtifact`` — the train → compile → serve seam.

Every approximation family (maclaurin, poly2, fourier, ...) compiles an
exact ``SVMModel`` into one of these: a named bag of device arrays plus
JSON-able metadata. The artifact is the ONLY thing the serving stack
needs — no training-side objects (``SVMModel``, solver state, rngs)
survive compilation, so a server process can ``CompiledArtifact.load``
an ``.npz`` file and serve it without importing any training code.

Design points:

  * **Pytree-registered.** Arrays are the children (sorted by key so the
    flatten order is stable); ``(family, keys, meta)`` is the aux data.
    Artifacts therefore pass through ``jax.jit`` / ``jax.device_put`` /
    donation like any model pytree.
  * **Versioned npz.** ``save``/``load`` speak a plain ``.npz`` with one
    extra ``__artifact__`` member holding the JSON header (format
    version, family name, meta). ``load`` refuses future format
    versions instead of mis-parsing them.
  * **Deterministic bytes.** ``save`` writes zip members itself with
    pinned timestamps/permissions (ZIP_STORED), so compiling the same
    model with the same seed yields BIT-IDENTICAL files across
    processes — artifact stores can be content-addressed and diffed.

Family modules register themselves in ``repro.core.families.FAMILIES``;
scoring dispatches on ``artifact.family`` (see ``score_artifact``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Bump when the on-disk layout changes incompatibly. Readers accept
# anything <= their own version and reject newer files loudly.
# v2: quantized variants — int8 weight arrays with per-group f32 scales,
#     ``dtype`` in the meta (absent in v1 files => "float32").
ARTIFACT_FORMAT_VERSION = 2

_HEADER_MEMBER = "__artifact__"

# Bias given to validity-neutral padding heads (head-sharded serving
# pads K up to the mesh axis size): exp-enveloped scores are O(|c|+|v|+|M|)
# magnitudes, so a -1e30 bias can never win an argmax, and padding heads
# carry msq = 0, which satisfies Eq 3.11 for every row.
PAD_HEAD_BIAS = -1e30


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledArtifact:
    """One servable model: ``family`` tag, device arrays, JSON-able meta.

    ``meta`` always carries ``format_version``, ``d`` (feature dim),
    ``num_heads`` (K) and ``multiclass``; families add their own keys
    (error-bound constants, held-out error estimates, rng seeds, ...).
    """

    family: str
    arrays: dict[str, Array]
    meta: dict

    # ------------------------------------------------------------ conveniences

    @property
    def d(self) -> int:
        return int(self.meta["d"])

    @property
    def num_heads(self) -> int:
        return int(self.meta["num_heads"])

    @property
    def multiclass(self) -> bool:
        return bool(self.meta["multiclass"])

    @property
    def dtype(self) -> str:
        """Weight storage dtype: "float32" or "int8" (v1 files: float32)."""
        return self.meta.get("dtype", "float32")

    def nbytes(self) -> int:
        """In-memory size of the servable arrays (Table-3 accounting)."""
        return sum(a.size * a.dtype.itemsize for a in self.arrays.values())

    def with_meta(self, **updates) -> "CompiledArtifact":
        """Functional meta update (arrays shared, not copied)."""
        return CompiledArtifact(self.family, self.arrays, {**self.meta, **updates})

    # ------------------------------------------------------------- persistence

    def to_bytes(self) -> bytes:
        """The deterministic versioned ``.npz`` bytes ``save`` writes.

        Same model + seed ⇒ bit-identical bytes across processes (pinned
        zip metadata), so these bytes — not the object identity — are the
        canonical identity of a compiled model. ``digest()`` hashes them.
        """
        header = json.dumps(
            {
                "format_version": ARTIFACT_FORMAT_VERSION,
                "family": self.family,
                "meta": self.meta,
                "keys": sorted(self.arrays),
            },
            sort_keys=True,
        ).encode()
        members = {_HEADER_MEMBER: np.frombuffer(header, dtype=np.uint8)}
        for name in sorted(self.arrays):
            members[name] = np.ascontiguousarray(self.arrays[name])
        out = io.BytesIO()
        with zipfile.ZipFile(out, "w", zipfile.ZIP_STORED) as zf:
            for name, arr in members.items():
                buf = io.BytesIO()
                np.lib.format.write_array(buf, arr, allow_pickle=False)
                _write_member(zf, name + ".npy", buf.getvalue())
        return out.getvalue()

    def digest(self) -> str:
        """SHA-256 hex digest of ``to_bytes()`` — the content address.

        save → load → save round-trips to the SAME digest (tested), so an
        artifact registry can dedupe identical compiles and key a store on
        the digest regardless of which process produced the file.
        """
        return hashlib.sha256(self.to_bytes()).hexdigest()

    def save(self, path: str) -> str:
        """Write a deterministic versioned ``.npz``; returns ``path``."""
        with open(path, "wb") as f:
            f.write(self.to_bytes())
        return path

    @classmethod
    def load(cls, path: str) -> "CompiledArtifact":
        """Read an artifact written by ``save`` (any version <= current)."""
        with np.load(path, allow_pickle=False) as z:
            if _HEADER_MEMBER not in z.files:
                raise ValueError(f"{path} is not a CompiledArtifact npz "
                                 f"(missing {_HEADER_MEMBER!r} member)")
            header = json.loads(bytes(z[_HEADER_MEMBER]).decode())
            version = header.get("format_version")
            if not isinstance(version, int) or version > ARTIFACT_FORMAT_VERSION:
                raise ValueError(
                    f"artifact format version {version!r} is newer than this "
                    f"reader (supports <= {ARTIFACT_FORMAT_VERSION}); "
                    f"upgrade repro to load {path}"
                )
            arrays = {k: jnp.asarray(z[k]) for k in header["keys"]}
        return cls(family=header["family"], arrays=arrays, meta=header["meta"])


def _write_member(zf: zipfile.ZipFile, name: str, payload: bytes) -> None:
    """One zip member with pinned metadata (the determinism guarantee)."""
    info = zipfile.ZipInfo(name, date_time=(1980, 1, 1, 0, 0, 0))
    info.compress_type = zipfile.ZIP_STORED
    info.external_attr = 0o644 << 16
    zf.writestr(info, payload)


# ------------------------------------------------------------------ pytree


def _flatten(art: CompiledArtifact):
    keys = tuple(sorted(art.arrays))
    children = tuple(art.arrays[k] for k in keys)
    aux = (art.family, keys, json.dumps(art.meta, sort_keys=True))
    return children, aux


def _unflatten(aux, children):
    family, keys, meta_json = aux
    return CompiledArtifact(
        family=family, arrays=dict(zip(keys, children)), meta=json.loads(meta_json)
    )


jax.tree_util.register_pytree_node(CompiledArtifact, _flatten, _unflatten)


def base_meta(
    *, d: int, num_heads: int, multiclass: bool, dtype: str = "float32", **extra
) -> dict:
    """The meta keys every family must provide, plus family extras."""
    return {
        "format_version": ARTIFACT_FORMAT_VERSION,
        "d": int(d),
        "num_heads": int(num_heads),
        "multiclass": bool(multiclass),
        "dtype": str(dtype),
        **extra,
    }


def stack_heads(svm) -> tuple[Array, Array, int, bool]:
    """View an ``SVMModel``'s (alpha_y, b) as a K-head stack.

    Binary models store ``alpha_y`` as (n_sv,); OvR ensembles (from
    ``repro.svm.multiclass.train_one_vs_rest``) as (K, n_sv) with b (K,).
    Every family compiles the K-stacked view so serving is uniformly
    multi-head (K = 1 is just the smallest stack).
    """
    ay = svm.alpha_y
    multiclass = ay.ndim == 2
    ay2 = ay if multiclass else ay[None, :]
    b = jnp.reshape(svm.b, (ay2.shape[0],))
    return ay2, b, ay2.shape[0], multiclass
