"""Pluggable approximation families: compile any SVM into a servable
artifact.

The paper's Maclaurin collapse is one point in a family of explicit
kernel approximations that trade construction cost, prediction FLOPs and
error guarantees differently. This package makes that family axis
pluggable:

  ===========  =============================  ========================
  family       prediction cost / row          accuracy contract
  ===========  =============================  ========================
  maclaurin    O(K d^2) quadratic form        per-row Eq 3.11 envelope,
                                              3.05% per-term rel. err
  poly2        O(K d^2) quadratic form        per-row Eq 3.11 envelope,
                                              7.26% per-term rel. err
  fourier      O(F d) dense RFF projection,   compile-time held-out
               O(F log d) with Fastfood       error estimate
  ===========  =============================  ========================

Every family compiles an exact ``SVMModel`` (binary or K-head OvR) into
a ``CompiledArtifact`` — pytree-registered, versioned npz ``save``/
``load`` — so serving needs no training-side objects. A family module
exports ``NAME``, ``compile(svm, **opts)``, ``score(artifact, Z,
config=None)``, ``TILE_KERNEL`` and ``tile_lookup(artifact, bucket)``.

Every family also compiles an int8 variant (``compile(...,
dtype="int8")`` — see ``repro.core.families.quantize``): the bulk weight
operand is stored int8 with per-group f32 scales, dequantization is
fused into the serving GEMMs, and the measured quantization error ships
in the artifact meta. Quantized variants serialize ~4x smaller, carry
distinct content digests, and are first-class candidates in
``compile_model``'s budget search.

``compile_model(svm, budget)`` is the front door: the §4 verification
run across all families, returning the cheapest artifact within budget.
"""

from repro.core.families import fourier, maclaurin, poly2, quantize
from repro.core.families.base import (
    ARTIFACT_FORMAT_VERSION,
    CompiledArtifact,
)
from repro.core.families.compile import Budget, compile_model

FAMILIES = {
    maclaurin.NAME: maclaurin,
    poly2.NAME: poly2,
    fourier.NAME: fourier,
}


def get_family(name: str):
    """The family module registered under ``name`` (KeyError lists known)."""
    try:
        return FAMILIES[name]
    except KeyError:
        raise KeyError(
            f"unknown approximation family {name!r}; known: {sorted(FAMILIES)}"
        ) from None


def score_artifact(artifact: CompiledArtifact, Z, *, config=None):
    """(scores (n, K), valid_rows (n,)) via the artifact's family."""
    return get_family(artifact.family).score(artifact, Z, config=config)


__all__ = [
    "ARTIFACT_FORMAT_VERSION",
    "Budget",
    "CompiledArtifact",
    "FAMILIES",
    "compile_model",
    "fourier",
    "get_family",
    "maclaurin",
    "poly2",
    "quantize",
    "score_artifact",
]
