"""Symmetric int8 quantization of compiled artifacts.

The paper's compiled forms already trade a controlled approximation error
for serving cost; quantizing the compiled WEIGHTS trades a second, much
smaller error for a ~4x memory-footprint win on the dominant operand
(the stacked Hessian for the quadform families, the projection matrix
for fourier). Cotter et al. motivate the error-for-cost exchange; Le et
al.'s Fastfood shows the RFF weights are themselves an approximation
whose error budget can absorb quantization noise.

Scheme (weight-only, activations stay f32):

  * **Per-feature-group scales.** Weights are quantized symmetrically
    (zero-point 0) in groups of ``GROUP_SIZE`` = 16 along one axis, the
    same grouping the int8 KV cache uses — one f32 scale per group keeps
    the quantization error per column small enough that multiclass argmax
    parity survives (a single per-tensor scale does not once one head has
    a heavy-tailed Hessian).
  * **Scales fold AFTER the GEMM.** Every quantized axis here is an
    OUTPUT axis of its contraction (Hessian columns, RFF feature rows,
    readout heads), so dequantization is a cheap VPU multiply on the
    small GEMM result, never a materialized f32 copy of the weights —
    the Pallas tiles fold it in VMEM, the XLA path is an int8->f32 GEMM
    followed by one broadcast multiply.
  * **Deterministic.** round-half-to-even in float64 on host: the same
    model + seed quantizes to bit-identical int8 CODES AND SCALES in any
    process. The full artifact digest additionally covers the measured
    quantization error in the meta, which is computed through the
    serving backend — so digests reproduce across processes on one
    host/backend configuration (the registry's dedupe unit, gated in CI
    by ``tools/check_artifact_determinism.py``) but, like fourier's
    held-out error estimate and ``compile_model``'s measured-latency
    report, are not bit-portable across backends or BLAS builds.

Every quantized artifact ships its measured quantization error
(``quant_mean_abs_err`` / ``quant_max_abs_err`` vs its own f32 parent on
a deterministic held-out sample) in the meta, so the §4 budget search in
``compile_model`` can treat int8 variants as first-class candidates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

INT8_DTYPE = "int8"
F32_DTYPE = "float32"
DTYPES = (F32_DTYPE, INT8_DTYPE)

# Channels per f32 sub-scale along the quantized axis. Matches the int8
# KV-cache precedent: 16 is fine enough to keep argmax parity, coarse
# enough that scale overhead is ~25% of the int8 payload at worst.
GROUP_SIZE = 16

_QMAX = 127.0


def check_dtype(dtype: str) -> str:
    if dtype not in DTYPES:
        raise ValueError(f"artifact dtype must be one of {DTYPES}, got {dtype!r}")
    return dtype


def num_groups(n: int, group_size: int = GROUP_SIZE) -> int:
    return -(-int(n) // group_size)


def quantize_groups(
    x, axis: int = -1, group_size: int = GROUP_SIZE
) -> tuple[Array, Array]:
    """Symmetric int8 quantization with one scale per ``group_size`` slab
    along ``axis``.

    Returns ``(q int8, scales f32)`` where ``scales`` has the quantized
    axis reduced to ``num_groups``. All-zero groups get scale 1 (they
    dequantize to exact zeros). Computed in float64 on host so the
    int8 codes are platform-independent — part of the artifact's
    deterministic-bytes contract.

    The shipped artifact layouts use the pooled/rowwise specializations
    below (``quantize_col_groups``, ``quantize_rows``); this per-slab
    form is the primitive for the ROADMAP's finer per-(head, row, group)
    Hessian scales if a real model ever loses argmax parity.
    """
    x = np.asarray(x, np.float64)
    axis = axis % x.ndim
    g = num_groups(x.shape[axis], group_size)
    pad = g * group_size - x.shape[axis]
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = np.pad(x, widths)
    shape = list(x.shape)
    shape[axis : axis + 1] = [g, group_size]
    xg = x.reshape(shape)
    absmax = np.abs(xg).max(axis=axis + 1)
    scale = np.where(absmax > 0.0, absmax / _QMAX, 1.0)
    q = np.clip(np.rint(xg / np.expand_dims(scale, axis + 1)), -_QMAX, _QMAX)
    shape[axis : axis + 2] = [g * group_size]
    q = q.reshape(shape)
    if pad:
        q = np.take(q, np.arange(x.shape[axis] - pad), axis=axis)
    return jnp.asarray(q.astype(np.int8)), jnp.asarray(scale.astype(np.float32))


def quantize_col_groups(
    x, group_size: int = GROUP_SIZE
) -> tuple[Array, Array]:
    """Symmetric int8 for a (..., r, n) operand with one scale per
    (leading dims, n-group) — absmax pooled over the WHOLE row axis and
    the group slab, so the scale layout is independent of r.

    This is the stacked-Hessian layout: n is the Hessian's column axis
    (an OUTPUT axis of ``Z @ M``), so the (..., G) scales fold onto the
    GEMM result with one broadcast multiply; a scale that also varied
    with the row (contraction) axis could not fold post-GEMM at all.
    """
    x = np.asarray(x, np.float64)
    *lead, r, n = x.shape
    g = num_groups(n, group_size)
    pad = g * group_size - n
    xp = np.pad(x, [(0, 0)] * len(lead) + [(0, 0), (0, pad)])
    xg = xp.reshape(*lead, r, g, group_size)
    absmax = np.abs(xg).max(axis=(-3, -1))                  # (*lead, G)
    scale = np.where(absmax > 0.0, absmax / _QMAX, 1.0)
    per_col = np.repeat(scale, group_size, axis=-1)         # (*lead, g*gs)
    q = np.clip(np.rint(xp / per_col[..., None, :]), -_QMAX, _QMAX)
    q = q[..., :n]
    return jnp.asarray(q.astype(np.int8)), jnp.asarray(scale.astype(np.float32))


def expand_group_scales(
    scales: Array, n: int, group_size: int = GROUP_SIZE
) -> Array:
    """Broadcast per-group scales back to per-element along the last axis:
    (..., G) -> (..., n). The inverse layout of ``quantize_groups`` so the
    dequant multiply can fold onto a (..., n)-shaped GEMM output."""
    return jnp.repeat(scales, group_size, axis=-1)[..., :n]


def dequantize_groups(
    q: Array, scales: Array, group_size: int = GROUP_SIZE
) -> Array:
    """f32 reconstruction (tests and trace-time constants, not hot paths)."""
    return q.astype(jnp.float32) * expand_group_scales(
        scales, q.shape[-1], group_size
    )


def quantize_rows(x) -> tuple[Array, Array]:
    """Symmetric int8 with one scale per leading-axis row:
    (..., n) -> (q (..., n) int8, scales (...,) f32). The layout for
    operands whose OUTPUT axis is the leading one (RFF projection rows,
    per-head readout weights)."""
    x = np.asarray(x, np.float64)
    absmax = np.abs(x).max(axis=-1)
    scale = np.where(absmax > 0.0, absmax / _QMAX, 1.0)
    q = np.clip(np.rint(x / scale[..., None]), -_QMAX, _QMAX)
    return jnp.asarray(q.astype(np.int8)), jnp.asarray(scale.astype(np.float32))


def quantize_signs(x) -> Array:
    """Lossless int8 encoding of an exactly-{-1, +1} operand (Fastfood's
    B diagonal). No scale: the values ARE representable, so this is a
    cast with a guard — anything that is not a sign means the caller
    grabbed the wrong array, not a quantization decision."""
    x = np.asarray(x, np.float64)
    if not np.all(np.abs(x) == 1.0):
        raise ValueError("sign operand must be exactly +-1 everywhere")
    return jnp.asarray(x.astype(np.int8))


def compact_perm(perm) -> Array:
    """Narrowest exact integer dtype for permutation indices: int16 when
    every index fits (d' <= 32768 — any realistic feature width), int32
    otherwise. Lossless either way; this is a serialized-bytes win, not
    a quantization (the backend upcasts to int32 at trace time)."""
    perm = np.asarray(perm)
    if perm.size and perm.max() < np.iinfo(np.int16).max:
        return jnp.asarray(perm.astype(np.int16))
    return jnp.asarray(perm.astype(np.int32))


def measure_quant_error(f32_art, q_art, Z) -> dict:
    """Scores of the quantized artifact vs its f32 parent on ``Z``.

    This is the number that rides in the quantized artifact's meta: the
    pure quantization error, separate from the family's approximation
    error vs the exact expansion (which ``compile_model`` measures on
    top). Deferred import: families call into this module at compile
    time.
    """
    from repro.core import families

    ref, _ = families.score_artifact(f32_art, Z)
    got, _ = families.score_artifact(q_art, Z)
    err = jnp.abs(got - ref)
    return {
        "quant_holdout_n": int(np.asarray(Z).shape[0]),
        "quant_mean_abs_err": float(jnp.mean(err)),
        "quant_max_abs_err": float(jnp.max(err)),
    }
