"""The ``poly2`` family — the §3.2 degree-2 polynomial expansion as an
approximation of the SAME RBF model.

Folds the SV-side exponential into the support values
(``alpha_i' = alpha_i e^{-gamma ||x_i||^2}``, the paper's remark under
Eq 3.16) and expands e^{2 gamma x^T z} as (1 + gamma x^T z)^2 instead of
the Maclaurin series — the second-order coefficient is x^2/4, not x^2/2.
The artifact is the same quadratic form served by the same fused
``quadform_heads`` path (identical FLOPs and tuning bucket as maclaurin)
but is cheaper to CONSTRUCT (no 2x reweighting, and the per-term bound
analysis carries a different constant): per-term relative error under the
Eq 3.11 envelope is ``bounds.POLY2_REL_ERR_AT_HALF`` (7.26%) vs
maclaurin's 3.05%. ``compile_model`` exists precisely to measure which
trade-off a given model/budget actually wants.
"""

from __future__ import annotations

import jax

from repro.core.bounds import POLY2_REL_ERR_AT_HALF
from repro.core.families import quantize
from repro.core.families.base import CompiledArtifact, stack_heads
from repro.core.families import maclaurin as _mac
from repro.core.poly2 import collapse_rbf_as_poly2
from repro.core.rbf import SVMModel

NAME = "poly2"
TILE_KERNEL = _mac.TILE_KERNEL                   # same fused serving kernel
TILE_KERNEL_Q8 = _mac.TILE_KERNEL_Q8


def compile(                                                   # noqa: A001
    svm: SVMModel,
    *,
    dtype: str = "float32",
    seed: int = 0,
    holdout=None,
    holdout_n: int = 256,
    **_opts,
) -> CompiledArtifact:
    """Collapse every head via the poly-2 expansion (Eqs 3.13-3.16).

    Same artifact kind as maclaurin, so ``dtype="int8"`` rides the shared
    quadform quantizer (per-column-group Hessian scales, measured error in
    the meta).
    """
    quantize.check_dtype(dtype)
    ay2, b, k, multiclass = stack_heads(svm)

    def one(ay_k, b_k):
        return collapse_rbf_as_poly2(
            SVMModel(X=svm.X, alpha_y=ay_k, b=b_k, gamma=svm.gamma)
        )

    art = _mac._quadform_artifact(
        NAME, jax.vmap(one)(ay2, b), multiclass,
        rel_err_at_half=POLY2_REL_ERR_AT_HALF,
    )
    if dtype == quantize.INT8_DTYPE:
        art = _mac.quantize_quadform_artifact(
            art, svm, seed=seed, holdout=holdout, holdout_n=holdout_n
        )
    return art


# Same artifact kind => same scorer and tuning resolution as maclaurin.
score = _mac.score
pad_heads = _mac.pad_heads
score_sharded = _mac.score_sharded
tile_lookup = _mac.tile_lookup
