"""Approximation-validity bounds (§3.1 and Appendix A of the paper).

Three tools:

  * ``maclaurin_rel_error``     — Eq A.2 / Fig 1: the absolute relative error
                                  of the 2nd-order Maclaurin series of exp.
  * ``gamma_max``               — pre-training bound: largest gamma for which
                                  Eq 3.11 is guaranteed on a given data set.
  * ``validity_fraction`` etc.  — run-time checks of Eq 3.11 / Eq 3.9.

The guarantee chain:  |x| < 1/2  =>  rel.err(exp approx) < 3.05%   (A.2)
                      |2 gamma x_i^T z| < 1/2  for all i           (3.9)
      Cauchy-Schwarz: ||x_M||^2 ||z||^2 < 1/(16 gamma^2)           (3.11)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# Eq A.2: sup_{|x|<1/2} |(e^x - 1 - x - x^2/2) / e^x| < 0.0305
REL_ERR_AT_HALF = 0.0305

# The §3.2 analogue for the poly-2 family: approximating e^x by the
# degree-2 polynomial-kernel expansion (1 + x/2)^2 = 1 + x + x^2/4 under
# the same |x| < 1/2 envelope. The sup is attained at x = -1/2:
# |e^{-1/2} - (3/4)^2| / e^{-1/2} = 0.07256... — the poly-2 artifact is
# cheaper to build (no SV-side exponentials) but ~2.4x looser per term.
POLY2_REL_ERR_AT_HALF = 0.0726


def poly2_exp(x: Array) -> Array:
    """The poly-2 family's implicit exp approximation: (1 + x/2)^2."""
    q = 1.0 + 0.5 * x
    return q * q


def poly2_rel_error(x: Array) -> Array:
    """Absolute relative error of the poly-2 exp approximation (the §3.2
    analogue of Fig 1; its sup on |x| <= 1/2 is POLY2_REL_ERR_AT_HALF)."""
    return jnp.abs((jnp.exp(x) - poly2_exp(x)) / jnp.exp(x))


def maclaurin_exp(x: Array) -> Array:
    """Second-order Maclaurin series of exp: 1 + x + x^2/2 (Eq A.1)."""
    return 1.0 + x + 0.5 * x * x


def maclaurin_rel_error(x: Array) -> Array:
    """Absolute relative error |(e^x - (1+x+x^2/2)) / e^x|  (Fig 1)."""
    return jnp.abs((jnp.exp(x) - maclaurin_exp(x)) / jnp.exp(x))


def gamma_max(X: Array) -> Array:
    """Largest gamma guaranteeing Eq 3.11 for every pair drawn from data X.

    Uses the max instance norm for both the SV and the test-point role
    (the paper notes this is slightly over-conservative because the max-norm
    instance need not become a support vector):

        ||x_M||^2 ||z||^2 < 1/(16 gamma^2)   with ||z|| <= ||x_M||
        =>  gamma < 1 / (4 ||x_M||^2)
    """
    max_sq = jnp.max(jnp.sum(X * X, axis=-1))
    return 1.0 / (4.0 * max_sq)


def bound_holds(max_sv_sq_norm: Array, z_sq_norm: Array, gamma: Array) -> Array:
    """Eq 3.11 per test instance (broadcastable)."""
    return max_sv_sq_norm * z_sq_norm < 1.0 / (16.0 * gamma**2)


def exact_bound_holds(X_sv: Array, z: Array, gamma: Array) -> Array:
    """Eq 3.9 directly (needs the inner products — used in tests only)."""
    u = 2.0 * gamma * (X_sv @ z)
    return jnp.all(jnp.abs(u) < 0.5)


@jax.jit
def validity_fraction(max_sv_sq_norm: Array, Z: Array, gamma: Array) -> Array:
    """Fraction of a test batch adhering to Eq 3.11."""
    z_sq = jnp.sum(Z * Z, axis=-1)
    return jnp.mean(bound_holds(max_sv_sq_norm, z_sq, gamma).astype(jnp.float32))


def max_abs_exponent(X_sv: Array, Z: Array, gamma: Array) -> Array:
    """max_{i,j} |2 gamma x_i^T z_j| — the true quantity bounded by Eq 3.11.

    O(n_sv * n) — diagnostic only, quantifies how conservative Cauchy-Schwarz
    is on a given data set (the paper's epsilon-vs-sensit discussion, §4.2).
    """
    return jnp.max(jnp.abs(2.0 * gamma * (Z @ X_sv.T)))
