"""The paper's primary contribution: second-order Maclaurin collapse of
RBF kernel expansions (exact model -> (c, v, M) quadratic form), with the
validity bounds of §3.1 and the poly-2 relation of §3.2.

The collapse is one member of the pluggable approximation-family layer in
``repro.core.families`` (maclaurin / poly2 / fourier); ``compile_model``
there turns any exact model into the cheapest servable artifact meeting
an accuracy budget."""

from repro.core import backend
from repro.core.rbf import SVMModel, rbf_kernel, decision_function, predict_labels
from repro.core.maclaurin import (
    ApproxModel,
    approximate,
    approx_decision_function,
    approx_decision_function_checked,
    hybrid_decision_function,
)
from repro.core.bounds import (
    gamma_max,
    bound_holds,
    maclaurin_exp,
    maclaurin_rel_error,
    validity_fraction,
    REL_ERR_AT_HALF,
    POLY2_REL_ERR_AT_HALF,
)
from repro.core.families import Budget, CompiledArtifact, compile_model

__all__ = [
    "Budget",
    "CompiledArtifact",
    "compile_model",
    "POLY2_REL_ERR_AT_HALF",
    "SVMModel",
    "rbf_kernel",
    "decision_function",
    "predict_labels",
    "ApproxModel",
    "approximate",
    "approx_decision_function",
    "approx_decision_function_checked",
    "hybrid_decision_function",
    "gamma_max",
    "bound_holds",
    "maclaurin_exp",
    "maclaurin_rel_error",
    "validity_fraction",
    "REL_ERR_AT_HALF",
]
