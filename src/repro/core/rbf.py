"""Exact RBF-kernel expansion models (Eq 3.2/3.3 of the paper).

The exact decision function of any representer-theorem model with an RBF
kernel is

    f(z) = sum_i  alpha_i y_i exp(-gamma ||x_i - z||^2) + b.

We store ``alpha_y = alpha * y`` as one vector (the paper never needs them
separately at prediction time) and support vectors as rows of ``X``
(``(n_sv, d)``; the paper uses the transposed convention ``d x n_sv``).

TPU note: the hot loop is expressed as ``||x||^2 + ||z||^2 - 2 Z X^T`` so the
pairwise distance matrix comes out of a single GEMM on the MXU rather than a
lane-hostile subtract-square-reduce.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SVMModel:
    """An exact RBF kernel expansion (SVM / LS-SVM / any representer model).

    Attributes:
      X:        (n_sv, d) support vectors, one per row.
      alpha_y:  (n_sv,) combined support values ``alpha_i * y_i``.
      b:        scalar bias.
      gamma:    scalar RBF kernel parameter.
    """

    X: Array
    alpha_y: Array
    b: Array
    gamma: Array

    @property
    def n_sv(self) -> int:
        return self.X.shape[0]

    @property
    def d(self) -> int:
        return self.X.shape[1]

    def num_parameters(self) -> int:
        """Stored scalars: SVs + alpha_y + b + gamma (Table-3 accounting)."""
        return self.X.size + self.alpha_y.size + 2


def rbf_kernel(Xa: Array, Xb: Array, gamma: Array) -> Array:
    """Pairwise RBF kernel matrix K[i, j] = exp(-gamma ||a_i - b_j||^2).

    Computed via the GEMM expansion; clamps tiny negative distances arising
    from cancellation.
    """
    sq_a = jnp.sum(Xa * Xa, axis=-1)[:, None]
    sq_b = jnp.sum(Xb * Xb, axis=-1)[None, :]
    d2 = sq_a + sq_b - 2.0 * (Xa @ Xb.T)
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-gamma * d2)


@partial(jax.jit, static_argnames=())
def decision_function(model: SVMModel, Z: Array) -> Array:
    """Exact decision values f(Z) for a batch of test rows Z (n, d)."""
    K = rbf_kernel(Z, model.X, model.gamma)  # (n, n_sv)
    return K @ model.alpha_y + model.b


def decision_function_loops(model: SVMModel, Z: Array) -> Array:
    """The paper's LOOPS baseline: stream one SV at a time (no GEMM).

    Deliberately naive — used by the Table-2 benchmark to reproduce the
    LOOPS-vs-BLAS ordering. O(n_sv) sequential steps via ``lax.scan``.
    """

    def body(acc, xi_ai):
        xi, ai = xi_ai
        diff = Z - xi[None, :]
        k = jnp.exp(-model.gamma * jnp.sum(diff * diff, axis=-1))
        return acc + ai * k, None

    init = jnp.zeros(Z.shape[0], dtype=Z.dtype)
    acc, _ = jax.lax.scan(body, init, (model.X, model.alpha_y))
    return acc + model.b


def predict_labels(model: SVMModel, Z: Array) -> Array:
    """Binary labels in {-1, +1}."""
    return jnp.where(decision_function(model, Z) >= 0, 1, -1)


def model_bytes(model: SVMModel) -> int:
    """In-memory size of the exact model (for the Table-3 analogue)."""
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree_util.tree_leaves(model)
    )
