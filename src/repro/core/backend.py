"""Backend dispatch for the SVM prediction hot path.

One process-level decision, made here and nowhere else, of HOW the
serving primitives are evaluated:

  * the collapsed quadratic form (Eq 3.8), fused over K heads — the fast
    path of ``approx_decision_function*``, ``approx_ovr_predict`` and the
    maclaurin/poly2 artifact families;
  * fused random-Fourier-feature scoring (projection + cos + weight dot
    per Z tile) — the fourier family's fast path;
  * the exact RBF expansion (Eq 3.2) — the engine's accuracy fallback and
    every Table-1/2 oracle.

The FAMILY axis sits one level up: ``family_scores`` dispatches a
``CompiledArtifact`` (see ``repro.core.families``) to whichever primitive
its family serves through, so the engine and benchmarks never switch on
family names themselves.

Backends:

  * ``"pallas"`` — the kernels in ``repro.kernels.{quadform,rbf_pred}``:
    Hessians resident in VMEM, one MXU contraction scoring all K heads per
    Z tile, streaming SV tiles for the exact path.  Compiled natively on
    TPU; interpret mode elsewhere (correct but slow — tests only).
  * ``"xla"``   — algebraically identical single-GEMM jnp formulations
    that XLA fuses well on CPU/GPU: the (d, K*d) stacked-Hessian operand
    makes the K-head quadratic term ONE dot_general regardless of K.

Resolution order: ``set_backend(...)`` > ``$REPRO_SVM_BACKEND`` > auto
(pallas iff the default jax backend is TPU).  The choice is read at trace
time: functions already jit-compiled keep the backend they were traced
with — set it before first use (process start / test setup).

Tile sizes travel as a ``TileConfig`` from ``repro.kernels.common``:
callers that know their shape bucket (the serving engine) pass a resolved
config; ``config=None`` resolves the measured-or-default entry for the
operand shapes from the tuning registry right here, so every dispatch —
not just the engine's — benefits from the checked-in tuning table.

All scalars (c, b, gamma, ...) are traced values, so everything here
composes with outer jits over model pytrees.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.common import TileConfig, tuning
from repro.kernels.fwht.kernel import (
    fastfood_score_pallas,
    fastfood_score_q8_pallas,
)
from repro.kernels.fwht.ref import fastfood_score_q8_ref, fastfood_score_ref
from repro.kernels.quadform.kernel import (
    quadform_heads_pallas,
    quadform_heads_q8_pallas,
)
from repro.kernels.quadform.ref import eq311_valid
from repro.kernels.rbf_pred.kernel import rbf_predict_pallas
from repro.kernels.rff_score.kernel import rff_score_pallas, rff_score_q8_pallas

Array = jax.Array

_ENV_VAR = "REPRO_SVM_BACKEND"
_VALID = ("auto", "pallas", "xla")
_forced: str | None = None


def set_backend(name: str | None) -> str | None:
    """Force the backend for this process ("pallas" / "xla" / "auto" / None).

    Returns the previous forced value so tests can restore it.
    """
    global _forced
    if name is not None and name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {name!r}")
    prev = _forced
    _forced = None if name in (None, "auto") else name
    return prev


def resolve() -> str:
    """The backend the next trace will use: "pallas" or "xla"."""
    choice = _forced or os.environ.get(_ENV_VAR, "auto")
    if choice not in _VALID:
        raise ValueError(f"${_ENV_VAR} must be one of {_VALID}, got {choice!r}")
    if choice == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return choice


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------- profiling seam
#
# The serving layer's observability package can install a trace-time
# scope factory (jax.named_scope) here so scoring functions traced while
# profiling is enabled carry structured op names in profiler timelines.
# A callback hook keeps the layering clean: repro.core never imports
# repro.serve. When unset, _scope is a no-op nullcontext.

_profile_scope = None


def set_profile_scope(factory) -> None:
    """Install (or clear, with None) a ``name -> context manager`` factory
    wrapped around the top-level dispatch seams (``family_scores``,
    ``rbf_scores``). Installed by ``repro.serve.runtime.obs.profile``."""
    global _profile_scope
    _profile_scope = factory


def _scope(name: str):
    factory = _profile_scope
    if factory is None:
        import contextlib

        return contextlib.nullcontext()
    return factory(name)


# --------------------------------------------------------------- quadform


def quadform_heads_xla(Z, M_all, V, c, b, gamma, msq):
    """Fused K-head quadratic form as ONE XLA GEMM (not K).

    Identical math to the Pallas kernel: the K Hessians are laid out as a
    single (d, K*d) operand so the quadratic term of every head comes out
    of one dot_general, followed by a (n, K) row-dot, the thin Z @ V^T
    GEMM and the exp/bias/validity epilogue.
    """
    n, d = Z.shape
    k = M_all.shape[0]
    z_sq = jnp.sum(Z * Z, axis=-1)                          # (n,)
    m_kd = jnp.transpose(M_all, (1, 0, 2)).reshape(d, k * d)
    zm = (Z @ m_kd).reshape(n, k, d)                        # ONE GEMM, all heads
    quad = jnp.einsum("nkd,nd->nk", zm, Z)
    lin = Z @ V.T                                           # (n, K)
    env = jnp.exp(-z_sq[:, None] * gamma[None, :])
    scores = env * (c[None, :] + lin + quad) + b[None, :]
    return scores, z_sq, eq311_valid(z_sq, gamma, msq)


def quadform_heads(Z, M_all, V, c, b, gamma, msq, *, config: TileConfig | None = None):
    """Dispatching fused K-head scores.

    Z: (n, d); M_all: (K, d, d); V: (K, d); c/b/gamma/msq: (K,).
    Returns (scores (n, K), z_sq (n,), valid (n, K)) where valid is the
    per-head Eq 3.11 mask. ``config=None`` resolves the tuned (or default)
    ``TileConfig`` for this (d, K, n) bucket from the tuning registry.
    """
    if config is None:
        config = tuning.lookup(
            "quadform",
            tuning.shape_key(
                d=Z.shape[1], k=M_all.shape[0], n=tuning.bucket(Z.shape[0])
            ),
        )
    if resolve() == "pallas":
        return quadform_heads_pallas(
            Z, M_all, V, c, b, gamma, msq,
            config=config, interpret=_interpret(),
        )
    return quadform_heads_xla(Z, M_all, V, c, b, gamma, msq)


def quadform_heads_q8_xla(Z, M_q, col_scale, V, c, b, gamma, msq):
    """Int8-Hessian K-head quadratic form as one int8->f32 GEMM under XLA.

    The stacked int8 operand is upcast INSIDE the contraction (XLA fuses
    the convert into the GEMM loop on CPU — the weights stay int8 in
    memory); the per-(head, column) scales fold onto the (n, K, d) GEMM
    result with one broadcast multiply before the row-dot, exactly the
    math the Pallas tile performs in VMEM.
    """
    n, d = Z.shape
    k = M_q.shape[0]
    z_sq = jnp.sum(Z * Z, axis=-1)                          # (n,)
    m_kd = jnp.transpose(M_q, (1, 0, 2)).reshape(d, k * d)
    zm = (Z @ m_kd.astype(jnp.float32)).reshape(n, k, d)    # ONE GEMM, all heads
    zm = zm * col_scale[None, :, :]                         # fold dequant scales
    quad = jnp.einsum("nkd,nd->nk", zm, Z)
    lin = Z @ V.T                                           # (n, K)
    env = jnp.exp(-z_sq[:, None] * gamma[None, :])
    scores = env * (c[None, :] + lin + quad) + b[None, :]
    return scores, z_sq, eq311_valid(z_sq, gamma, msq)


def quadform_heads_q8(
    Z, M_q, col_scale, V, c, b, gamma, msq, *, config: TileConfig | None = None
):
    """Dispatching fused K-head scores off an int8-quantized Hessian.

    Z: (n, d); M_q: (K, d, d) int8; col_scale: (K, d) f32 per-column
    dequant scales; V: (K, d) f32 (already dequantized — it is thin);
    c/b/gamma/msq: (K,). Same return contract as ``quadform_heads``.
    ``config=None`` resolves the ``quadform_q8`` tuning family for this
    (d, K, n) bucket.
    """
    if config is None:
        config = tuning.lookup(
            "quadform_q8",
            tuning.shape_key(
                d=Z.shape[1], k=M_q.shape[0], n=tuning.bucket(Z.shape[0])
            ),
        )
    if resolve() == "pallas":
        return quadform_heads_q8_pallas(
            Z, M_q, col_scale, V, c, b, gamma, msq,
            config=config, interpret=_interpret(),
        )
    return quadform_heads_q8_xla(Z, M_q, col_scale, V, c, b, gamma, msq)


def quadform_heads_sharded(
    Z, M_all, V, c, b, gamma, msq, *, mesh, config: TileConfig | None = None
):
    """``quadform_heads`` with the K heads sharded over a device mesh.

    The stacked Hessian (K, d, d) — the operand that busts one device's
    memory in the extreme-multiclass regime — and every other per-head
    array are partitioned over ``mesh``'s first axis; Z is replicated.
    Each device runs the SAME fused per-shard primitive the single-
    device path uses (one GEMM for its K/shards heads), so tuning and
    backend choice apply per shard. Outputs stay head-sharded
    (``P(None, axis)``): a consumer reducing over heads (the engine's
    argmax) lets XLA insert the one cross-shard reduce at the end
    instead of gathering (n, K) scores to every device.

    K must divide evenly by the axis size — pad validity-neutral heads
    first (``families.*.pad_heads``). Returns (scores (n, K),
    valid (n, K)); ``z_sq`` is a per-shard by-product and is not
    returned (the per-head validity mask already encodes it).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    shards = mesh.shape[axis]
    k = M_all.shape[0]
    if k % shards:
        raise ValueError(
            f"num_heads ({k}) must divide by mesh axis {axis!r} ({shards}); "
            f"pad validity-neutral heads first"
        )

    def _local(Zb, Ms, Vs, cs, bs, gs, ms):
        scores, _, valid = quadform_heads(
            Zb, Ms, Vs, cs, bs, gs, ms, config=config
        )
        return scores, valid

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(), P(axis, None, None), P(axis, None),
                  P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(None, axis), P(None, axis)),
    )
    return fn(Z, M_all, V, c, b, gamma, msq)


def quadform_heads_q8_sharded(
    Z, M_q, col_scale, V, c, b, gamma, msq, *, mesh,
    config: TileConfig | None = None,
):
    """``quadform_heads_q8`` with the K heads sharded over a device mesh.

    Same partitioning as the f32 path — the int8 stacked Hessian AND its
    per-(head, column) dequant scales carry the head axis, so both shard
    together and the scale fold happens inside each device's fused
    per-shard primitive (the scale epilogue never crosses the wire).
    Int8 sharding is where head sharding pays most: the same mesh holds a
    4x bigger K before the Hessian busts per-device memory.

    K must divide the axis size (pad validity-neutral heads first).
    Returns head-sharded (scores (n, K), valid (n, K)).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    shards = mesh.shape[axis]
    k = M_q.shape[0]
    if k % shards:
        raise ValueError(
            f"num_heads ({k}) must divide by mesh axis {axis!r} ({shards}); "
            f"pad validity-neutral heads first"
        )

    def _local(Zb, Ms, cols, Vs, cs, bs, gs, ms):
        scores, _, valid = quadform_heads_q8(
            Zb, Ms, cols, Vs, cs, bs, gs, ms, config=config
        )
        return scores, valid

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(), P(axis, None, None), P(axis, None), P(axis, None),
                  P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(None, axis), P(None, axis)),
    )
    return fn(Z, M_q, col_scale, V, c, b, gamma, msq)


# ------------------------------------------------------------ rff scoring


def rff_score_xla(Z, W, phase, weights, bias):
    """RFF scoring as two GEMMs with the cos epilogue between them.

    Identical math to the Pallas kernel; XLA materializes the (n, F)
    feature block between the projection and the weight contraction,
    which is fine on CPU/GPU where there is no small fast memory to keep
    it resident in.
    """
    phi = jnp.cos(Z @ W.T + phase[None, :])
    return phi @ weights.T + bias[None, :]


def rff_score(Z, W, phase, weights, bias, *, config: TileConfig | None = None):
    """Dispatching fused random-Fourier-feature scores.

    Z: (n, d); W: (F, d); phase: (F,); weights: (K, F) with the 2/F
    feature scaling folded in at compile time; bias: (K,). Returns
    per-head scores (n, K). ``config=None`` resolves the tuned (or
    default) ``TileConfig`` for this (d, F, n) bucket.
    """
    if config is None:
        config = tuning.lookup(
            "rff_score",
            tuning.shape_key(
                d=Z.shape[1], f=W.shape[0], n=tuning.bucket(Z.shape[0])
            ),
        )
    if resolve() == "pallas":
        return rff_score_pallas(
            Z, W, phase, weights, bias, config=config, interpret=_interpret()
        )
    return rff_score_xla(Z, W, phase, weights, bias)


def rff_score_sharded(
    Z, W, phase, weights, bias, *, mesh, config: TileConfig | None = None
):
    """``rff_score`` with the (K, F) readout sharded over a device mesh.

    The projection (W, phase) is per-row work and stays replicated —
    each device computes the (n, F) feature block for its shard of
    heads; only the readout weights and bias partition over ``mesh``'s
    first axis. That trades F·n duplicate flops per device for zero
    cross-device traffic before the final head reduce, the right trade
    whenever K·F (the readout) dominates F·d (the projection), i.e.
    exactly the extreme-multiclass regime head sharding exists for.

    K must divide evenly by the axis size (pad heads first). Returns
    head-sharded scores (n, K), spec ``P(None, axis)``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    shards = mesh.shape[axis]
    k = weights.shape[0]
    if k % shards:
        raise ValueError(
            f"num_heads ({k}) must divide by mesh axis {axis!r} ({shards}); "
            f"pad validity-neutral heads first"
        )

    def _local(Zb, Wf, ph, ws, bs):
        return rff_score(Zb, Wf, ph, ws, bs, config=config)

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(axis, None), P(axis)),
        out_specs=P(None, axis),
    )
    return fn(Z, W, phase, weights, bias)


def rff_score_q8_xla(Z, W_q, w_scale, phase, weights_q, wt_scale, bias):
    """Int8-weights RFF scoring as two int8->f32 GEMMs under XLA; both
    quantized axes are GEMM output axes, so each scale is one broadcast
    multiply on the small result."""
    proj = (Z @ W_q.astype(jnp.float32).T) * w_scale[None, :]
    phi = jnp.cos(proj + phase[None, :])
    return (phi @ weights_q.astype(jnp.float32).T) * wt_scale[None, :] \
        + bias[None, :]


def rff_score_q8(
    Z, W_q, w_scale, phase, weights_q, wt_scale, bias,
    *, config: TileConfig | None = None,
):
    """Dispatching fused RFF scores off int8 projection + readout weights.

    Z: (n, d); W_q: (F, d) int8 with per-row scales w_scale (F,);
    weights_q: (K, F) int8 with per-head scales wt_scale (K,); phase (F,)
    and bias (K,) stay f32. Returns (n, K). ``config=None`` resolves the
    ``rff_score_q8`` tuning family for this (d, F, n) bucket.
    """
    if config is None:
        config = tuning.lookup(
            "rff_score_q8",
            tuning.shape_key(
                d=Z.shape[1], f=W_q.shape[0], n=tuning.bucket(Z.shape[0])
            ),
        )
    if resolve() == "pallas":
        return rff_score_q8_pallas(
            Z, W_q, w_scale, phase, weights_q, wt_scale, bias,
            config=config, interpret=_interpret(),
        )
    return rff_score_q8_xla(Z, W_q, w_scale, phase, weights_q, wt_scale, bias)


def rff_score_q8_sharded(
    Z, W_q, w_scale, phase, weights_q, wt_scale, bias,
    *, mesh, config: TileConfig | None = None,
):
    """``rff_score_q8`` with the int8 (K, F) readout sharded over a mesh.

    Partitioning mirrors ``rff_score_sharded``: the projection operands
    (W_q, w_scale, phase) replicate — per-row work — while the readout
    codes, their per-head scales and the bias shard over ``mesh``'s first
    axis, so the dequant scale-epilogue folds inside each shard's fused
    primitive. K must divide the axis size (pad heads first). Returns
    head-sharded scores (n, K), spec ``P(None, axis)``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    shards = mesh.shape[axis]
    k = weights_q.shape[0]
    if k % shards:
        raise ValueError(
            f"num_heads ({k}) must divide by mesh axis {axis!r} ({shards}); "
            f"pad validity-neutral heads first"
        )

    def _local(Zb, Wf, ws, ph, wq, wts, bs):
        return rff_score_q8(Zb, Wf, ws, ph, wq, wts, bs, config=config)

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(axis, None), P(axis), P(axis)),
        out_specs=P(None, axis),
    )
    return fn(Z, W_q, w_scale, phase, weights_q, wt_scale, bias)


# ------------------------------------------------------- fastfood scoring


def fastfood_score_xla(Z, B, G, perm, scale, phase, weights, bias):
    """Structured (Fastfood) RFF scoring under XLA: the log-depth
    butterfly stages as reshape/concat ops, then one thin readout GEMM.
    Algebraically identical to the Pallas kernel (same ``fwht`` body)."""
    return fastfood_score_ref(Z, B, G, perm, scale, phase, weights, bias)


def fastfood_score(
    Z, B, G, perm, scale, phase, weights, bias,
    *, config: TileConfig | None = None,
):
    """Dispatching fused Fastfood scores.

    Z: (n, d); B/G/scale: (stacks, d') diagonal operators; perm:
    (stacks, d') int; phase: (F,) with F = stacks*d'; weights: (K, F)
    with the 2/F scaling folded at compile time; bias: (K,). Returns
    (n, K). ``config=None`` resolves the ``fwht`` tuning family for this
    (d, F, n) bucket.
    """
    if config is None:
        config = tuning.lookup(
            "fwht",
            tuning.shape_key(
                d=Z.shape[1], f=B.shape[0] * B.shape[1],
                n=tuning.bucket(Z.shape[0]),
            ),
        )
    if resolve() == "pallas":
        return fastfood_score_pallas(
            Z, B, G, perm, scale, phase, weights, bias,
            config=config, interpret=_interpret(),
        )
    return fastfood_score_xla(Z, B, G, perm, scale, phase, weights, bias)


def fastfood_score_q8_xla(
    Z, b_q, g_q, perm, s_q, stack_scale, phase, weights_q, wt_scale, bias
):
    """Int8-operator Fastfood scoring under XLA: diagonals upcast in
    registers (B is exact +-1 signs), the per-stack combined G*S scale
    folds once per stack on the transform output, and the readout is an
    int8->f32 GEMM with the per-head scale fold — the same epilogue
    placement as the Pallas tile."""
    return fastfood_score_q8_ref(
        Z, b_q, g_q, perm, s_q, stack_scale, phase, weights_q, wt_scale, bias
    )


def fastfood_score_q8(
    Z, b_q, g_q, perm, s_q, stack_scale, phase, weights_q, wt_scale, bias,
    *, config: TileConfig | None = None,
):
    """Dispatching fused Fastfood scores off int8 operators.

    b_q/g_q/s_q: (stacks, d') int8 (b_q holds exact +-1 signs);
    stack_scale: (stacks,) f32 combined G*S row scales; weights_q: (K, F)
    int8 with per-head scales wt_scale (K,); phase (F,) and bias (K,)
    f32 (phase may arrive f16 — it is upcast at trace time). Returns
    (n, K). ``config=None`` resolves the ``fwht_q8`` tuning family.
    """
    if config is None:
        config = tuning.lookup(
            "fwht_q8",
            tuning.shape_key(
                d=Z.shape[1], f=b_q.shape[0] * b_q.shape[1],
                n=tuning.bucket(Z.shape[0]),
            ),
        )
    if resolve() == "pallas":
        return fastfood_score_q8_pallas(
            Z, b_q, g_q, perm, s_q, stack_scale, phase,
            weights_q, wt_scale, bias,
            config=config, interpret=_interpret(),
        )
    return fastfood_score_q8_xla(
        Z, b_q, g_q, perm, s_q, stack_scale, phase, weights_q, wt_scale, bias
    )


def fastfood_score_sharded(
    Z, B, G, perm, scale, phase, weights, bias,
    *, mesh, config: TileConfig | None = None,
):
    """``fastfood_score`` with the (K, F) readout sharded over a mesh.

    The replication trade that makes dense-RFF head sharding worthwhile
    (``rff_score_sharded``) is STRICTLY BETTER here: the replicated
    per-shard work is the O(F log d') structured transform instead of an
    O(F d) GEMM, while the sharded operand — the (K, F) readout, the
    only O(K) memory in the artifact — is the same. K must divide the
    axis size (pad heads first). Returns head-sharded scores (n, K).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    shards = mesh.shape[axis]
    k = weights.shape[0]
    if k % shards:
        raise ValueError(
            f"num_heads ({k}) must divide by mesh axis {axis!r} ({shards}); "
            f"pad validity-neutral heads first"
        )

    def _local(Zb, Bs, Gs, ps, ss, ph, ws, bs):
        return fastfood_score(Zb, Bs, Gs, ps, ss, ph, ws, bs, config=config)

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(), P(axis, None), P(axis)),
        out_specs=P(None, axis),
    )
    return fn(Z, B, G, perm, scale, phase, weights, bias)


def fastfood_score_q8_sharded(
    Z, b_q, g_q, perm, s_q, stack_scale, phase, weights_q, wt_scale, bias,
    *, mesh, config: TileConfig | None = None,
):
    """``fastfood_score_q8`` with the int8 readout sharded over a mesh.

    The O(F) int8 diagonals and phase replicate; the int8 (K, F) readout
    codes, their per-head scales and the bias partition over ``mesh``'s
    first axis — the scale-epilogue folds per shard, exactly like
    ``rff_score_q8_sharded``. K must divide the axis size (pad heads
    first). Returns head-sharded scores (n, K).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]
    shards = mesh.shape[axis]
    k = weights_q.shape[0]
    if k % shards:
        raise ValueError(
            f"num_heads ({k}) must divide by mesh axis {axis!r} ({shards}); "
            f"pad validity-neutral heads first"
        )

    def _local(Zb, bq, gq, ps, sq, ssc, ph, wq, wts, bs):
        return fastfood_score_q8(
            Zb, bq, gq, ps, sq, ssc, ph, wq, wts, bs, config=config
        )

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(), P(),
                  P(axis, None), P(axis), P(axis)),
        out_specs=P(None, axis),
    )
    return fn(
        Z, b_q, g_q, perm, s_q, stack_scale, phase, weights_q, wt_scale, bias
    )


# ------------------------------------------------------------- family axis


def family_scores(artifact, Z, *, config: TileConfig | None = None):
    """Score a ``CompiledArtifact`` through its family's serving primitive.

    Returns ``(scores (n, K), valid_rows (n,))`` — the family decides what
    "valid" means (per-row Eq 3.11 envelope for the quadform families, the
    compile-time held-out error verdict broadcast over rows for fourier).
    Thin front door over ``families.score_artifact`` (ONE implementation
    of the dispatch); the import is deferred because families call back
    into this module's primitives.
    """
    from repro.core import families

    with _scope(f"repro.backend/family_scores/{artifact.family}"):
        return families.score_artifact(artifact, Z, config=config)


# -------------------------------------------------------------- exact RBF


def rbf_scores_xla(Z, X, alpha_y, gamma, b):
    """Exact expansion via the GEMM distance trick (what XLA fuses well)."""
    sq_z = jnp.sum(Z * Z, axis=-1)[:, None]
    sq_x = jnp.sum(X * X, axis=-1)[None, :]
    d2 = jnp.maximum(sq_z + sq_x - 2.0 * (Z @ X.T), 0.0)
    return jnp.exp(-gamma * d2) @ alpha_y + b


def rbf_scores(Z, X, alpha_y, gamma, b, *, config: TileConfig | None = None):
    """Dispatching exact decision values f(Z) = sum_i a_i K(x_i, z) + b.

    The Pallas path streams double-buffered SV tiles flash-attention-style
    (never materializes the (n, n_sv) kernel matrix in HBM).
    ``config=None`` resolves the tuned (or default) ``TileConfig`` for
    this (d, m, n) bucket from the tuning registry.
    """
    if config is None:
        config = tuning.lookup(
            "rbf_pred",
            tuning.shape_key(d=Z.shape[1], m=X.shape[0], n=tuning.bucket(Z.shape[0])),
        )
    with _scope("repro.backend/rbf_scores"):
        if resolve() == "pallas":
            return rbf_predict_pallas(
                Z, X, alpha_y, gamma, b,
                config=config, interpret=_interpret(),
            )
        return rbf_scores_xla(Z, X, alpha_y, gamma, b)
