"""Second-order Maclaurin approximation of RBF kernel expansions (§3).

Collapses f(z) = sum_i alpha_i y_i exp(-gamma ||x_i - z||^2) + b into the
fixed-size quadratic form (Eq 3.8)

    f_hat(z) = exp(-gamma ||z||^2) (c + v^T z + z^T M z) + b

with (Eq 3.7, matrix form):

    c = sum_i alpha_y_i exp(-gamma ||x_i||^2)            -- g(0)
    v = X^T w,   w_i = 2 gamma   alpha_y_i exp(-gamma ||x_i||^2)   -- gradient
    M = X^T D X, D_ii = 2 gamma^2 alpha_y_i exp(-gamma ||x_i||^2)  -- Hessian

(our X is (n_sv, d) row-major, hence the transposes relative to the paper's
column-major X). Construction is a single GEMM — the paper's ATLAS argument,
our MXU argument. Prediction is O(d^2) independent of n_sv.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.core.rbf import SVMModel

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ApproxModel:
    """The approximated model: three scalars, a d-vector and a d x d matrix.

    ``max_sv_sq_norm`` stores ||x_M||^2 of the max-norm SV so the validity
    bound (Eq 3.11) can be checked at prediction time for free.
    """

    c: Array
    v: Array          # (d,)
    M: Array          # (d, d), symmetric
    b: Array
    gamma: Array
    max_sv_sq_norm: Array

    @property
    def d(self) -> int:
        return self.v.shape[0]

    def num_parameters(self) -> int:
        """Stored scalars: c, v, M, b, gamma, ||x_M||^2 (Table-3 accounting)."""
        return self.v.size + self.M.size + 4


@jax.jit
def approximate(model: SVMModel) -> ApproxModel:
    """Build (c, v, M) from an exact model. One pass; cost O(n_sv d^2) GEMM."""
    X, ay, gamma = model.X, model.alpha_y, model.gamma
    sv_sq_norms = jnp.sum(X * X, axis=-1)                      # (n_sv,)
    base = ay * jnp.exp(-gamma * sv_sq_norms)                  # alpha_y e^{-g||x||^2}
    c = jnp.sum(base)
    w = 2.0 * gamma * base                                     # (n_sv,)
    v = X.T @ w                                                # (d,)
    dvals = 2.0 * gamma**2 * base                              # D diagonal
    M = jnp.einsum("i,ij,ik->jk", dvals, X, X)                 # X^T D X
    return ApproxModel(
        c=c,
        v=v,
        M=M,
        b=model.b,
        gamma=gamma,
        max_sv_sq_norm=jnp.max(sv_sq_norms),
    )


def _as_heads(model: ApproxModel):
    """One ApproxModel viewed as a K=1 stack for the fused backend path."""
    one = lambda x: jnp.reshape(x, (1,))
    return (
        model.M[None],
        model.v[None],
        one(model.c),
        one(model.b),
        one(model.gamma),
        one(model.max_sv_sq_norm),
    )


@jax.jit
def approx_decision_function(model: ApproxModel, Z: Array) -> Array:
    """f_hat(Z) per Eq 3.8. O(d^2) per row. Dispatched via repro.core.backend
    (Pallas kernel on TPU, fused single-GEMM XLA elsewhere)."""
    scores, _, _ = backend.quadform_heads(Z, *_as_heads(model))
    return scores[:, 0]


@jax.jit
def approx_decision_function_checked(model: ApproxModel, Z: Array) -> tuple[Array, Array]:
    """f_hat(Z) plus the per-instance validity flag of Eq 3.11.

    valid[i] == True guarantees every term in the linear combination had
    relative error < 3.05% (conservative, via Cauchy-Schwarz). The check is
    free: ||z||^2 is already needed for the exp(-gamma ||z||^2) factor.
    """
    scores, _, valid = backend.quadform_heads(Z, *_as_heads(model))
    return scores[:, 0], valid[:, 0]


def approx_predict_labels(model: ApproxModel, Z: Array) -> Array:
    return jnp.where(approx_decision_function(model, Z) >= 0, 1, -1)


def approx_model_bytes(model: ApproxModel) -> int:
    """In-memory size of the approximated model (Table-3 analogue)."""
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree_util.tree_leaves(model)
    )


@partial(jax.jit, static_argnames=())
def hybrid_decision_function(
    approx: ApproxModel, exact: SVMModel, Z: Array
) -> tuple[Array, Array]:
    """Beyond-paper hybrid: approx fast path, exact fallback where Eq 3.11 fails.

    Returns (values, used_fast_path mask). Rows violating the bound are
    re-evaluated exactly, preserving the paper's accuracy guarantee without
    globally abandoning the speedup. With data-dependent gather this would be
    ragged; we keep it dense (select) so it stays jit/TPU friendly — the
    exact pass prices at the full batch, so the engine layer batches
    violating rows separately (see repro.serve.svm_engine).
    """
    from repro.core.rbf import decision_function

    f_hat, valid = approx_decision_function_checked(approx, Z)
    f_exact = decision_function(exact, Z)
    return jnp.where(valid, f_hat, f_exact), valid
