"""Production SVM prediction engine — the paper's application layer (§5).

A stream of feature vectors needs decision values at minimum latency
(object detection under heavy traffic). The engine serves a compiled
approximation ARTIFACT — any ``repro.core.families`` family: the paper's
Maclaurin quadratic form, the §3.2 poly-2 expansion, or random Fourier
features — through that family's fused backend path, and enforces the
family's accuracy contract at run time. Artifacts may be f32 or int8
(``dtype="int8"`` compiles): the family's scorer dispatches on
``artifact.dtype`` to the fused dequantizing kernels, each bucket's
``TileConfig`` resolves under the int8 kernel's own tuning family
(``quadform_q8`` / ``rff_score_q8``), and the engine's contract is
otherwise unchanged — same buckets, same validity mask, same fallback.
A bare ``ApproxModel`` is still accepted (wrapped into a maclaurin
artifact), so pre-families callers keep working. Design:

Shape buckets, bounded jit cache
  Traffic arrives with arbitrary batch sizes; naive jit would recompile
  per distinct shape. Every batch is padded host-side to the next
  power-of-two bucket (floored at ``min_bucket``, capped at ``max_batch``
  — longer batches are chunked), so the engine owns at most
  log2(max_batch / min_bucket) + 1 compiled variants and steady-state
  serving performs ZERO recompilations. The padded input buffer is donated
  to the compiled step (no-op on CPU where buffer sizes can't alias; lets
  XLA reuse the buffer on device backends).

Per-bucket tile tuning
  Each bucket resolves its own ``TileConfig`` at trace time from the
  ``repro.kernels.common.tuning`` registry, keyed on the FAMILY's serving
  kernel (``quadform`` for maclaurin/poly2, ``rff_score`` for fourier)
  and shape bucket — a measured entry from the checked-in table if there
  is one, else the kernel default — so ``warmup()`` precompiles the TUNED
  variant of every bucket, not one fixed block size. Resolved configs are
  kept in ``bucket_configs`` for observability; an explicit
  ``tile_config`` argument pins all buckets (A/B runs).

One fused compiled step
  The step scores ALL K heads with a single backend call (one pallas_call
  on TPU / one or two GEMMs under XLA — not K vmapped passes), and fuses
  the family's row-validity computation and the multiclass argmax (or
  binary sign) into the same executable. K = 1 is just the smallest stack.

Head-sharded extreme multiclass (``head_mesh=``)
  In the extreme-OvR regime (K in the thousands) the stacked Hessian
  (K, d, d) is the operand that outgrows one device. A ``head_mesh``
  partitions the heads over the mesh's first axis via the family's
  ``score_sharded`` path (shard_map over the fused per-shard primitive);
  K is padded up to the axis size with argmax- and validity-neutral
  heads, the per-row argmax and validity AND reduce across shards inside
  the compiled step, and ``_finalize`` slices the score columns back to
  the real K. f32 quadform/dense-RFF artifacts only (int8 + sharding
  raises). Orthogonal to ``mesh``, which shards the EXACT path's SVs.

Deferred synchronization
  ``submit`` returns an ``EngineResult`` holding device-resident outputs;
  nothing blocks until the caller materializes ``.values`` / ``.labels`` /
  ``.valid``. A caller pipelining many batches pays one sync at the end,
  not one per batch. ``predict`` is the synchronous convenience wrapper.

Exact fallback (bounded-accuracy serving)
  Each family defines what "inside the accuracy contract" means. The
  quadform families check the Eq 3.11 bound per instance at zero extra
  cost (||z||^2 is a by-product of the envelope); the fourier family has
  no per-row envelope — its contract is the compile-time held-out error
  estimate, so validity is a per-ARTIFACT verdict broadcast over the
  batch (violating artifacts send every row down the exact path). Invalid
  rows are re-scored with the exact expansion via the streaming
  ``rbf_pred`` path (Pallas kernel on TPU: SV tiles streamed
  flash-attention style, never materializing the (n, n_sv) kernel
  matrix). With a ``mesh``, the support vectors are sharded across
  devices (shard_map + psum over the first mesh axis) so arbitrarily
  large exact models serve the slow path too. The paper recommends
  adhering to the bound; the fallback is our beyond-paper extension for
  inputs outside the verified envelope.

Statistics are kept for observability (fallback rate, padding overhead,
bucket histogram, compile count).
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import backend, families
from repro.core.families import CompiledArtifact
from repro.core.maclaurin import ApproxModel
from repro.core.rbf import SVMModel
from repro.kernels.common import TileConfig, tuning

Array = jax.Array

# Profiling seam: repro.serve.runtime.obs.profile installs a context-
# manager factory (jax.profiler.TraceAnnotation) here so engine steps
# show up as named host-side slices in profiler timelines. Push-pattern
# like backend.set_profile_scope — the engine never imports obs, and the
# disabled hot path costs one module-global None check per step.
_profile_annotation = None


def set_profile_annotation(factory) -> None:
    """Install (or clear, with None) a ``name -> context manager`` factory
    wrapped around every engine step dispatch."""
    global _profile_annotation
    _profile_annotation = factory


def _annotate(name: str):
    factory = _profile_annotation
    if factory is None:
        import contextlib

        return contextlib.nullcontext()
    return factory(name)


def bucket_size(n: int, min_bucket: int = 32, max_batch: int = 8192) -> int:
    """Next power-of-two bucket for a batch of n rows (n <= max_batch).

    Delegates to the canonical policy in ``kernels.common.tuning`` so the
    engine's buckets, the sweep's recorded keys and the dispatch-level
    lookups can never drift apart.
    """
    return tuning.bucket(n, lo=min_bucket, hi=max_batch)


@dataclasses.dataclass
class EngineStats:
    """Serving counters, safe under concurrent ``submit()`` callers.

    The micro-batching runtime drives one engine from many threads, so
    every mutation goes through a lock — bare ``x += 1`` on the dataclass
    fields loses updates under contention (CPython interleaves the
    LOAD/STORE pair). Reads of individual counters stay lock-free (single
    attribute loads are atomic); ``snapshot()`` gives a consistent view.
    """

    batches: int = 0
    instances: int = 0
    fallback_instances: int = 0
    compiled_steps: int = 0             # bucket variants traced (compile count)
    padded_instances: int = 0           # wasted rows from bucket padding
    degraded_batches: int = 0           # submit_exact batches (breaker open)
    degraded_instances: int = 0
    bucket_hits: dict = dataclasses.field(default_factory=dict)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def record_batch(self, n: int, buckets: list[tuple[int, int]]) -> None:
        """One submit(): n rows chunked into [(bucket, rows_used), ...]."""
        with self._lock:
            self.batches += 1
            self.instances += n
            for bkt, m in buckets:
                self.padded_instances += bkt - m
                self.bucket_hits[bkt] = self.bucket_hits.get(bkt, 0) + 1

    def record_fallback(self, k: int) -> None:
        with self._lock:
            self.fallback_instances += k

    def record_degraded(self, n: int) -> None:
        """One ``submit_exact`` batch of n rows (kept OUT of the fast-path
        batch/instance counters so ``fallback_rate`` — drift's signal —
        is not polluted by breaker-degraded traffic)."""
        with self._lock:
            self.degraded_batches += 1
            self.degraded_instances += n

    def record_compile(self) -> None:
        with self._lock:
            self.compiled_steps += 1

    def snapshot(self) -> dict:
        """Consistent point-in-time copy of every counter (plain dict)."""
        with self._lock:
            return {
                "batches": self.batches,
                "instances": self.instances,
                "fallback_instances": self.fallback_instances,
                "fallback_rate": self.fallback_instances / max(1, self.instances),
                "compiled_steps": self.compiled_steps,
                "padded_instances": self.padded_instances,
                "padding_overhead": self.padded_instances / max(1, self.instances),
                "degraded_batches": self.degraded_batches,
                "degraded_instances": self.degraded_instances,
                "bucket_hits": dict(self.bucket_hits),
            }

    @property
    def fallback_rate(self) -> float:
        return self.fallback_instances / max(1, self.instances)

    @property
    def padding_overhead(self) -> float:
        return self.padded_instances / max(1, self.instances)


class EngineResult:
    """Device-resident scores for one submitted batch; host sync deferred.

    Each accessor materializes on first use (one device->host transfer,
    then the exact fallback for rows outside the Eq 3.11 envelope).
    """

    def __init__(self, engine: "SVMEngine", Z: np.ndarray | None, chunks):
        self._engine = engine
        self._Z = Z                      # original rows (fallback re-scores);
                                         # None when no fallback can happen
        self._chunks = chunks            # [(scores, valid, labels), n_rows]
        self._done = None
        self._sync = threading.Lock()    # scatter consumers race to be first
        self.on_materialize = None       # scheduler latency hook (fires once)

    def block_until_ready(self) -> "EngineResult":
        for out, _ in self._chunks:
            jax.block_until_ready(out)
        return self

    def _materialize(self):
        # The micro-batcher hands slices of one result to many client
        # threads; the first accessor runs _finalize exactly once (it
        # mutates fallback counters — double-running would double-count).
        with self._sync:
            if self._done is None:
                self._done = self._engine._finalize(self._Z, self._chunks)
                if self.on_materialize is not None:
                    # the hook receives the finalized (values, valid,
                    # labels) so the scheduler can record per-row validity
                    # (the drift window) along with the latency sample
                    self.on_materialize(self._done)
        return self._done

    def split(self, sizes) -> list["SliceResult"]:
        """Scatter hook: carve this result into per-request row spans.

        ``sizes`` are the row counts of the requests that were coalesced
        (in submission order, summing to this result's n). Each returned
        ``SliceResult`` is a zero-copy deferred view — the parent still
        materializes ONCE on first access from any slice, so coalescing
        keeps the engine's deferred-sync property end to end.
        """
        spans, start = [], 0
        for sz in sizes:
            spans.append(SliceResult(self, start, start + sz))
            start += sz
        total = sum(m for _, m in self._chunks)
        if start != total:
            raise ValueError(f"split sizes sum to {start}, result has {total} rows")
        return spans

    @property
    def values(self) -> np.ndarray:
        """(n,) decision values (binary) or (n, K) per-class scores."""
        return self._materialize()[0]

    @property
    def valid(self) -> np.ndarray:
        """(n,) bool — row satisfied the Eq 3.11 envelope (fast path used)."""
        return self._materialize()[1]

    @property
    def labels(self) -> np.ndarray:
        """(n,) labels: {-1, +1} (binary) or argmax class index (OvR)."""
        return self._materialize()[2]


class SliceResult:
    """One request's rows out of a coalesced ``EngineResult``.

    Same accessor surface as ``EngineResult`` (``values`` / ``valid`` /
    ``labels`` / ``block_until_ready``); materializing any slice
    materializes the shared parent once and every sibling becomes free.
    """

    def __init__(self, parent: EngineResult, start: int, stop: int):
        self._parent = parent
        self._start = start
        self._stop = stop

    def __len__(self) -> int:
        return self._stop - self._start

    def block_until_ready(self) -> "SliceResult":
        self._parent.block_until_ready()
        return self

    def _view(self, i):
        full = self._parent._materialize()[i]
        return full[self._start : self._stop]

    @property
    def values(self) -> np.ndarray:
        return self._view(0)

    @property
    def valid(self) -> np.ndarray:
        return self._view(1)

    @property
    def labels(self) -> np.ndarray:
        return self._view(2)


class SVMEngine:
    def __init__(
        self,
        model: CompiledArtifact | ApproxModel,
        exact: SVMModel | None = None,
        *,
        allow_fallback: bool = True,
        mesh: Mesh | None = None,
        head_mesh: Mesh | None = None,
        device=None,
        min_bucket: int = 32,
        max_batch: int = 8192,
        tile_config: TileConfig | None = None,
    ):
        if min_bucket & (min_bucket - 1) or max_batch & (max_batch - 1):
            raise ValueError("min_bucket and max_batch must be powers of two")
        if isinstance(model, CompiledArtifact):
            self.artifact = model
            self.approx = None                 # pre-families accessor
        elif isinstance(model, ApproxModel):
            self.artifact = families.maclaurin.from_approx(model)
            self.approx = model
        else:
            raise TypeError(
                f"SVMEngine serves a CompiledArtifact (or a legacy "
                f"ApproxModel), got {type(model).__name__}"
            )
        self._family = families.get_family(self.artifact.family)
        self.family = self.artifact.family
        self.dtype = self.artifact.dtype      # weight storage: float32 / int8
        self.exact = exact
        self.multiclass = self.artifact.multiclass
        self.num_heads = self.artifact.num_heads
        self.d = self.artifact.d
        self.allow_fallback = allow_fallback and exact is not None
        self.min_bucket = min_bucket
        self.max_batch = max_batch
        self.tile_config = tile_config
        self.bucket_configs: dict[int, TileConfig] = {}
        self.stats = EngineStats()
        self._trace_lock = threading.Lock()   # guards bucket_configs
        self._device = device                 # replica pinning (scale-out)
        self.head_mesh = head_mesh

        # The artifact's arrays are closed over -> baked into the executable
        # as constants; only the padded batch is an argument (and is donated
        # where the backend supports aliasing). Under a head_mesh the heads
        # are padded up to the mesh axis size and the family's sharded
        # scorer partitions them across devices; the padded artifact is
        # engine-internal (padding would change the content digest) and
        # ``num_heads`` keeps the REAL head count — ``_finalize`` slices
        # the score columns back down.
        if head_mesh is not None:
            pad = getattr(self._family, "pad_heads", None)
            sharded = getattr(self._family, "score_sharded", None)
            if pad is None or sharded is None:
                raise NotImplementedError(
                    f"family {self.family!r} has no head-sharded serving path"
                )
            shards = head_mesh.shape[head_mesh.axis_names[0]]
            self._serve_artifact = pad(self.artifact, shards)
        else:
            self._serve_artifact = self.artifact
        artifact = self._serve_artifact

        def _step(Zp):
            # Runs once per bucket (at trace time): resolve this bucket's
            # tuned tile sizes, so warmup() precompiles tuned variants.
            cfg = self._resolve_tile_config(Zp.shape[0])
            if head_mesh is not None:
                scores, valid_row = self._family.score_sharded(
                    artifact, Zp, mesh=head_mesh, config=cfg
                )
            else:
                scores, valid_row = self._family.score(artifact, Zp, config=cfg)
            if self.multiclass:
                labels = jnp.argmax(scores, axis=-1)       # fused argmax
            else:
                labels = jnp.where(scores[:, 0] >= 0, 1, -1)
            return scores, valid_row, labels

        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._step = jax.jit(_step, donate_argnums=donate)
        self._slow = self._build_slow(exact, mesh) if exact is not None else None

        # Degraded-mode step (circuit breaker open): the exact expansion
        # through the streaming rbf_pred path, shaped like _step so the
        # coalesced scatter machinery works unchanged. valid is all-False
        # — the rows were served OUTSIDE the approximation contract's
        # fast path, same semantics as fallback-patched rows.
        if self._slow is not None:
            slow = self._slow

            def _slow_full(Zp):
                scores = slow(Zp)                               # (m, K)
                if self.multiclass:
                    labels = jnp.argmax(scores, axis=-1)
                else:
                    labels = jnp.where(scores[:, 0] >= 0, 1, -1)
                return scores, jnp.zeros((Zp.shape[0],), bool), labels

            self._slow_step = jax.jit(_slow_full)
        else:
            self._slow_step = None

    # ---------------------------------------------------------- tile tuning

    def _resolve_tile_config(self, bucket: int) -> TileConfig:
        """The TileConfig this shape bucket's compiled step uses.

        Explicit ``tile_config`` pins every bucket; otherwise the tuning
        registry is consulted for the FAMILY's serving kernel and this
        bucket's shape key (``quadform``/(d, K, bucket) for the quadratic
        forms, ``rff_score``/(d, F, bucket) for fourier) — a measured
        entry from the checked-in table (written by the serving-latency
        block sweep) or the kernel default. block_n is clamped to the
        bucket so tiny buckets never pad up to a full default tile.
        """
        with self._trace_lock:
            cached = self.bucket_configs.get(bucket)
            if cached is not None:
                return cached
            if self.tile_config is not None:
                base = self.tile_config
            else:
                kernel, key = self._family.tile_lookup(self.artifact, bucket)
                base = tuning.lookup(kernel, key)
            cfg = base.clamp_block_n(bucket)
            self.bucket_configs[bucket] = cfg
            self.stats.record_compile()       # runs at trace time only
            return cfg

    # ------------------------------------------------------------- fast path

    def _put(self, buf: np.ndarray):
        """Host batch -> device array, honoring the replica's pinned device."""
        if self._device is not None:
            return jax.device_put(buf, self._device)
        return jnp.asarray(buf)

    def submit(self, Z) -> EngineResult:
        """Enqueue one batch; returns without waiting for device compute."""
        Z = np.asarray(Z, dtype=np.float32)
        if Z.ndim != 2 or Z.shape[1] != self.d:
            raise ValueError(f"expected (n, {self.d}) batch, got {Z.shape}")
        n = Z.shape[0]
        chunks = []
        for start in range(0, max(n, 1), self.max_batch):
            rows = Z[start : start + self.max_batch]
            m = rows.shape[0]
            bkt = bucket_size(m, self.min_bucket, self.max_batch)
            buf = np.zeros((bkt, self.d), dtype=np.float32)
            buf[:m] = rows                                  # host-side pad
            with _annotate(f"svm_engine.step/{self.family}/b{bkt}"):
                out = self._step(self._put(buf))
            chunks.append((out, m))
        self.stats.record_batch(n, [(c[0][0].shape[0], c[1]) for c in chunks])
        # Z is only needed to re-score bound-violating rows; don't pin the
        # host copy of every deferred batch when no fallback can happen.
        return EngineResult(self, Z if self.allow_fallback else None, chunks)

    @property
    def exact_available(self) -> bool:
        """True when an exact model was published (``submit_exact`` works)."""
        return self._slow_step is not None

    def submit_exact(self, Z) -> EngineResult:
        """Score ``Z`` entirely through the exact streaming ``rbf_pred``
        path — the circuit breaker's graceful-degradation target.

        Same deferred-sync ``EngineResult`` surface as ``submit`` (the
        micro-batcher's scatter works unchanged) with every row's
        ``valid`` False: the rows were exact-served, not approximated.
        Batches are bucket-padded like the fast path so degraded serving
        keeps the bounded-compile property (one slow variant per bucket,
        not per batch shape). Requires an exact model.
        """
        if self._slow_step is None:
            raise RuntimeError("submit_exact needs an exact model (none given)")
        Z = np.asarray(Z, dtype=np.float32)
        if Z.ndim != 2 or Z.shape[1] != self.d:
            raise ValueError(f"expected (n, {self.d}) batch, got {Z.shape}")
        n = Z.shape[0]
        chunks = []
        for start in range(0, max(n, 1), self.max_batch):
            rows = Z[start : start + self.max_batch]
            m = rows.shape[0]
            bkt = bucket_size(m, self.min_bucket, self.max_batch)
            buf = np.zeros((bkt, self.d), dtype=np.float32)
            buf[:m] = rows
            with _annotate(f"svm_engine.step_exact/b{bkt}"):
                out = self._slow_step(self._put(buf))
            chunks.append((out, m))
        self.stats.record_degraded(n)
        return EngineResult(self, None, chunks)   # exact already: no re-score

    def predict(self, Z) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous: (decision values, used_fast_path bool mask)."""
        r = self.submit(Z)
        return r.values, r.valid

    def predict_labels(self, Z) -> np.ndarray:
        """{-1, +1} (binary) or class indices (multiclass)."""
        return self.submit(Z).labels

    def bucket_for(self, n: int) -> int:
        """The padded bucket a batch of ``n`` rows dispatches into —
        lets the scheduler stamp engine-step spans with the bucket and
        its resolved ``TileConfig`` without re-deriving the policy."""
        return bucket_size(max(int(n), 1), self.min_bucket, self.max_batch)

    def jit_cache_size(self) -> int:
        """Number of compiled step variants (== buckets seen); bounded by
        log2(max_batch / min_bucket) + 1 by construction."""
        probe = getattr(self._step, "_cache_size", None)  # private jax API
        if probe is not None:
            return probe()
        return len(self.stats.bucket_hits)                # buckets == variants

    def warmup(self, batch_sizes=None) -> int:
        """Pre-compile every bucket a production stream can hit.

        Warmup traffic does not pollute the serving statistics (only the
        bucket histogram keeps its entries, so jit_cache_size stays
        truthful on jax versions without the cache probe).
        """
        if batch_sizes is None:
            batch_sizes, b = [], self.min_bucket
            while b <= self.max_batch:
                batch_sizes.append(b)
                b *= 2
        saved = self.stats
        self.stats = EngineStats(bucket_hits=dict(saved.bucket_hits))
        try:
            for n in batch_sizes:
                self.submit(np.zeros((n, self.d), np.float32)).block_until_ready()
        finally:
            saved.bucket_hits = self.stats.bucket_hits
            saved.compiled_steps += self.stats.compiled_steps  # traces are real
            self.stats = saved
        return self.jit_cache_size()

    # ------------------------------------------------------------- slow path

    def _build_slow(self, exact: SVMModel, mesh: Mesh | None):
        """Exact re-scorer through the streaming rbf_pred backend path.

        With a mesh, SVs are sharded over its first axis (rows padded with
        alpha = 0, which contribute exactly 0) and partial sums psum'd.
        Multiclass exact models keep alpha_y as (K, n_sv); heads are
        vmapped — the slow path is off the latency budget by definition.
        """
        ay = np.asarray(exact.alpha_y, np.float32)
        ay2 = ay[None, :] if ay.ndim == 1 else ay           # (K, n_sv)
        X = np.asarray(exact.X, np.float32)
        gamma, bias = exact.gamma, exact.b

        if mesh is None:
            Xd, ayd = jnp.asarray(X), jnp.asarray(ay2)

            @jax.jit
            def slow(Zb):
                f = jax.vmap(
                    lambda a: backend.rbf_scores(Zb, Xd, a, gamma, 0.0)
                )(ayd)                                       # (K, m)
                return f.T + jnp.reshape(bias, (1, -1))      # (m, K)

            return slow

        axis = mesh.axis_names[0]
        shards = mesh.shape[axis]
        pad = (-X.shape[0]) % shards
        Xp = np.pad(X, ((0, pad), (0, 0)))
        ayp = np.pad(ay2, ((0, 0), (0, pad)))               # alpha 0 => 0 contribution
        Xd = jax.device_put(Xp)
        ayd = jax.device_put(ayp)

        from jax.experimental.shard_map import shard_map

        def _partial(Zb, Xs, ays):
            f = jax.vmap(lambda a: backend.rbf_scores(Zb, Xs, a, gamma, 0.0))(ays)
            return jax.lax.psum(f, axis)                     # (K, m) replicated

        sharded = shard_map(
            _partial,
            mesh=mesh,
            in_specs=(P(), P(axis, None), P(None, axis)),
            out_specs=P(),
        )

        @jax.jit
        def slow(Zb):
            return sharded(Zb, Xd, ayd).T + jnp.reshape(bias, (1, -1))

        return slow

    # ----------------------------------------------------------- materialize

    def _finalize(self, Z: np.ndarray | None, chunks):
        """One host sync per result: concat chunks, slice padding, patch
        bound-violating rows through the exact path."""
        scores = np.concatenate(
            [np.asarray(out[0])[:m] for out, m in chunks]
        ) if chunks else np.zeros((0, self.num_heads), np.float32)
        if scores.shape[1] != self.num_heads:
            # head-sharded serving pads K up to the mesh axis size; the
            # padding heads are argmax-neutral, so labels are already
            # correct — only the score columns need slicing back down.
            scores = np.ascontiguousarray(scores[:, : self.num_heads])
        valid = np.concatenate([np.asarray(out[1])[:m] for out, m in chunks]) \
            if chunks else np.zeros((0,), bool)
        labels = np.concatenate([np.asarray(out[2])[:m] for out, m in chunks]) \
            if chunks else np.zeros((0,), np.int32)

        if Z is not None and self.allow_fallback and not valid.all():
            idx = np.nonzero(~valid)[0]
            self.stats.record_fallback(len(idx))
            exact_scores = np.asarray(self._slow(jnp.asarray(Z[idx])))  # (m, K)
            scores[idx] = exact_scores
            if self.multiclass:
                labels[idx] = exact_scores.argmax(axis=-1)
            else:
                labels[idx] = np.where(exact_scores[:, 0] >= 0, 1, -1)

        values = scores if self.multiclass else scores[:, 0]
        return values, valid, labels
