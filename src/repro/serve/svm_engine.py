"""Batched SVM prediction engine — the paper's application layer.

Production picture (object detection, §5): a stream of feature vectors
needs decision values at minimum latency. The engine serves the
APPROXIMATED model (O(d^2)/instance, paper Eq 3.8) and enforces the paper's
accuracy contract at run time:

  * every batch is scored through the quadratic form (fast path),
  * the Eq 3.11 bound is checked per instance at zero extra cost
    (||z||^2 is a by-product),
  * instances that violate the bound are re-scored with the exact model
    (slow path) — bounded-accuracy serving without globally giving up the
    speedup. The paper recommends adhering to the bound; the fallback is
    our beyond-paper extension for inputs outside the verified envelope.

Distribution: the approximated model is O(d^2) and replicated; the exact
fallback shards its SVs across devices (jax.jit + NamedSharding when a mesh
is provided). Statistics are kept for observability.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maclaurin import ApproxModel, approx_decision_function_checked
from repro.core.rbf import SVMModel, decision_function

Array = jax.Array


@dataclasses.dataclass
class EngineStats:
    batches: int = 0
    instances: int = 0
    fallback_instances: int = 0

    @property
    def fallback_rate(self) -> float:
        return self.fallback_instances / max(1, self.instances)


class SVMEngine:
    def __init__(
        self,
        approx: ApproxModel,
        exact: SVMModel | None = None,
        *,
        allow_fallback: bool = True,
    ):
        self.approx = approx
        self.exact = exact
        self.allow_fallback = allow_fallback and exact is not None
        self.stats = EngineStats()
        self._fast = jax.jit(approx_decision_function_checked)
        self._slow = jax.jit(decision_function) if exact is not None else None

    def predict(self, Z: Array) -> tuple[np.ndarray, np.ndarray]:
        """Returns (decision values, used_fast_path bool mask)."""
        f_hat, valid = self._fast(self.approx, Z)
        f_hat = np.array(f_hat)  # writable copy (fallback overwrites rows)
        valid = np.asarray(valid)
        self.stats.batches += 1
        self.stats.instances += Z.shape[0]
        if self.allow_fallback and not valid.all():
            idx = np.nonzero(~valid)[0]
            self.stats.fallback_instances += len(idx)
            # Re-batch only the violating rows through the exact model.
            f_exact = np.asarray(self._slow(self.exact, Z[idx]))
            f_hat[idx] = f_exact
        return f_hat, valid

    def predict_labels(self, Z: Array) -> np.ndarray:
        f, _ = self.predict(Z)
        return np.where(f >= 0, 1, -1)
