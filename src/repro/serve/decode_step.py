"""Serve-side step factories: prefill and single-token decode.

``make_serve_step(cfg)`` returns the function the decode_32k / long_500k
dry-run cells lower: (params, tokens(B,1), pos, cache[, image_embeds]) ->
(logits, new_cache). The cache backend follows cfg.attention_backend:

  softmax    O(S) KV cache — the exact-model baseline
  maclaurin  O(d^2) moment state — the paper's collapse (context-length-free)

``make_prefill_step(cfg)`` lowers the full-sequence forward (logits only).
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.models.transformer import decode, forward


def make_prefill_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "vlm":
        def prefill_step(params, tokens, image_embeds):
            logits, _ = forward(cfg, params, tokens, image_embeds)
            return logits
    else:
        def prefill_step(params, tokens):
            logits, _ = forward(cfg, params, tokens)
            return logits
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    if cfg.family == "vlm":
        def serve_step(params, tokens, pos, cache, image_embeds):
            return decode(cfg, params, tokens, pos, cache, image_embeds)
    else:
        def serve_step(params, tokens, pos, cache):
            return decode(cfg, params, tokens, pos, cache)
    return serve_step


def greedy_generate(cfg: ModelConfig, params, prompt, cache, *, steps: int,
                    start_pos: int = 0, image_embeds=None):
    """Simple greedy decode loop (examples/serving demo; not the dry-run path)."""
    import jax.numpy as jnp

    step = jax.jit(make_serve_step(cfg))
    tok = prompt[:, -1:]
    out = []
    pos = start_pos
    for _ in range(steps):
        args = (params, tok, jnp.int32(pos), cache)
        if cfg.family == "vlm":
            args = args + (image_embeds,)
        logits, cache = step(*args)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1), cache
