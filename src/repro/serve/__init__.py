from repro.serve.decode_step import make_serve_step, make_prefill_step
from repro.serve.runtime import ArtifactRegistry, MicroBatcher, Runtime
from repro.serve.svm_engine import (
    EngineResult,
    EngineStats,
    SliceResult,
    SVMEngine,
    bucket_size,
)

__all__ = [
    "make_serve_step",
    "make_prefill_step",
    "ArtifactRegistry",
    "MicroBatcher",
    "Runtime",
    "SVMEngine",
    "EngineResult",
    "EngineStats",
    "SliceResult",
    "bucket_size",
]
