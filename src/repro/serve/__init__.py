from repro.serve.decode_step import make_serve_step, make_prefill_step
from repro.serve.svm_engine import EngineResult, EngineStats, SVMEngine, bucket_size

__all__ = [
    "make_serve_step",
    "make_prefill_step",
    "SVMEngine",
    "EngineResult",
    "EngineStats",
    "bucket_size",
]
