from repro.serve.decode_step import make_serve_step, make_prefill_step
from repro.serve.runtime import (
    ArtifactCorrupt,
    ArtifactRegistry,
    BatcherClosed,
    CircuitBreaker,
    DeadlineExceeded,
    DriftGuard,
    FaultInjector,
    MicroBatcher,
    Runtime,
    RuntimeOverloaded,
)
from repro.serve.svm_engine import (
    EngineResult,
    EngineStats,
    SliceResult,
    SVMEngine,
    bucket_size,
)

__all__ = [
    "make_serve_step",
    "make_prefill_step",
    "ArtifactCorrupt",
    "ArtifactRegistry",
    "BatcherClosed",
    "CircuitBreaker",
    "DeadlineExceeded",
    "DriftGuard",
    "FaultInjector",
    "MicroBatcher",
    "Runtime",
    "RuntimeOverloaded",
    "SVMEngine",
    "EngineResult",
    "EngineStats",
    "SliceResult",
    "bucket_size",
]
