"""``repro.serve`` — the supported serving surface.

This module's ``__all__`` is the FROZEN public API: everything an
external caller may depend on, snapshot-tested by
``tests/test_public_api.py`` so any change to the surface is a
deliberate, reviewed diff. The supported entry points:

  * ``compile_model`` (re-exported from ``repro.core.families``) —
    train-time: turn an exact ``SVMModel`` into a ``CompiledArtifact``;
  * ``Runtime`` / ``ArtifactRegistry`` / ``SVMEngine`` /
    ``PublishSpec`` — serve-time Python API;
  * ``create_app`` (re-exported from ``repro.serve.server``) — the
    HTTP front door over a ``Runtime``;
  * the error taxonomy (``ServingError`` and its subclasses) — every
    refusal a caller can observe, each with a stable ``code`` and
    ``http_status``.

Anything importable but not listed here is internal and may change
without notice.
"""

from repro.core.families import compile_model
from repro.serve.decode_step import make_prefill_step, make_serve_step
from repro.serve.runtime import (
    ArtifactCorrupt,
    ArtifactRegistry,
    BatcherClosed,
    CircuitBreaker,
    DeadlineExceeded,
    DriftGuard,
    FaultInjector,
    ModelNotFound,
    MicroBatcher,
    PublishSpec,
    Runtime,
    RuntimeOverloaded,
    ServingError,
)
from repro.serve.server import create_app, serve
from repro.serve.svm_engine import (
    EngineResult,
    EngineStats,
    SliceResult,
    SVMEngine,
    bucket_size,
)

__all__ = [
    "ArtifactCorrupt",
    "ArtifactRegistry",
    "BatcherClosed",
    "CircuitBreaker",
    "DeadlineExceeded",
    "DriftGuard",
    "EngineResult",
    "EngineStats",
    "FaultInjector",
    "MicroBatcher",
    "ModelNotFound",
    "PublishSpec",
    "Runtime",
    "RuntimeOverloaded",
    "SVMEngine",
    "ServingError",
    "SliceResult",
    "bucket_size",
    "compile_model",
    "create_app",
    "make_prefill_step",
    "make_serve_step",
    "serve",
]
