"""``PublishSpec`` — the one shape a model publication takes.

Before the HTTP front door, publication options had drifted across
layers: ``Runtime.publish`` took ``exact=``/``replicas=``,
``ArtifactRegistry.register`` additionally took ``alias=``/``path=``,
and warmup policy lived on the registry constructor only. A wire API
cannot serialize "whichever kwargs this layer grew", so publication is
now a single dataclass that the Python API, the HTTP management API,
and the tests all speak:

    spec = PublishSpec(alias="detector", replicas=2, warmup=True)
    runtime.publish("detector", artifact, spec=spec)       # python
    POST /v1/models {"artifact_b64": ..., "spec": spec}    # wire

``to_wire()``/``from_wire()`` define the JSON projection. ``exact``
(the fallback ``SVMModel`` object) is deliberately NOT wire-serializable
— a remote client cannot ship a live training object; it stays a
Python-API-only field and ``to_wire`` records only its presence.

The old per-layer kwargs (``Runtime.publish(alias, art, exact=m,
replicas=2)``) are DEPRECATED but still accepted for one release: they
are folded into a spec internally and raise a ``DeprecationWarning``.
Passing both a spec and old kwargs is an error — there must be exactly
one source of truth per call.
"""

from __future__ import annotations

import dataclasses
import warnings

_WIRE_FIELDS = ("alias", "replicas", "warmup", "path")


@dataclasses.dataclass(frozen=True)
class PublishSpec:
    """Options for one model publication, identical across API layers.

    Every field defaults to ``None`` = "leave the current/registry
    default alone", so a plain re-register never silently collapses a
    scaled-out model or flips warmup policy.

      * ``alias`` — mutable name to (atomically) point at the digest.
      * ``replicas`` — engines to build from this digest (>= 1).
      * ``warmup`` — per-model override of the registry's
        ``warmup_on_load`` (pre-compile every bucket variant at build).
      * ``path`` — file backing the artifact (makes the entry
        evictable + reloadable under the memory budget).
      * ``exact`` — fallback ``SVMModel`` for breaker-open degraded
        serving and per-row out-of-envelope rescoring. Python API only;
        never crosses the wire.
    """

    alias: str | None = None
    replicas: int | None = None
    warmup: bool | None = None
    path: str | None = None
    exact: object | None = None

    def __post_init__(self):
        if self.replicas is not None and int(self.replicas) < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")

    def to_wire(self) -> dict:
        """JSON-able projection (drops ``exact``; records its presence)."""
        out = {k: getattr(self, k) for k in _WIRE_FIELDS
               if getattr(self, k) is not None}
        if self.exact is not None:
            out["has_exact"] = True
        return out

    @classmethod
    def from_wire(cls, data: dict) -> "PublishSpec":
        """Parse the wire projection; unknown keys are rejected so a
        typo'd field fails loudly instead of silently defaulting."""
        if not isinstance(data, dict):
            raise TypeError(f"spec must be an object, got {type(data).__name__}")
        unknown = set(data) - set(_WIRE_FIELDS) - {"has_exact"}
        if unknown:
            raise ValueError(f"unknown PublishSpec fields {sorted(unknown)}; "
                             f"known: {list(_WIRE_FIELDS)}")
        kw = {}
        if data.get("alias") is not None:
            kw["alias"] = str(data["alias"])
        if data.get("replicas") is not None:
            kw["replicas"] = int(data["replicas"])
        if data.get("warmup") is not None:
            kw["warmup"] = bool(data["warmup"])
        if data.get("path") is not None:
            kw["path"] = str(data["path"])
        return cls(**kw)


def resolve_spec(spec: PublishSpec | None, *, caller: str,
                 **legacy) -> PublishSpec:
    """Fold deprecated per-layer kwargs into one ``PublishSpec``.

    ``spec`` given → legacy kwargs must all be None (one source of
    truth). Legacy kwargs given → DeprecationWarning naming the caller,
    then folded. Neither → an empty spec (all defaults).
    """
    used = {k: v for k, v in legacy.items() if v is not None}
    if spec is not None:
        if used:
            raise TypeError(
                f"{caller}: pass either spec= or the legacy kwargs "
                f"({sorted(used)}), not both"
            )
        if not isinstance(spec, PublishSpec):
            raise TypeError(f"{caller}: spec must be a PublishSpec, "
                            f"got {type(spec).__name__}")
        return spec
    if used:
        warnings.warn(
            f"{caller}: the {sorted(used)} kwargs are deprecated; pass "
            f"spec=PublishSpec(...) (one shape across the Python and "
            f"HTTP APIs)",
            DeprecationWarning,
            stacklevel=3,
        )
    return PublishSpec(**used)
