"""``DriftGuard`` — the drift-triggered recompile / canary / flip loop.

The compile-time accuracy contract (``compile_model`` picking the
cheapest family within a ``Budget``, the per-row §4 validity check at
serve time) is measured against the SAMPLE the model was compiled on.
Traffic drifts: if inputs grow (‖z‖² past the Maclaurin validity bound)
or shift into a regime the chosen family approximates poorly, the
runtime doesn't get WRONG — the validity check routes the offending rows
through the exact fallback — it gets SLOW, and stays slow forever. The
guard closes that loop:

  1. **watch** — the model's telemetry keeps a bounded window of recent
     per-row validity (fast-path flushes only); the guard trips when the
     WINDOWED fallback rate crosses ``threshold`` with at least
     ``min_rows`` of evidence. The windowed rate matters: a week-old
     model's lifetime rate dilutes a sudden shift into invisibility.
  2. **sample** — a seeded reservoir (Vitter's Algorithm R over rows)
     fed by the runtime's traffic-listener hook holds a uniform sample
     of RECENT traffic — the distribution the recompile should target,
     not the one the original compile assumed.
  3. **recompile** — ``compile_model(exact, budget, sample=reservoir)``
     re-runs the whole family × dtype search against current traffic;
     drift that pushed the old family out of its sweet spot simply
     makes a different candidate win.
  4. **canary** — the candidate is registered (content-addressed, NOT
     aliased) and the reservoir is scored through the real serving path
     on the candidate digest; labels are judged against the exact RBF
     expansion. Agreement below ``min_agreement`` rejects the candidate
     — the alias never flips to a model that would misserve the very
     traffic that triggered the heal.
  5. **flip** — ``set_alias`` atomically points the alias at the
     candidate. In-flight requests on the old digest drain on the old
     engine (registry hot-swap semantics); zero requests are dropped by
     a flip, which is asserted in the end-to-end drift test.

Everything is observable: ``record_recompile`` / ``record_canary`` land
in the watched model's telemetry, and ``check()`` returns a verdict dict
a test (or an ops loop) can assert on. The guard never acts on degraded
(breaker-open) traffic — those rows bypass the validity window by
construction, because an engine FAULT is not input DRIFT and recompiling
cannot fix it.

Threading: ``offer``/``check`` are safe to call from any thread;
``check`` serializes heals under an internal lock (one recompile at a
time) and enforces ``cooldown_s`` between heal attempts so a window that
stays red during a slow compile cannot stampede the compiler.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.families import compile_model
from repro.core.families.base import stack_heads
from repro.core.rbf import rbf_kernel
from repro.serve.runtime.publish import PublishSpec


class ReservoirSampler:
    """Uniform row sample over an unbounded stream (Algorithm R), seeded.

    ``offer`` cost is O(rows accepted); memory is ``capacity`` rows.
    Thread-safe: the runtime's traffic listener calls ``offer`` from
    every client thread.
    """

    def __init__(self, capacity: int = 512, seed: int = 0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._rng = np.random.default_rng(seed)
        self._rows: list[np.ndarray] = []
        self._seen = 0
        self._lock = threading.Lock()

    def offer(self, Z) -> None:
        Z = np.atleast_2d(np.asarray(Z, dtype=np.float32))
        with self._lock:
            for row in Z:
                self._seen += 1
                if len(self._rows) < self.capacity:
                    self._rows.append(row.copy())
                else:
                    j = int(self._rng.integers(0, self._seen))
                    if j < self.capacity:
                        self._rows[j] = row.copy()

    def sample(self) -> np.ndarray:
        with self._lock:
            if not self._rows:
                return np.zeros((0, 0), np.float32)
            return np.stack(self._rows)

    def __len__(self) -> int:
        with self._lock:
            return len(self._rows)

    @property
    def seen(self) -> int:
        with self._lock:
            return self._seen


def _exact_labels(exact, Z: np.ndarray) -> np.ndarray:
    """Ground-truth labels from the exact RBF expansion (the canary judge)."""
    ay2, b, _, multiclass = stack_heads(exact)
    K = rbf_kernel(jnp.asarray(Z), exact.X, exact.gamma)       # (n, n_sv)
    scores = np.asarray(K @ ay2.T + b)                          # (n, K)
    if multiclass:
        return np.argmax(scores, axis=1)
    return np.where(scores[:, 0] >= 0, 1, -1)


class DriftGuard:
    """Self-healing loop for one served alias.

    Args:
      runtime:        the ``Runtime`` serving the alias.
      alias:          the mutable name to watch (and atomically re-point).
      exact:          the exact ``SVMModel`` — recompile source AND
                      canary judge. (The registry entry's ``exact`` is
                      not reused on purpose: the guard must be able to
                      heal a model published without a fallback.)
      budget:         ``Budget`` handed to ``compile_model`` on heal.
      threshold:      windowed fallback rate that arms a heal (0..1).
      min_rows:       evidence floor — no heal off a near-empty window.
      min_agreement:  canary label-agreement floor for the alias flip.
      capacity/seed:  reservoir size and determinism seed.
      cooldown_s:     wall-clock spacing between heal ATTEMPTS (pass or
                      fail), so a red window can't stampede the compiler.
      min_valid_fraction: §4 validity floor injected into the heal's
                      budget when the caller's budget leaves ``min_valid``
                      unset. The heal's entire POINT is cutting the
                      fallback rate, so a candidate that error-fits the
                      drifted sample but flags it invalid row-by-row
                      (fallback-served: correct, never fast) must lose
                      the search to one whose envelope fits the traffic.
      compile_opts:   extra kwargs for ``compile_model`` (families=...,
                      dtypes=..., family_opts=...).
      clock:          monotonic time source for cooldown spacing AND the
                      heal-history trigger timestamps surfaced through
                      ``Runtime.stats()`` — injectable so tests drive it.

    Every heal attempt lands in the watched model's telemetry
    (``record_heal`` → the ``heals`` block of ``Runtime.stats()``) and,
    when the runtime has observability enabled, as a linked span arc
    under the OLD digest's trace ring: trigger → reservoir → recompile
    → canary → flip, all sharing one heal trace id with the trigger
    span as parent.
    """

    def __init__(
        self,
        runtime,
        alias: str,
        *,
        exact,
        budget,
        threshold: float = 0.25,
        min_rows: int = 64,
        min_agreement: float = 0.98,
        capacity: int = 512,
        seed: int = 0,
        cooldown_s: float = 0.0,
        min_valid_fraction: float | None = 0.9,
        compile_opts: dict | None = None,
        clock=time.monotonic,
    ):
        self.runtime = runtime
        self.alias = alias
        self.exact = exact
        self.budget = budget
        self.threshold = float(threshold)
        self.min_rows = int(min_rows)
        self.min_agreement = float(min_agreement)
        self.cooldown_s = float(cooldown_s)
        self.min_valid_fraction = min_valid_fraction
        self.compile_opts = dict(compile_opts or {})
        self.compile_opts.setdefault("seed", seed)
        self._clock = clock
        self.reservoir = ReservoirSampler(capacity=capacity, seed=seed)
        self._heal_lock = threading.Lock()
        self._last_heal_at: float | None = None
        self._attached = False
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.heals: list[dict] = []            # verdict history, newest last

    # ------------------------------------------------------------- watching

    def attach(self) -> "DriftGuard":
        """Subscribe the reservoir to the alias's traffic. Idempotent."""
        if not self._attached:
            self.runtime.add_traffic_listener(self._on_traffic)
            self._attached = True
        return self

    def _on_traffic(self, model: str, digest: str, Z) -> None:
        # only the watched alias feeds the reservoir; canary submits go
        # by candidate DIGEST and are deliberately excluded (the guard
        # must not judge candidates on its own probe traffic)
        if model == self.alias:
            self.reservoir.offer(Z)

    def fallback_rate(self) -> dict:
        """The windowed drift signal for the alias's CURRENT digest."""
        return self.runtime.telemetry(self.alias).fallback_window()

    # -------------------------------------------------------------- healing

    def check(self) -> dict:
        """One watch cycle: inspect the window, heal if it's red.

        Returns a verdict dict: ``triggered`` (window crossed the
        threshold), and when triggered the full heal verdict
        (``healed``, ``agreement``, ``old_digest``, ``new_digest``,
        ``family``...). Cheap when the window is green — safe to call
        on every request or from a tight ops loop.
        """
        window = self.fallback_rate()
        verdict = {"triggered": False, "healed": False, "window": window}
        if window["rows"] < self.min_rows or window["rate"] < self.threshold:
            return verdict
        if len(self.reservoir) < self.min_rows:
            # red window but no sample to recompile against yet
            verdict.update(triggered=True, reason="reservoir too small")
            return verdict
        if not self._heal_lock.acquire(blocking=False):
            verdict.update(triggered=True, reason="heal already in progress")
            return verdict
        try:
            now = self._clock()
            if (self._last_heal_at is not None
                    and now - self._last_heal_at < self.cooldown_s):
                verdict.update(triggered=True, reason="cooldown")
                return verdict
            self._last_heal_at = now
            verdict.update(triggered=True)
            verdict.update(self._heal_locked(trigger_at=now, window=window))
            self.heals.append(verdict)
            return verdict
        finally:
            self._heal_lock.release()

    def _tracer(self):
        obs = getattr(self.runtime, "obs", None)
        return obs.tracer if obs is not None else None

    def _heal_locked(self, *, trigger_at: float, window: dict) -> dict:
        rt = self.runtime
        old_digest = rt.registry.resolve(self.alias)
        telemetry = rt.telemetry(self.alias)
        telemetry.record_recompile()
        sample = self.reservoir.sample()

        # heal arc spans: one trace, the trigger span as common parent,
        # recorded under the OLD digest's ring (where the drift happened)
        tr = self._tracer()
        model_key = old_digest[:12]
        heal_trace = trigger_id = None
        if tr is not None:
            heal_trace = tr.new_trace()
            trigger_id = tr.span(model_key, "heal.trigger",
                                 trace_id=heal_trace, attrs={
                                     "alias": self.alias,
                                     "rate": window["rate"],
                                     "rows": window["rows"],
                                 })
            tr.span(model_key, "heal.reservoir", trace_id=heal_trace,
                    parent_id=trigger_id, attrs={
                        "rows": int(sample.shape[0]),
                        "seen": self.reservoir.seen,
                    })

        def _arc(name, **attrs):
            if tr is not None:
                tr.span(model_key, name, trace_id=heal_trace,
                        parent_id=trigger_id, attrs=attrs)

        def _finish(out):
            healed = out.get("healed", False)
            entry = dict(
                trigger_at=trigger_at,
                healed=healed,
                old_digest=old_digest,
                new_digest=out.get("new_digest", ""),
                detail={k: out[k] for k in ("reason", "agreement", "family")
                        if k in out},
            )
            telemetry.record_heal(**entry)
            if healed:
                # the alias now resolves to the NEW digest; mirror the
                # flip there so ``stats(alias)`` keeps the heal visible
                rt.telemetry(out["new_digest"]).record_heal(
                    mirror=True, **entry
                )
            return out

        # 1. recompile the family × dtype search against CURRENT traffic;
        # the budget gains a validity floor (unless the caller pinned one)
        # because a heal that still fallback-serves the traffic heals nothing
        budget = self.budget
        if budget.min_valid is None and self.min_valid_fraction is not None:
            budget = dataclasses.replace(budget, min_valid=self.min_valid_fraction)
        try:
            artifact = compile_model(
                self.exact, budget, sample=sample, **self.compile_opts
            )
        except Exception as e:                  # no candidate met the budget
            telemetry.record_canary(False)
            _arc("heal.recompile", ok=False, error=str(e))
            return _finish({"healed": False, "old_digest": old_digest,
                            "reason": f"recompile failed: {e}"})
        _arc("heal.recompile", ok=True, family=artifact.family,
             dtype=artifact.dtype)

        # 2. register content-addressed (NOT aliased — candidates are
        # invisible to alias traffic until the canary passes)
        new_digest = rt.register(artifact, PublishSpec(exact=self.exact))
        if new_digest == old_digest:
            telemetry.record_canary(False)
            _arc("heal.canary", passed=False,
                 reason="recompile reproduced the serving artifact")
            return _finish({"healed": False, "old_digest": old_digest,
                            "new_digest": new_digest,
                            "reason": "recompile reproduced the serving "
                                      "artifact"})

        # 3. canary through the REAL serving path on the candidate digest
        judge = _exact_labels(self.exact, sample)
        got = np.asarray(rt.submit(new_digest, sample).result().labels)
        agreement = float(np.mean(got == judge)) if judge.size else 0.0
        passed = agreement >= self.min_agreement
        telemetry.record_canary(passed)
        _arc("heal.canary", passed=passed, agreement=agreement,
             rows=int(judge.size), candidate=new_digest[:12])
        out = {
            "healed": passed,
            "old_digest": old_digest,
            "new_digest": new_digest,
            "family": artifact.family,
            "dtype": artifact.dtype,
            "agreement": agreement,
            "canary_rows": int(judge.size),
        }
        if not passed:
            out["reason"] = (f"canary agreement {agreement:.4f} < "
                             f"{self.min_agreement}")
            return _finish(out)

        # 4. atomic flip; old-digest traffic in flight drains untouched
        rt.set_alias(self.alias, new_digest)
        telemetry.reset_fallback_window()       # old window is stale evidence
        _arc("heal.flip", old_digest=old_digest[:12],
             new_digest=new_digest[:12], alias=self.alias)
        return _finish(out)

    # ------------------------------------------------------- background loop

    def start(self, interval_s: float = 1.0) -> "DriftGuard":
        """Run ``check()`` every ``interval_s`` on a daemon thread."""
        self.attach()
        if self._thread is not None:
            return self
        self._stop.clear()

        def _loop():
            while not self._stop.wait(interval_s):
                try:
                    self.check()
                except Exception:               # the watchdog must not die
                    pass

        self._thread = threading.Thread(
            target=_loop, name=f"driftguard-{self.alias}", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=5.0)
