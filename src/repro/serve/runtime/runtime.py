"""``Runtime`` — the multi-tenant serving front door.

One object per server process:

    rt = Runtime(memory_budget_bytes=256 << 20)
    rt.publish("detector", artifact, exact=svm)      # or load_directory(...)
    fut = rt.submit("detector", Z)                   # async, coalesced
    values = fut.result().values                     # one shared host sync

``submit(model, Z)`` resolves ``model`` through the ``ArtifactRegistry``
(digest, alias, ``name@latest``, digest prefix), lazily builds + warms
the model's ``SVMEngine``, and enqueues the rows on that model's
``MicroBatcher``. Because batchers are keyed on the immutable DIGEST,
alias hot-swaps compose naturally: after ``publish`` flips an alias,
new submits route to the new digest's batcher while requests already
queued on the old digest drain on the old engine — no lock spans a
batch, nothing is torn.

``predict`` is the synchronous convenience (submit + materialize), and
``stats()`` exports the whole telemetry tree: per-model scheduler +
engine counters, plus the registry's load/eviction/alias state.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core.families import CompiledArtifact
from repro.serve.runtime.registry import ArtifactRegistry
from repro.serve.runtime.scheduler import (
    DEFAULT_MAX_WAIT_US,
    BatcherClosed,
    MicroBatcher,
)
from repro.serve.runtime.telemetry import ModelTelemetry


class Runtime:
    def __init__(
        self,
        registry: ArtifactRegistry | None = None,
        *,
        max_wait_us: float = DEFAULT_MAX_WAIT_US,
        flush_rows: int | None = None,
        memory_budget_bytes: int | None = None,
        warmup_on_load: bool = True,
        engine_opts: dict | None = None,
    ):
        if registry is None:
            registry = ArtifactRegistry(
                memory_budget_bytes=memory_budget_bytes,
                warmup_on_load=warmup_on_load,
                engine_opts=engine_opts,
            )
        self.registry = registry
        self.max_wait_us = max_wait_us
        self.flush_rows = flush_rows
        self._batchers: dict[str, MicroBatcher] = {}
        self._telemetry: dict[str, ModelTelemetry] = {}
        self._lock = threading.Lock()
        self._closed = False
        # an idle batcher pins its engine; retire it on eviction so the
        # registry's memory budget actually frees the engine's arrays
        self.registry.add_evict_listener(self._on_evict)

    # ------------------------------------------------------------ publishing

    def publish(self, alias: str, artifact: CompiledArtifact, *, exact=None) -> str:
        """Register ``artifact`` and atomically point ``alias`` at it."""
        return self.registry.publish(alias, artifact, exact=exact)

    def register(self, artifact: CompiledArtifact, **kw) -> str:
        return self.registry.register(artifact, **kw)

    def load_directory(self, dirpath: str, **kw) -> dict[str, str]:
        return self.registry.add_directory(dirpath, **kw)

    def set_alias(self, alias: str, ref: str) -> str:
        return self.registry.set_alias(alias, ref)

    # --------------------------------------------------------------- serving

    def _batcher(self, digest: str, engine) -> MicroBatcher:
        b = self._batchers.get(digest)
        if b is not None and b.engine is engine:
            return b
        stale = None
        with self._lock:
            if self._closed:
                raise RuntimeError("Runtime is closed")
            b = self._batchers.get(digest)
            if b is None or b.engine is not engine:
                # first use, or the registry evicted + rebuilt this model's
                # engine: retire the old batcher (it drains in-flight work
                # on the old engine) and route new traffic to the fresh one.
                stale = b
                tel = self._telemetry.setdefault(digest, ModelTelemetry())
                b = MicroBatcher(
                    engine,
                    max_wait_us=self.max_wait_us,
                    flush_rows=self.flush_rows,
                    telemetry=tel,
                    name=digest[:12],
                )
                self._batchers[digest] = b
        if stale is not None:
            stale.close()
        return b

    def _on_evict(self, digest: str) -> None:
        """Registry evicted ``digest``'s engine: retire its batcher (the
        close drains in-flight work on the old engine first)."""
        with self._lock:
            b = self._batchers.pop(digest, None)
        if b is not None:
            b.close()

    def submit(self, model: str, Z):
        """Async scoring: ``Future[SliceResult]`` for ``Z`` on ``model``."""
        while True:
            digest, engine = self.registry.get_engine(model)
            try:
                return self._batcher(digest, engine).submit(Z)
            except BatcherClosed:
                # the batcher was retired between lookup and submit (engine
                # evicted + reloaded under us); re-resolve onto the fresh one
                continue

    def predict(self, model: str, Z) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous convenience: (values, valid) like ``SVMEngine.predict``."""
        res = self.submit(model, Z).result()
        return res.values, res.valid

    def warmup(self, model: str) -> int:
        """Force-load + warm ``model`` now; returns its compiled variants."""
        _, engine = self.registry.get_engine(model)
        if not self.registry.warmup_on_load:
            engine.warmup()                 # registry didn't warm at load time
        return engine.jit_cache_size()

    # ------------------------------------------------------------- telemetry

    def stats(self, model: str | None = None) -> dict:
        """Telemetry snapshot: one model's, or the whole runtime tree."""
        if model is not None:
            digest = self.registry.resolve(model)
            tel = self._telemetry.get(digest)
            batcher = self._batchers.get(digest)
            if batcher is not None:
                engine = batcher.engine          # the engine traffic actually hits
            else:
                entry = self.registry._entries.get(digest)
                engine = entry.engine if entry is not None else None
            if tel is None:
                tel = ModelTelemetry()            # zeroed snapshot pre-traffic
            out = tel.snapshot(engine)
            out["digest"] = digest
            entry = self.registry._entries.get(digest)
            if entry is not None:
                out["evictions"] = entry.evictions
            return out
        with self._lock:
            digests = list(self._telemetry)
        return {
            "registry": self.registry.snapshot(),
            "models": {d[:12]: self.stats(d) for d in digests},
        }

    # -------------------------------------------------------------- lifetime

    def close(self) -> None:
        with self._lock:
            self._closed = True
            batchers = list(self._batchers.values())
            self._batchers.clear()
        for b in batchers:
            b.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
