"""``Runtime`` — the multi-tenant serving front door.

One object per server process:

    rt = Runtime(memory_budget_bytes=256 << 20)
    rt.publish("detector", artifact, PublishSpec(exact=svm))
    fut = rt.submit("detector", Z)                   # async, coalesced
    values = fut.result().values                     # one shared host sync

``submit(model, Z)`` resolves ``model`` through the ``ArtifactRegistry``
(digest, alias, ``name@latest``, digest prefix), lazily builds + warms
the model's ``SVMEngine``, and enqueues the rows on that model's
``MicroBatcher``. Because batchers are keyed on the immutable DIGEST,
alias hot-swaps compose naturally: after ``publish`` flips an alias,
new submits route to the new digest's batcher while requests already
queued on the old digest drain on the old engine — no lock spans a
batch, nothing is torn.

``predict`` is the synchronous convenience (submit + materialize), and
``stats()`` exports the whole telemetry tree: per-model scheduler +
engine counters, plus the registry's load/eviction/alias state.

Robustness knobs (all per-runtime, applied to every model's batcher):

  * ``max_queue_rows`` — admission bound per model; a submit that would
    overflow the queue raises ``RuntimeOverloaded(retry_after_s=...)``
    instead of queueing unboundedly (bounded queue ⇒ bounded latency
    for everything that IS admitted).
  * ``default_deadline_s`` / ``submit(..., deadline_s=...)`` — per-
    request deadline; an admitted request that cannot reach a flush in
    time fails its future with ``DeadlineExceeded``.
  * ``breaker`` — per-model circuit breaker config (``True`` default,
    ``False`` off, or a kwargs dict for ``CircuitBreaker``). While open,
    traffic degrades to the exact streaming ``rbf_pred`` path when the
    model was published with ``exact=``, or is shed otherwise.
  * ``fault_injector`` — one ``FaultInjector`` threaded through both
    the batchers (``engine_step`` site) and the registry
    (``registry_load`` site) for deterministic chaos testing.

Traffic listeners (``add_traffic_listener``) observe every submitted
batch — the hook the ``DriftGuard`` reservoir-samples from to get a
recompile dataset that reflects CURRENT traffic, not compile-time
assumptions.

Observability (PR 9): every runtime owns an ``obs.Observability``
(``obs=False`` disables, an explicit instance isolates). Request
lifecycle spans are recorded by the batchers under each model's digest
prefix; ``ModelTelemetry`` counters mirror onto the bundle's metrics
registry labelled (model_digest, alias, family, dtype);
``render_prometheus()`` exposes them as Prometheus text; and
``profile(model, Z, path)`` captures a ``jax.profiler`` trace of one
coalesced step.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core.families import CompiledArtifact
from repro.serve.runtime.errors import BatcherClosed
from repro.serve.runtime.faults import FaultInjector
from repro.serve.runtime.obs import Observability
from repro.serve.runtime.obs import profile as obs_profile
from repro.serve.runtime.publish import PublishSpec, resolve_spec
from repro.serve.runtime.registry import ArtifactRegistry
from repro.serve.runtime.scheduler import DEFAULT_MAX_WAIT_US, MicroBatcher
from repro.serve.runtime.telemetry import ModelTelemetry


class Runtime:
    def __init__(
        self,
        registry: ArtifactRegistry | None = None,
        *,
        max_wait_us: float = DEFAULT_MAX_WAIT_US,
        flush_rows: int | None = None,
        memory_budget_bytes: int | None = None,
        warmup_on_load: bool = True,
        engine_opts: dict | None = None,
        max_queue_rows: int | None = None,
        default_deadline_s: float | None = None,
        breaker=True,
        fault_injector: FaultInjector | None = None,
        obs=None,
    ):
        # obs=None -> own bundle on the process default metrics registry;
        # obs=False -> observability off (no spans, no metric mirroring);
        # an Observability instance -> use it (isolated registries/tracers)
        if obs is None:
            obs = Observability()
        self.obs: Observability | None = obs or None
        if registry is None:
            registry = ArtifactRegistry(
                memory_budget_bytes=memory_budget_bytes,
                warmup_on_load=warmup_on_load,
                engine_opts=engine_opts,
                fault_injector=fault_injector,
                obs=self.obs,
            )
        elif getattr(registry, "obs", None) is None and self.obs is not None:
            registry.obs = self.obs
        self.registry = registry
        self.max_wait_us = max_wait_us
        self.flush_rows = flush_rows
        self.max_queue_rows = max_queue_rows
        self.default_deadline_s = default_deadline_s
        self.breaker = breaker
        self.faults = fault_injector
        self._batchers: dict[str, MicroBatcher] = {}
        self._telemetry: dict[str, ModelTelemetry] = {}
        self._traffic_listeners: list = []
        self._lock = threading.Lock()
        self._closed = False
        # an idle batcher pins its engine; retire it on eviction so the
        # registry's memory budget actually frees the engine's arrays
        self.registry.add_evict_listener(self._on_evict)

    # ------------------------------------------------------------ publishing

    def publish(self, alias: str, artifact: CompiledArtifact,
                spec: PublishSpec | None = None, *, exact=None,
                replicas: int | None = None) -> str:
        """Register ``artifact`` and atomically point ``alias`` at it.

        Options travel in one ``PublishSpec`` (``spec=PublishSpec(
        replicas=2, warmup=True)``) — the same shape the HTTP management
        API serializes; the bare ``exact=``/``replicas=`` kwargs are
        deprecated-but-accepted for one release.

        ``replicas=N`` scales the model out over N engines (pinned
        round-robin across local devices); the model's batcher then
        routes each flush to the least-loaded replica. ``None`` keeps
        the current count (default 1).
        """
        spec = resolve_spec(spec, caller="Runtime.publish",
                            exact=exact, replicas=replicas)
        return self.registry.publish(alias, artifact, spec)

    def register(self, artifact: CompiledArtifact,
                 spec: PublishSpec | None = None, **kw) -> str:
        return self.registry.register(artifact, spec, **kw)

    def load_directory(self, dirpath: str, **kw) -> dict[str, str]:
        return self.registry.add_directory(dirpath, **kw)

    def set_alias(self, alias: str, ref: str) -> str:
        return self.registry.set_alias(alias, ref)

    # --------------------------------------------------------------- serving

    def _batcher(self, digest: str, engines: list) -> MicroBatcher:
        engine = engines[0]
        b = self._batchers.get(digest)
        if b is not None and b.engine is engine:
            return b
        stale = None
        with self._lock:
            if self._closed:
                raise RuntimeError("Runtime is closed")
            b = self._batchers.get(digest)
            if b is None or b.engine is not engine:
                # first use, or the registry evicted + rebuilt this model's
                # engines (including a replica-count change, which swaps
                # the whole replica set atomically): retire the old
                # batcher (it drains in-flight work on the old engines)
                # and route new traffic to the fresh ones.
                stale = b
                tel = self._telemetry.setdefault(digest, ModelTelemetry())
                if self.obs is not None:
                    tel.bind_obs(self.obs.metrics, self._labels(digest, engine))
                b = MicroBatcher(
                    engine,
                    max_wait_us=self.max_wait_us,
                    flush_rows=self.flush_rows,
                    telemetry=tel,
                    name=digest[:12],
                    max_queue_rows=self.max_queue_rows,
                    breaker=self.breaker,
                    fault_injector=self.faults,
                    engines=engines,
                    tracer=self.obs.tracer if self.obs is not None else None,
                )
                self._batchers[digest] = b
        if stale is not None:
            stale.close()
        return b

    def _labels(self, digest: str, engine) -> dict:
        """Metric label set for one served digest: digest prefix, the
        alias currently pointing at it (first match; "" if served by
        digest only), and the engine's family/dtype dimensions."""
        alias = ""
        for a, d in self.registry.aliases().items():
            if d == digest:
                alias = a
                break
        return {
            "model_digest": digest[:12],
            "alias": alias,
            "family": getattr(engine, "family", ""),
            "dtype": getattr(engine, "dtype", ""),
        }

    def _on_evict(self, digest: str) -> None:
        """Registry evicted ``digest``'s engine: retire its batcher (the
        close drains in-flight work on the old engine first, and resolves
        every still-pending future — eviction never strands a caller)."""
        with self._lock:
            b = self._batchers.pop(digest, None)
        if b is not None:
            b.close()

    def add_traffic_listener(self, fn) -> None:
        """``fn(model_ref, digest, Z)`` observes every submitted batch
        AFTER admission (shed requests are not traffic). Listener errors
        propagate to the submitter — keep listeners trivial (the
        ``DriftGuard`` reservoir offer is an O(rows) numpy copy)."""
        self._traffic_listeners.append(fn)

    def submit(self, model: str, Z, *, deadline_s: float | None = None):
        """Async scoring: ``Future[SliceResult]`` for ``Z`` on ``model``.

        Raises ``RuntimeOverloaded`` when admission sheds, and the
        future fails with ``DeadlineExceeded`` when ``deadline_s`` (or
        the runtime's ``default_deadline_s``) expires before service.
        """
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        while True:
            digest, engines = self.registry.get_engines(model)
            try:
                fut = self._batcher(digest, engines).submit(
                    Z, deadline_s=deadline_s
                )
            except BatcherClosed:
                # the batcher was retired between lookup and submit (engine
                # evicted + reloaded under us); re-resolve onto the fresh one
                continue
            for fn in self._traffic_listeners:
                fn(model, digest, Z)
            return fut

    def predict(self, model: str, Z) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous convenience: (values, valid) like ``SVMEngine.predict``."""
        res = self.submit(model, Z).result()
        return res.values, res.valid

    def warmup(self, model: str) -> int:
        """Force-load + warm ``model`` now; returns its compiled variants."""
        _, engine = self.registry.get_engine(model)
        if not self.registry.warmup_on_load:
            engine.warmup()                 # registry didn't warm at load time
        return engine.jit_cache_size()

    # ------------------------------------------------------------- telemetry

    def telemetry(self, model: str) -> ModelTelemetry:
        """The live ``ModelTelemetry`` for ``model``'s current digest
        (created if the model has not served yet) — what ``DriftGuard``
        reads its fallback window from and records canary verdicts on."""
        digest = self.registry.resolve(model)
        with self._lock:
            return self._telemetry.setdefault(digest, ModelTelemetry())

    def stats(self, model: str | None = None) -> dict:
        """Telemetry snapshot: one model's, or the whole runtime tree."""
        if model is not None:
            digest = self.registry.resolve(model)
            tel = self._telemetry.get(digest)
            batcher = self._batchers.get(digest)
            if batcher is not None:
                engine = batcher.engine          # the engine traffic actually hits
            else:
                entry = self.registry._entries.get(digest)
                engine = entry.engine if entry is not None else None
            if tel is None:
                tel = ModelTelemetry()            # zeroed snapshot pre-traffic
            out = tel.snapshot(engine)
            out["digest"] = digest
            if batcher is not None and batcher.breaker is not None:
                out["breaker"]["config"] = batcher.breaker.snapshot()
                # live per-replica circuits (telemetry's "replicas" block
                # holds the counters; this is current state + config)
                out["breaker"]["per_replica"] = [
                    r.breaker.snapshot() if r.breaker is not None else None
                    for r in batcher.replicas
                ]
            entry = self.registry._entries.get(digest)
            if entry is not None:
                out["evictions"] = entry.evictions
                out["quarantined"] = entry.quarantined
            return out
        with self._lock:
            digests = list(self._telemetry)
        return {
            "registry": self.registry.snapshot(),
            "models": {d[:12]: self.stats(d) for d in digests},
        }

    def render_prometheus(self) -> str:
        """Prometheus text exposition of this runtime's metrics registry
        ("" when observability is disabled). The future HTTP front door
        (ROADMAP item 1) serves exactly this string."""
        if self.obs is None:
            return ""
        return self.obs.render_prometheus()

    def profile(self, model: str, Z, path) -> str:
        """Capture a ``jax.profiler`` trace of ONE coalesced step.

        Warms ``model`` first so the capture shows steady-state serving
        (step dispatch + device compute), not compilation; then submits
        ``Z`` and materializes the result inside the profiler session,
        with engine-step trace annotations enabled for the duration.
        The trace directory is written to ``path`` (viewable with
        TensorBoard's profile plugin). Returns ``path``.
        """
        self.warmup(model)
        with obs_profile.capture(path):
            res = self.submit(model, Z).result()
            np.asarray(res.values)          # device -> host sync in-session
        return str(path)

    # -------------------------------------------------------------- lifetime

    def close(self) -> None:
        """Shut down every batcher; EVERY pending future resolves (with
        its result if the final flush served it, ``BatcherClosed`` if
        not) and every worker thread is joined — no caller blocked on
        ``future.result()`` survives a close un-woken."""
        with self._lock:
            self._closed = True
            batchers = list(self._batchers.values())
            self._batchers.clear()
        for b in batchers:
            b.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
