"""Opt-in kernel profiling hooks around the engine/backend seam.

Two annotation layers, both off by default (zero steady-state cost —
the hot path sees one module-global ``bool`` check):

* **Host-side** — ``annotate(name)`` wraps the blocking dispatch of a
  compiled engine step in ``jax.profiler.TraceAnnotation`` so the
  profiler timeline shows which engine/bucket a device slice belongs
  to. ``SVMEngine.submit`` / ``submit_exact`` call this around every
  step.
* **Trace-time** — ``enable()`` installs a ``jax.named_scope`` factory
  into ``repro.core.backend`` (via ``backend.set_profile_scope``, a
  callback hook so the core layer never imports serving code). Scoring
  functions traced *while enabled* get their XLA ops grouped under
  ``repro.backend/...`` scopes. Functions compiled before ``enable()``
  keep their old op names until recompiled — enable first, then warm.

``capture(path)`` bundles the whole flow: enable annotations, open a
``jax.profiler.trace`` session writing to ``path``, and restore the
previous state on exit. ``Runtime.profile(model, Z, path)`` uses it to
capture exactly one coalesced step.
"""

from __future__ import annotations

import contextlib
import threading

from repro.core import backend as _backend

_lock = threading.Lock()
_enabled = False


def enabled() -> bool:
    """True when profiling annotations are active."""
    return _enabled


def enable(on: bool = True) -> bool:
    """Toggle profiling annotations; returns the previous state.

    Enabling installs a ``jax.named_scope`` factory into the backend
    dispatch seam so newly traced scoring functions carry structured
    op names; disabling uninstalls it.
    """
    global _enabled
    with _lock:
        prev = _enabled
        _enabled = bool(on)
        from repro.serve import svm_engine as _engine

        if _enabled:
            import jax
            from jax.profiler import TraceAnnotation

            _backend.set_profile_scope(jax.named_scope)
            _engine.set_profile_annotation(TraceAnnotation)
        else:
            _backend.set_profile_scope(None)
            _engine.set_profile_annotation(None)
    return prev


def annotate(name: str):
    """Context manager: ``jax.profiler.TraceAnnotation`` when enabled,
    a no-op otherwise. Safe to use on every hot-path step."""
    if not _enabled:
        return contextlib.nullcontext()
    from jax.profiler import TraceAnnotation

    return TraceAnnotation(name)


@contextlib.contextmanager
def capture(path):
    """Profile everything inside the block into ``path``.

    Enables annotations, records a ``jax.profiler`` trace (viewable
    with TensorBoard's profile plugin or ``perfetto``), then restores
    the previous annotation state.
    """
    import jax

    prev = enable(True)
    try:
        with jax.profiler.trace(str(path)):
            yield
    finally:
        enable(prev)
