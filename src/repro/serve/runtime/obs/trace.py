"""Request-lifecycle tracing with deterministic span identity.

A ``Tracer`` records *completed* spans — small dicts with a name, a
model key, start/end timestamps from an injectable clock, a trace id
linking the spans of one request (or one coalesced flush, or one
DriftGuard heal arc), an optional parent id, and free-form attrs.
Spans land in a bounded per-model ring buffer (``deque(maxlen=...)``)
so a hot runtime can trace forever without growing, and can be dumped
as JSONL for offline inspection.

``span()`` itself is asynchronous: it mints the deterministic id
(lock-free counter) and enqueues an event tuple — about a microsecond
on the caller. A daemon writer thread materializes the record dicts
and monotone counts off the serving path (under a coalesced flush,
every microsecond spent in ``span()`` lands on the latency of every
request in the batch). Readers drain the queue before answering, so
the view any reader gets includes every span recorded before its
call. One contract follows: the ``attrs`` dict is taken by reference
and must not be mutated by the caller after ``span()`` returns.

Determinism contract
--------------------
Span and trace ids derive from a seeded monotone counter:
``{seed:04x}-{ordinal:012x}``. They never encode wall-clock time,
thread identity, or ``id()`` of objects, so a replay that performs the
same allocations in the same order yields byte-identical ids — the
same contract the ``FaultInjector`` gives for fault verdicts (pure
function of seed and ordinal). Under concurrent traffic the allocation
*order* is whatever the thread interleaving produced, but the id of
the N-th allocated span is always the same function of (seed, N).

Conservation
------------
Ring buffers forget; accounting must not. Alongside the ring, the
tracer keeps unbounded monotone per-(model, span-name) counters,
bumped on every ``span()`` call — including per-replica and degraded
sub-keys (``request.served[replica=1]``, ``request.served[degraded]``)
when the span attrs carry those fields. ``conservation(model)``
evaluates the runtime's accounting identity over those counters:

    submitted == admitted + shed
    admitted  == served + failed + expired + closed + in_flight

so ``unaccounted == 0`` must hold after a drained runtime closes, no
matter how many spans the ring evicted.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from queue import Empty, SimpleQueue

# Request lifecycle verdict span names. Every admitted request must
# terminate in exactly one of the TERMINAL names.
ADMITTED = "request.admitted"
SHED = "request.shed"
SERVED = "request.served"
FAILED = "request.failed"
EXPIRED = "request.expired"
CLOSED = "request.closed"
TERMINAL = (SERVED, FAILED, EXPIRED, CLOSED)

_COUNT_ATTR_KEYS = ("replica",)


class Tracer:
    """Bounded per-model span recorder with deterministic ids."""

    def __init__(
        self,
        seed: int = 0,
        capacity: int = 4096,
        clock=time.perf_counter,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.seed = int(seed)
        self.capacity = int(capacity)
        self.clock = clock
        self._lock = threading.Lock()
        self._id_prefix = f"{self.seed & 0xFFFF:04x}-"
        self._rings: dict[str, deque] = {}
        self._counts: dict[str, dict[str, int]] = {}
        # Async span writer. ``span()`` is called on the serving hot path
        # — under a coalesced flush, every microsecond it spends lands on
        # the latency of EVERY request in the batch — so it only mints an
        # ordinal (lock-free ``itertools.count``) and enqueues a tuple;
        # the writer thread materializes records and counts during the
        # batcher's idle coalesce windows. Readers drain the queue under
        # the same lock before answering, so every span enqueued
        # before a read is visible to it (the conservation barrier).
        self._ordinals = itertools.count()
        self._events: SimpleQueue = SimpleQueue()
        self._wake = threading.Event()
        self._writer = threading.Thread(
            target=self._write_loop, daemon=True, name="tracer-writer"
        )
        self._writer.start()

    # -- identity ---------------------------------------------------------

    def new_id(self) -> str:
        """Next deterministic id: ``{seed:04x}-{ordinal:012x}``."""
        return self._id_prefix + format(next(self._ordinals), "012x")

    def new_trace(self) -> str:
        """Fresh trace id linking the spans of one request/flush/heal."""
        return self.new_id()

    # -- recording --------------------------------------------------------

    def span(
        self,
        model: str,
        name: str,
        *,
        trace_id: str | None = None,
        parent_id: str | None = None,
        t_start: float | None = None,
        t_end: float | None = None,
        attrs: dict | None = None,
    ) -> str:
        """Record one completed span; returns its span id.

        Hot-path cost is one lock-free counter bump plus a queue put;
        the record itself is materialized by the writer thread (or by
        the next reader, whichever comes first).
        """
        if t_end is None:
            t_end = self.clock()
        if t_start is None:
            t_start = t_end
        span_id = self._id_prefix + format(next(self._ordinals), "012x")
        self._events.put(
            (span_id, model, name, trace_id, parent_id,
             float(t_start), float(t_end), attrs)
        )
        self._wake.set()
        return span_id

    def span_many(self, model: str, events: list) -> None:
        """Record many completed spans for one model in ONE enqueue.

        The per-flush emission path: a coalesced flush produces one
        ``engine.step``/``flush.dispatch`` span plus a queue-wait and a
        verdict span per request — batching them amortizes the queue
        put and the call overhead across the whole flush. Each event is
        ``(name, trace_id, parent_id, t_start, t_end, attrs)``; span
        ids are minted here in event order (same (seed, ordinal)
        contract as ``span()``). Attrs dicts are taken by reference.
        """
        prefix = self._id_prefix
        ordinals = self._ordinals
        self._events.put(
            [
                (prefix + format(next(ordinals), "012x"),
                 model, name, trace_id, parent_id,
                 float(t_start), float(t_end), attrs)
                for name, trace_id, parent_id, t_start, t_end, attrs in events
            ]
        )
        self._wake.set()

    # -- span materialization (writer thread / readers) -------------------

    def _apply_locked(self, event: tuple) -> None:
        (span_id, model, name, trace_id, parent_id,
         t_start, t_end, attrs) = event
        record = {
            "span_id": span_id,
            "trace_id": trace_id,
            "parent_id": parent_id,
            "model": model,
            "name": name,
            "t_start": t_start,
            "t_end": t_end,
            "attrs": dict(attrs) if attrs else {},
        }
        ring = self._rings.get(model)
        if ring is None:
            ring = deque(maxlen=self.capacity)
            self._rings[model] = ring
        ring.append(record)
        counts = self._counts.setdefault(model, {})
        counts[name] = counts.get(name, 0) + 1
        if attrs:
            for key in _COUNT_ATTR_KEYS:
                if key in attrs:
                    sub = f"{name}[{key}={attrs[key]}]"
                    counts[sub] = counts.get(sub, 0) + 1
            if attrs.get("degraded"):
                sub = f"{name}[degraded]"
                counts[sub] = counts.get(sub, 0) + 1

    def _drain_locked(self) -> None:
        """Move every queued event into rings/counts; caller holds lock.

        All dequeues happen here, under the lock — the writer thread
        never holds an event outside it, so a reader that drains sees
        every span enqueued before its call.
        """
        while True:
            try:
                event = self._events.get_nowait()
            except Empty:
                return
            if isinstance(event, list):     # span_many batch
                for item in event:
                    self._apply_locked(item)
            else:
                self._apply_locked(event)

    def _write_loop(self) -> None:
        while True:
            self._wake.wait()
            self._wake.clear()
            with self._lock:
                self._drain_locked()

    # -- inspection -------------------------------------------------------

    def models(self) -> list[str]:
        with self._lock:
            self._drain_locked()
            return sorted(self._rings)

    def spans(self, model: str, name: str | None = None) -> list[dict]:
        """Spans currently held in ``model``'s ring (oldest first)."""
        with self._lock:
            self._drain_locked()
            ring = self._rings.get(model)
            records = list(ring) if ring is not None else []
        if name is not None:
            records = [r for r in records if r["name"] == name]
        return records

    def counts(self, model: str | None = None) -> dict:
        """Monotone span counts; survive ring eviction."""
        with self._lock:
            self._drain_locked()
            if model is not None:
                return dict(self._counts.get(model, {}))
            return {m: dict(c) for m, c in self._counts.items()}

    def conservation(self, model: str) -> dict:
        """Evaluate the accounting identity over monotone span counts."""
        counts = self.counts(model)
        admitted = counts.get(ADMITTED, 0)
        shed = counts.get(SHED, 0)
        terminal = sum(counts.get(name, 0) for name in TERMINAL)
        return {
            "submitted": admitted + shed,
            "admitted": admitted,
            "shed": shed,
            "served": counts.get(SERVED, 0),
            "failed": counts.get(FAILED, 0),
            "expired": counts.get(EXPIRED, 0),
            "closed": counts.get(CLOSED, 0),
            "terminal": terminal,
            "unaccounted": admitted - terminal,
        }

    # -- export -----------------------------------------------------------

    def export_jsonl(self, path, model: str | None = None) -> int:
        """Write ring-resident spans as JSONL; returns the line count."""
        models = [model] if model is not None else self.models()
        n = 0
        with open(path, "w", encoding="utf-8") as fh:
            for key in models:
                for record in self.spans(key):
                    fh.write(json.dumps(record, sort_keys=True))
                    fh.write("\n")
                    n += 1
        return n
