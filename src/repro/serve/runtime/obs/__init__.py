"""Observability subsystem for the serving runtime.

Three layers, one bundle:

* ``trace`` — per-request lifecycle spans with deterministic seeded
  ids, bounded per-model ring buffers, JSONL export, and monotone
  conservation counters (see ``obs/README.md`` for the id contract).
* ``metrics`` — typed counter/gauge/histogram registry with Prometheus
  text exposition (``render_prometheus()``).
* ``profile`` — opt-in ``jax.profiler`` annotations around engine
  steps and the backend dispatch seam.

``Observability`` ties a ``Tracer`` to a ``MetricsRegistry``; every
``Runtime`` owns one (sharing the process default metrics registry
unless given its own) and threads it through scheduler, registry, and
DriftGuard.
"""

from __future__ import annotations

import time

from repro.serve.runtime.obs import profile
from repro.serve.runtime.obs.metrics import (
    DEFAULT_BUCKETS,
    DEFAULT_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    render_prometheus,
)
from repro.serve.runtime.obs.trace import Tracer

__all__ = [
    "Counter",
    "DEFAULT_BUCKETS",
    "DEFAULT_REGISTRY",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Observability",
    "Tracer",
    "profile",
    "render_prometheus",
]


class Observability:
    """A tracer plus a metrics registry, threaded through one runtime.

    ``registry=None`` binds to the process default registry so the
    module-level ``render_prometheus()`` sees every runtime; pass a
    private ``MetricsRegistry()`` for isolation (tests, benchmarks).
    """

    def __init__(
        self,
        *,
        seed: int = 0,
        capacity: int = 4096,
        registry: MetricsRegistry | None = None,
        clock=time.perf_counter,
    ):
        self.tracer = Tracer(seed=seed, capacity=capacity, clock=clock)
        self.metrics = registry if registry is not None else DEFAULT_REGISTRY

    def render_prometheus(self) -> str:
        return self.metrics.render()
