"""Typed metrics registry with Prometheus text-format exposition.

The serving stack's counters were born as per-model snapshot dicts
(``ModelTelemetry.snapshot()``), which answers "what happened" for a
test but not "what is happening" for an ops stack: no standard
exposition format, no label dimensions, no histogram buckets. This
module is the missing substrate:

  * ``Counter`` / ``Gauge`` / ``Histogram`` — the three Prometheus
    instrument types, each a *family* keyed by a label-name tuple;
    ``family.labels(**values)`` returns the child for one label-value
    combination (created on first use, cached after).
  * ``MetricsRegistry`` — a thread-safe collection of families with
    ``render()`` producing the Prometheus text format (``# HELP`` /
    ``# TYPE`` headers, one sample line per child, histogram
    ``_bucket``/``_sum``/``_count`` expansion with an ``+Inf`` bucket).
  * ``render_prometheus()`` — module-level exposition of the process
    default registry, the single string a future HTTP front door
    (ROADMAP item 1) has to serve.

``ModelTelemetry`` binds its counters onto a registry via
``bind_obs``: every existing ``record_*`` site then feeds both the
snapshot dict (back-compat) and the typed instruments, dimensioned by
(model_digest, alias, family, dtype) plus per-metric extra labels
(replica, bucket, verdict). The conservation identity the runtime
property-tests (served + shed + failed + expired + closed ==
submitted) therefore holds in this rendering too — it is the same
``record_*`` call feeding both sides.

Instruments are deliberately minimal: monotonic ``inc`` for counters,
``set`` for gauges, ``observe`` for histograms with explicit bucket
bounds. No default-registry magic inside instruments — a family
belongs to exactly the registry that created it.
"""

from __future__ import annotations

import bisect
import threading

_BAD_LABEL_CHARS = {"\\": "\\\\", '"': '\\"', "\n": "\\n"}  # backslash first


def _escape_label_value(value: str) -> str:
    out = str(value)
    for raw, esc in _BAD_LABEL_CHARS.items():
        out = out.replace(raw, esc)
    return out


def _format_value(value: float) -> str:
    value = float(value)
    if value == int(value) and abs(value) < 1e15:
        return str(int(value))
    return repr(value)


def _sample_line(name: str, labels: dict, value: float) -> str:
    if labels:
        body = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in labels.items())
        return f"{name}{{{body}}} {_format_value(value)}"
    return f"{name} {_format_value(value)}"


class _Child:
    """One label-value combination of a family; holds the value(s)."""

    __slots__ = ("labels", "_lock", "_value", "_buckets", "_sum", "_count")

    def __init__(self, labels: dict, bounds: tuple | None):
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0
        if bounds is not None:
            self._buckets = [0] * (len(bounds) + 1)
            self._sum = 0.0
            self._count = 0
        else:
            self._buckets = None
            self._sum = 0.0
            self._count = 0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        with self._lock:
            self._value += amount

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class _HistogramChild(_Child):
    __slots__ = ()

    def observe(self, value: float, bounds: tuple) -> None:
        value = float(value)
        # bisect (C-implemented) keeps this off the GIL for the serving
        # hot path; lands in _buckets[len(bounds)] (the +Inf bucket)
        # when value exceeds every bound
        idx = bisect.bisect_left(bounds, value)
        with self._lock:
            self._buckets[idx] += 1
            self._sum += value
            self._count += 1


class _Family:
    """One named metric family: fixed label names, children per values."""

    kind = "untyped"

    def __init__(self, name: str, help_text: str, labelnames: tuple):
        self.name = name
        self.help = help_text
        self.labelnames = tuple(labelnames)
        self._children: dict[tuple, _Child] = {}
        self._lock = threading.Lock()

    def _make_child(self, labels: dict) -> _Child:
        return _Child(labels, None)

    def labels(self, **values: str) -> _Child:
        if set(values) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(values))}"
            )
        key = tuple(str(values[k]) for k in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._make_child(dict(zip(self.labelnames, key)))
                self._children[key] = child
            return child

    def children(self) -> list[_Child]:
        with self._lock:
            return list(self._children.values())

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} {self.kind}"]
        for child in self.children():
            lines.append(_sample_line(self.name, child.labels, child.value))
        return lines


class Counter(_Family):
    kind = "counter"


class Gauge(_Family):
    kind = "gauge"


DEFAULT_BUCKETS = (
    0.0005,
    0.001,
    0.0025,
    0.005,
    0.01,
    0.025,
    0.05,
    0.1,
    0.25,
    0.5,
    1.0,
)


class Histogram(_Family):
    kind = "histogram"

    def __init__(
        self,
        name: str,
        help_text: str,
        labelnames: tuple,
        buckets: tuple = DEFAULT_BUCKETS,
    ):
        super().__init__(name, help_text, labelnames)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = bounds

    def _make_child(self, labels: dict) -> _Child:
        return _HistogramChild(labels, self.buckets)

    def labels(self, **values: str) -> "_BoundHistogram":
        child = super().labels(**values)
        return _BoundHistogram(child, self.buckets)

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} {self.kind}"]
        for child in self.children():
            with child._lock:
                counts = list(child._buckets)
                total = child._count
                acc_sum = child._sum
            cumulative = 0
            for bound, n in zip(self.buckets, counts):
                cumulative += n
                labels = dict(child.labels)
                labels["le"] = _format_value(bound)
                lines.append(_sample_line(f"{self.name}_bucket", labels, cumulative))
            labels = dict(child.labels)
            labels["le"] = "+Inf"
            lines.append(_sample_line(f"{self.name}_bucket", labels, total))
            lines.append(_sample_line(f"{self.name}_sum", child.labels, acc_sum))
            lines.append(_sample_line(f"{self.name}_count", child.labels, total))
        return lines


class _BoundHistogram:
    """A histogram child bound to its family's bucket bounds."""

    __slots__ = ("_child", "_bounds")

    def __init__(self, child: _HistogramChild, bounds: tuple):
        self._child = child
        self._bounds = bounds

    def observe(self, value: float) -> None:
        self._child.observe(value, self._bounds)

    @property
    def value(self) -> float:
        return self._child.value


class MetricsRegistry:
    """Thread-safe collection of metric families with text exposition."""

    def __init__(self):
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help_text: str, labelnames, **kw):
        with self._lock:
            family = self._families.get(name)
            if family is None:
                family = cls(name, help_text, tuple(labelnames), **kw)
                self._families[name] = family
                return family
        if not isinstance(family, cls):
            raise ValueError(f"metric {name!r} already registered as {family.kind}")
        if family.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered with labels "
                f"{family.labelnames}, got {tuple(labelnames)}"
            )
        return family

    def counter(self, name: str, help_text: str, labelnames=()) -> Counter:
        return self._get_or_create(Counter, name, help_text, labelnames)

    def gauge(self, name: str, help_text: str, labelnames=()) -> Gauge:
        return self._get_or_create(Gauge, name, help_text, labelnames)

    def histogram(
        self,
        name: str,
        help_text: str,
        labelnames=(),
        buckets: tuple = DEFAULT_BUCKETS,
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help_text, labelnames, buckets=buckets
        )

    def families(self) -> list[_Family]:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def collect(self) -> dict:
        """``{name: {label_tuple: value}}`` — the test-friendly view."""
        out: dict = {}
        for family in self.families():
            series = {}
            for child in family.children():
                key = tuple(sorted(child.labels.items()))
                series[key] = child.value
            out[family.name] = series
        return out

    def render(self) -> str:
        lines: list[str] = []
        for family in self.families():
            lines.extend(family.render())
        return "\n".join(lines) + ("\n" if lines else "")


DEFAULT_REGISTRY = MetricsRegistry()


def render_prometheus(registry: MetricsRegistry | None = None) -> str:
    """Prometheus text exposition of ``registry`` (default: the process
    default registry every ``Runtime`` binds to unless given its own)."""
    return (registry if registry is not None else DEFAULT_REGISTRY).render()
