"""Deterministic fault injection for the serving runtime (chaos harness).

The runtime's robustness claims — "an engine fault fails only its own
batch", "a tripped breaker degrades to the exact path", "a corrupt file
can never serve under its old digest" — are only claims until a test can
MAKE those things happen on demand, repeatably. This module is the
demand side: one ``FaultInjector`` threaded (optionally) through the
batcher and the registry, producing faults that are a pure function of
``(seed, site, check ordinal)`` — never of wall-clock time or thread
scheduling — so a failing chaos run replays exactly.

Sites (the strings the runtime consults):

  * ``"engine_step"``   — consulted by ``MicroBatcher`` immediately
    before the coalesced engine submit; a fault raises ``InjectedFault``
    (the batch fails, the worker must survive), a slow verdict sleeps
    ``slow_step_s`` first (deadline/overload pressure without faulting).
  * ``"registry_load"`` — consulted by ``ArtifactRegistry`` before
    (re)loading an artifact from disk; a fault raises ``InjectedFault``
    (transient load failure: the entry is NOT quarantined and the next
    resolve retries).
  * ``"engine_step#<i>"`` — the replica-scoped variant a multi-replica
    ``MicroBatcher`` consults via ``check_replica``: scripted verdicts
    for replica ``i`` only (fault-isolation tests trip ONE replica's
    breaker while its siblings keep serving). Replica sites are
    scripted-only — when nothing is queued for the replica site the
    check falls through to the base site, so seeded rates behave
    identically whether a model runs 1 replica or N.

Two ways to schedule faults, composable:

  * **scripted** — ``fail_next(site, n)`` / ``slow_next(site, n)`` queue
    exact outcomes for the next n checks (chaos tests that need "the
    next 3 engine steps fail, then recovery");
  * **seeded rates** — ``engine_fault_rate`` etc. draw from a per-site
    ``np.random.default_rng`` sequence: the k-th check of a site gets
    the same verdict for the same seed in every run and every process.

``corrupt_file`` / ``truncate_file`` are the disk-side counterpart:
deterministic (seeded) byte flips / truncation for artifact files, used
to exercise the registry's ``ArtifactCorrupt`` quarantine path.

Counters (``snapshot()``) record checks/faults/slows per site so a chaos
test can assert the harness actually fired — a chaos suite whose faults
silently never trigger is worse than no suite at all.
"""

from __future__ import annotations

import collections
import threading
import time
import zlib

import numpy as np

from repro.serve.runtime.errors import InjectedFault

ENGINE_STEP = "engine_step"
REGISTRY_LOAD = "registry_load"


class FaultInjector:
    """Deterministic, seeded fault source for runtime chaos tests."""

    def __init__(
        self,
        seed: int = 0,
        *,
        engine_fault_rate: float = 0.0,
        slow_step_rate: float = 0.0,
        slow_step_s: float = 0.005,
        registry_load_fail_rate: float = 0.0,
        sleep=time.sleep,
    ):
        self.seed = int(seed)
        self.slow_step_s = float(slow_step_s)
        self._sleep = sleep
        self._rates = {
            ENGINE_STEP: float(engine_fault_rate),
            REGISTRY_LOAD: float(registry_load_fail_rate),
        }
        self._slow_rates = {ENGINE_STEP: float(slow_step_rate)}
        self._rngs: dict[str, np.random.Generator] = {}
        self._scripts: dict[str, collections.deque] = {}
        self._counts: dict[str, dict] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- scheduling

    def fail_next(self, site: str, n: int = 1) -> None:
        """Script the next ``n`` checks of ``site`` to raise."""
        with self._lock:
            self._scripts.setdefault(site, collections.deque()).extend(
                ["fault"] * n
            )

    def slow_next(self, site: str, n: int = 1) -> None:
        """Script the next ``n`` checks of ``site`` to sleep first."""
        with self._lock:
            self._scripts.setdefault(site, collections.deque()).extend(
                ["slow"] * n
            )

    def pass_next(self, site: str, n: int = 1) -> None:
        """Script the next ``n`` checks of ``site`` to pass (overrides
        the seeded rates — lets a test pin a recovery probe's outcome)."""
        with self._lock:
            self._scripts.setdefault(site, collections.deque()).extend(
                ["pass"] * n
            )

    def clear_scripts(self, site: str | None = None) -> None:
        """Drop queued scripted verdicts for ``site`` (or every site):
        the end-of-scenario reset for tests that over-provision a script
        (e.g. "slow everything during this burst") and need the next
        scenario to start from the seeded rates alone."""
        with self._lock:
            if site is None:
                self._scripts.clear()
            else:
                self._scripts.pop(site, None)

    # --------------------------------------------------------------- checking

    def _verdict_locked(self, site: str) -> str:
        script = self._scripts.get(site)
        if script:
            return script.popleft()
        # per-site rng: the k-th draw of a site is the same in every run
        # and does not depend on how other sites interleave with it.
        # crc32, not hash(): str hashing is salted per process
        # (PYTHONHASHSEED), which would silently break replayability.
        rng = self._rngs.get(site)
        if rng is None:
            rng = self._rngs[site] = np.random.default_rng(
                zlib.crc32(f"{self.seed}/{site}".encode())
            )
        u = float(rng.random())
        if u < self._rates.get(site, 0.0):
            return "fault"
        if u < self._rates.get(site, 0.0) + self._slow_rates.get(site, 0.0):
            return "slow"
        return "pass"

    def check(self, site: str) -> None:
        """Consult the injector at ``site``; may sleep or raise.

        Raises ``InjectedFault`` on a fault verdict; sleeps
        ``slow_step_s`` on a slow verdict; otherwise returns.
        """
        with self._lock:
            counts = self._counts.setdefault(
                site, {"checks": 0, "faults": 0, "slows": 0}
            )
            counts["checks"] += 1
            ordinal = counts["checks"]
            verdict = self._verdict_locked(site)
            if verdict == "fault":
                counts["faults"] += 1
            elif verdict == "slow":
                counts["slows"] += 1
        if verdict == "slow":
            self._sleep(self.slow_step_s)
        elif verdict == "fault":
            raise InjectedFault(site, ordinal)

    @staticmethod
    def replica_site(site: str, index: int) -> str:
        """The scripted-only site name scoping ``site`` to one replica."""
        return f"{site}#{int(index)}"

    def check_replica(self, site: str, index: int) -> None:
        """``check`` for replica ``index`` of ``site``.

        A verdict scripted for the replica site (``fail_next(
        replica_site(site, i))``) OVERRIDES the base site entirely —
        including a scripted "pass", so a test can pin one replica
        healthy. With nothing scripted for the replica, the base site is
        consulted as usual (its ordinal stream is shared by all
        replicas, in dispatch order).
        """
        rep = self.replica_site(site, index)
        with self._lock:
            scripted = bool(self._scripts.get(rep))
        if scripted:
            # replica sites carry no seeded rates: an exhausted script
            # can never fault by accident, only by being scripted again
            self.check(rep)
        else:
            self.check(site)

    def snapshot(self) -> dict:
        with self._lock:
            return {site: dict(c) for site, c in self._counts.items()}

    # ----------------------------------------------------------- file faults

    @staticmethod
    def corrupt_bytes(data: bytes, seed: int = 0, n_flips: int = 16) -> bytes:
        """Flip ``n_flips`` deterministic byte positions of ``data``."""
        buf = bytearray(data)
        if not buf:
            return bytes(buf)
        rng = np.random.default_rng(seed)
        for pos in rng.integers(0, len(buf), size=n_flips):
            buf[int(pos)] ^= 0xFF
        return bytes(buf)

    @classmethod
    def corrupt_file(cls, path: str, seed: int = 0, n_flips: int = 16) -> str:
        """Deterministically flip bytes of ``path`` in place."""
        with open(path, "rb") as f:
            data = f.read()
        with open(path, "wb") as f:
            f.write(cls.corrupt_bytes(data, seed=seed, n_flips=n_flips))
        return path

    @staticmethod
    def truncate_file(path: str, keep_fraction: float = 0.5) -> str:
        """Truncate ``path`` to ``keep_fraction`` of its size in place."""
        import os

        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(0, int(size * keep_fraction)))
        return path
