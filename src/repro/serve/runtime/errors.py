"""Unified failure taxonomy for the serving stack.

Every way the runtime (or the HTTP front door over it) can refuse or
fail a request is a ``ServingError`` subclass carrying two STABLE,
machine-readable attributes:

  * ``code`` — a frozen string identifier (``"overloaded"``,
    ``"deadline_exceeded"``, ...) that wire clients may switch on.
    Codes are part of the public API: renaming one is a breaking
    change (``tests/test_public_api.py`` snapshots them).
  * ``http_status`` — the HTTP status the front door maps the error to.
    The server maps BY ATTRIBUTE (``getattr(exc, "http_status")``),
    never by an isinstance ladder, so a new error type only has to set
    the two class attributes to be wired end to end.

The taxonomy (status → type):

  * 429 ``RuntimeOverloaded`` — admission control shed the request
    before it entered the queue (bounded queue full, a tripped breaker
    with no exact model to degrade to, or a tenant quota). Carries
    ``retry_after_s``, the server's own estimate of when capacity
    returns; the front door surfaces it as a ``Retry-After`` header.
  * 504 ``DeadlineExceeded`` — the request was admitted but its
    per-submit deadline expired before a flush could serve it.
  * 503 ``BatcherClosed`` — the model's batcher was retired (shutdown,
    or an engine eviction/hot-reload); ``Runtime.submit`` retries
    internally, a bare ``MicroBatcher`` caller sees it directly.
  * 503 ``ArtifactCorrupt`` — an artifact file failed structural
    validation or its bytes no longer hash to the registered digest;
    the registry QUARANTINES the entry (no retry loop) and every
    subsequent resolve fails fast with this error.
  * 404 ``ModelNotFound`` — a ref that resolves to no registered
    digest, alias, or unique prefix (also raised for an ambiguous
    prefix). Subclasses ``KeyError`` so pre-taxonomy callers that
    caught ``KeyError`` from ``ArtifactRegistry.resolve`` keep working.
  * 500 ``InjectedFault`` — raised only by the deterministic
    fault-injection harness (``repro.serve.runtime.faults``); chaos
    tests assert on this type to distinguish injected failures from
    real bugs.

The old concrete bases are preserved (``RuntimeOverloaded`` is still a
``RuntimeError``, ``DeadlineExceeded`` a ``TimeoutError``) so every
pre-taxonomy ``except`` clause keeps catching what it caught.
"""

from __future__ import annotations


class ServingError(Exception):
    """Base of the serving failure taxonomy.

    ``code`` and ``http_status`` are class attributes frozen per
    subclass; ``to_wire()`` is the canonical JSON-able error body the
    HTTP front door returns (subclasses extend it with their extra
    fields, e.g. ``retry_after_s``).
    """

    code: str = "serving_error"
    http_status: int = 500

    def to_wire(self) -> dict:
        return {
            "code": self.code,
            "status": self.http_status,
            "message": str(self),
        }


class RuntimeOverloaded(ServingError, RuntimeError):
    """Request shed by admission control; retry after ``retry_after_s``."""

    code = "overloaded"
    http_status = 429

    def __init__(self, message: str, retry_after_s: float = 0.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)

    def to_wire(self) -> dict:
        out = super().to_wire()
        out["retry_after_s"] = self.retry_after_s
        return out


class DeadlineExceeded(ServingError, TimeoutError):
    """Admitted request could not be flushed within its deadline."""

    code = "deadline_exceeded"
    http_status = 504


class BatcherClosed(ServingError, RuntimeError):
    """Raised by ``submit`` on a closed batcher (e.g. retired after an
    engine reload); ``Runtime`` re-resolves and retries on a fresh one."""

    code = "batcher_closed"
    http_status = 503


class ArtifactCorrupt(ServingError, RuntimeError):
    """Artifact file is structurally invalid or no longer matches its
    registered content digest. The entry is quarantined, not retried."""

    code = "artifact_corrupt"
    http_status = 503

    def __init__(self, message: str, *, digest: str | None = None,
                 path: str | None = None):
        super().__init__(message)
        self.digest = digest
        self.path = path

    def to_wire(self) -> dict:
        out = super().to_wire()
        if self.digest is not None:
            out["digest"] = self.digest
        return out


class ModelNotFound(ServingError, KeyError):
    """``ref`` resolves to no registered model (or is ambiguous).

    Subclasses ``KeyError`` for back-compat with callers that caught the
    registry's pre-taxonomy raise. ``__str__`` is overridden because
    ``KeyError`` quotes its args.
    """

    code = "model_not_found"
    http_status = 404

    def __init__(self, message: str, *, ref: str | None = None):
        super().__init__(message)
        self.ref = ref

    def __str__(self) -> str:
        return self.args[0] if self.args else ""

    def to_wire(self) -> dict:
        out = super().to_wire()
        if self.ref is not None:
            out["ref"] = self.ref
        return out


class InjectedFault(ServingError, RuntimeError):
    """A fault deliberately raised by the fault-injection harness."""

    code = "injected_fault"
    http_status = 500

    def __init__(self, site: str, ordinal: int):
        super().__init__(f"injected fault at {site!r} (check #{ordinal})")
        self.site = site
        self.ordinal = ordinal
