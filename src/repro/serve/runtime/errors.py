"""Typed failure taxonomy for the serving runtime.

Every way the runtime can refuse or fail a request has a distinct,
catchable type — a caller (or an HTTP front door mapping these onto
status codes) never has to parse a message string:

  * ``RuntimeOverloaded`` — admission control shed the request before it
    entered the queue (bounded queues, or a tripped breaker with no
    exact model to degrade to). Carries ``retry_after_s``, the server's
    own estimate of when capacity returns (HTTP 503 + Retry-After).
  * ``DeadlineExceeded`` — the request was admitted but its per-submit
    deadline expired before a flush could serve it (HTTP 504).
  * ``BatcherClosed`` — the model's batcher was retired (shutdown, or an
    engine eviction/hot-reload); ``Runtime.submit`` retries internally,
    a bare ``MicroBatcher`` caller sees it directly.
  * ``ArtifactCorrupt`` — an artifact file failed structural validation
    or its bytes no longer hash to the registered digest; the registry
    QUARANTINES the entry (no retry loop) and every subsequent resolve
    fails fast with this error until the file is repaired/re-registered.
  * ``InjectedFault`` — raised only by the deterministic fault-injection
    harness (``repro.serve.runtime.faults``); chaos tests assert on this
    type to distinguish injected failures from real bugs.
"""

from __future__ import annotations


class RuntimeOverloaded(RuntimeError):
    """Request shed by admission control; retry after ``retry_after_s``."""

    def __init__(self, message: str, retry_after_s: float = 0.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(TimeoutError):
    """Admitted request could not be flushed within its deadline."""


class BatcherClosed(RuntimeError):
    """Raised by ``submit`` on a closed batcher (e.g. retired after an
    engine reload); ``Runtime`` re-resolves and retries on a fresh one."""


class ArtifactCorrupt(RuntimeError):
    """Artifact file is structurally invalid or no longer matches its
    registered content digest. The entry is quarantined, not retried."""

    def __init__(self, message: str, *, digest: str | None = None,
                 path: str | None = None):
        super().__init__(message)
        self.digest = digest
        self.path = path


class InjectedFault(RuntimeError):
    """A fault deliberately raised by the fault-injection harness."""

    def __init__(self, site: str, ordinal: int):
        super().__init__(f"injected fault at {site!r} (check #{ordinal})")
        self.site = site
        self.ordinal = ordinal
