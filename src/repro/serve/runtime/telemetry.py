"""Per-model serving telemetry for the multi-tenant runtime.

One ``ModelTelemetry`` per served digest, fed by the micro-batcher
(enqueue / flush / materialize events) and merged with the engine's own
``EngineStats.snapshot()`` when exported. Everything is lock-guarded —
the writers are N client threads plus the flush thread.

The exported snapshot answers the operational questions the ROADMAP's
"millions of users" target implies:

  * **p50 / p99 latency** — end-to-end per request: enqueue into the
    scheduler queue → the coalesced result's host materialization. A
    bounded ring buffer (default 4096 samples) keeps the percentile
    memory constant under unbounded traffic.
  * **queue depth** — current and high-water pending rows, the signal
    that a model needs a bigger flush target (or more capacity).
  * **coalescing factor** — requests per engine step; 1.0 means the
    scheduler is adding latency without amortizing anything, ≫1 is the
    micro-batching win.
  * **fallback rate / compile count** — straight from the engine's
    thread-safe stats (accuracy-contract violations, trace activity).
  * **evictions / loads** — registry-level counters (cold-model churn).

Robustness counters (every failure mode the overload/fault/drift layer
can produce is observable — nothing sheds or fails silently):

  * **shed_requests / shed_rows** — rejected by admission control
    (bounded queue) with ``RuntimeOverloaded``;
  * **deadline_timeouts** — admitted requests failed with
    ``DeadlineExceeded`` because their per-submit deadline expired
    before a flush could include them;
  * **batch_failures / failed_requests / failed_rows** — engine-step
    exceptions scattered to exactly the affected batch's futures;
  * **tightened_waits** — flushes whose ``max_wait_us`` was shortened
    by queue pressure (the SLO-aware knob engaging);
  * **breaker** — current circuit state plus trip/probe counters,
    ``degraded_*`` accounting for batches served by the exact
    ``rbf_pred`` path while the breaker holds the fast path open, and
    ``breaker_shed_requests`` for open-breaker sheds when no exact
    model was published;
  * **canary / recompiles** — the ``DriftGuard`` self-healing loop's
    verdicts (recompiles triggered, canaries passed/failed);
  * **replicas** — per-replica flush/row/failure counters plus each
    replica's last observed breaker state: the scale-out dispatcher's
    observability (is load actually spreading? which replica is the
    one tripping?). The model-level ``breaker.state`` keeps its
    single-replica meaning and mirrors the most recent transition of
    ANY replica — per-replica truth lives here;
  * **fallback_window** — a bounded window of recent per-row validity
    (fast-path batches only), the drift signal ``DriftGuard`` watches:
    the LIFETIME fallback rate of a long-lived model dilutes a sudden
    input shift, the windowed rate does not.
"""

from __future__ import annotations

import collections
import threading

import numpy as np

DEFAULT_WINDOW = 4096
DEFAULT_VALIDITY_WINDOW = 256          # recent flushes tracked for drift


class LatencyWindow:
    """Bounded sample window with percentile export (thread-safe)."""

    def __init__(self, maxlen: int = DEFAULT_WINDOW):
        self._samples = collections.deque(maxlen=maxlen)
        self._count = 0
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))
            self._count += 1

    def snapshot(self) -> dict:
        with self._lock:
            samples = np.asarray(self._samples, np.float64)
            total = self._count
        if samples.size == 0:
            return {"n": 0, "p50_ms": None, "p99_ms": None}
        return {
            "n": total,                       # recorded ever; window may be smaller
            "p50_ms": round(float(np.percentile(samples, 50)) * 1e3, 4),
            "p99_ms": round(float(np.percentile(samples, 99)) * 1e3, 4),
        }


class ModelTelemetry:
    """Counters + latency window for one served model (one digest)."""

    def __init__(self, window: int = DEFAULT_WINDOW,
                 validity_window: int = DEFAULT_VALIDITY_WINDOW):
        self.latency = LatencyWindow(window)
        self._lock = threading.Lock()
        self._requests = 0
        self._rows = 0
        self._flushes = 0
        self._deadline_flushes = 0        # flushed because max_wait_us expired
        self._queue_rows = 0              # rows currently pending
        self._max_queue_rows = 0
        # -- admission / deadline / failure accounting
        self._shed_requests = 0
        self._shed_rows = 0
        self._deadline_timeouts = 0
        self._batch_failures = 0
        self._failed_requests = 0
        self._failed_rows = 0
        self._tightened_waits = 0
        # -- circuit breaker / degraded serving
        self._breaker_state = "closed"
        self._breaker_trips = 0
        self._breaker_probes = 0
        self._degraded_flushes = 0
        self._degraded_requests = 0
        self._degraded_rows = 0
        self._breaker_shed_requests = 0
        # -- self-healing loop
        self._recompiles = 0
        self._canary_pass = 0
        self._canary_fail = 0
        # -- drift signal: (rows, invalid_rows) per recent fast-path flush
        self._validity = collections.deque(maxlen=validity_window)
        # -- per-replica dispatch accounting (scale-out)
        self._replicas: dict[int, dict] = {}

    # ------------------------------------------------------------- recording

    def record_enqueue(self, rows: int) -> None:
        with self._lock:
            self._requests += 1
            self._rows += rows
            self._queue_rows += rows
            self._max_queue_rows = max(self._max_queue_rows, self._queue_rows)

    def record_flush(self, requests: int, rows: int, *, deadline: bool,
                     tightened: bool = False) -> None:
        with self._lock:
            self._flushes += 1
            self._deadline_flushes += int(deadline)
            self._tightened_waits += int(tightened)
            self._queue_rows -= rows

    def record_latency(self, seconds: float) -> None:
        self.latency.record(seconds)

    def record_shed(self, rows: int) -> None:
        """Request rejected at admission (never entered the queue)."""
        with self._lock:
            self._shed_requests += 1
            self._shed_rows += rows

    def record_deadline_timeout(self, requests: int = 1, rows: int = 0) -> None:
        """Admitted requests expired while queued (left without a flush)."""
        with self._lock:
            self._deadline_timeouts += requests
            self._queue_rows -= rows

    def record_batch_failure(self, requests: int, rows: int) -> None:
        """One engine step failed; its futures got the exception."""
        with self._lock:
            self._batch_failures += 1
            self._failed_requests += requests
            self._failed_rows += rows

    def _replica_locked(self, index: int) -> dict:
        return self._replicas.setdefault(int(index), {
            "flushes": 0,
            "requests": 0,
            "rows": 0,
            "failures": 0,
            "breaker_state": "closed",
            "trips": 0,
            "probes": 0,
        })

    def record_replica_flush(self, index: int, requests: int, rows: int) -> None:
        """One fast-path flush served by replica ``index``."""
        with self._lock:
            c = self._replica_locked(index)
            c["flushes"] += 1
            c["requests"] += requests
            c["rows"] += rows

    def record_replica_failure(self, index: int) -> None:
        """One fast-path flush FAILED on replica ``index``."""
        with self._lock:
            self._replica_locked(index)["failures"] += 1

    def record_breaker_state(self, state: str, *, tripped: bool = False,
                             probe: bool = False, replica: int = 0) -> None:
        with self._lock:
            # model-level state keeps its pre-replica meaning: the most
            # recent transition anywhere (exact for a single replica)
            self._breaker_state = state
            self._breaker_trips += int(tripped)
            self._breaker_probes += int(probe)
            c = self._replica_locked(replica)
            c["breaker_state"] = state
            c["trips"] += int(tripped)
            c["probes"] += int(probe)

    def record_degraded(self, requests: int, rows: int) -> None:
        """One flush served by the exact path under an open breaker."""
        with self._lock:
            self._degraded_flushes += 1
            self._degraded_requests += requests
            self._degraded_rows += rows

    def record_breaker_shed(self, requests: int = 1) -> None:
        with self._lock:
            self._breaker_shed_requests += requests

    def record_recompile(self) -> None:
        with self._lock:
            self._recompiles += 1

    def record_canary(self, passed: bool) -> None:
        with self._lock:
            if passed:
                self._canary_pass += 1
            else:
                self._canary_fail += 1

    def record_validity(self, rows: int, invalid: int) -> None:
        """Per-row validity of one FAST-PATH flush (drift window input).

        Degraded (breaker-open) flushes must NOT be recorded here: their
        rows are exact-served by construction and would read as 100%
        fallback, turning an engine fault into a phantom drift signal.
        """
        if rows <= 0:
            return
        with self._lock:
            self._validity.append((int(rows), int(invalid)))

    def fallback_window(self) -> dict:
        """Recent-traffic fallback rate — the ``DriftGuard`` signal."""
        with self._lock:
            rows = sum(r for r, _ in self._validity)
            invalid = sum(i for _, i in self._validity)
        return {
            "rows": rows,
            "invalid": invalid,
            "rate": invalid / rows if rows else 0.0,
        }

    def reset_fallback_window(self) -> None:
        with self._lock:
            self._validity.clear()

    # -------------------------------------------------------------- exporting

    def snapshot(self, engine=None) -> dict:
        with self._lock:
            out = {
                "requests": self._requests,
                "rows": self._rows,
                "flushes": self._flushes,
                "deadline_flushes": self._deadline_flushes,
                "queue_rows": self._queue_rows,
                "max_queue_rows": self._max_queue_rows,
                "coalescing_factor": round(
                    self._requests / max(1, self._flushes), 3
                ),
                "rows_per_flush": round(self._rows / max(1, self._flushes), 2),
                "shed_requests": self._shed_requests,
                "shed_rows": self._shed_rows,
                "deadline_timeouts": self._deadline_timeouts,
                "batch_failures": self._batch_failures,
                "failed_requests": self._failed_requests,
                "failed_rows": self._failed_rows,
                "tightened_waits": self._tightened_waits,
                "breaker": {
                    "state": self._breaker_state,
                    "trips": self._breaker_trips,
                    "probes": self._breaker_probes,
                    "degraded_flushes": self._degraded_flushes,
                    "degraded_requests": self._degraded_requests,
                    "degraded_rows": self._degraded_rows,
                    "shed_requests": self._breaker_shed_requests,
                },
                "canary": {
                    "recompiles": self._recompiles,
                    "passed": self._canary_pass,
                    "failed": self._canary_fail,
                },
                "replicas": {
                    str(i): dict(c)
                    for i, c in sorted(self._replicas.items())
                },
            }
        out["fallback_window"] = self.fallback_window()
        out["latency"] = self.latency.snapshot()
        if engine is not None:
            eng = engine.stats.snapshot()
            out["engine"] = eng
            out["fallback_rate"] = eng["fallback_rate"]
            out["compiled_steps"] = eng["compiled_steps"]
        return out
