"""Per-model serving telemetry for the multi-tenant runtime.

One ``ModelTelemetry`` per served digest, fed by the micro-batcher
(enqueue / flush / materialize events) and merged with the engine's own
``EngineStats.snapshot()`` when exported. Everything is lock-guarded —
the writers are N client threads plus the flush thread.

The exported snapshot answers the operational questions the ROADMAP's
"millions of users" target implies:

  * **p50 / p99 latency** — end-to-end per request: enqueue into the
    scheduler queue → the coalesced result's host materialization. A
    bounded ring buffer (default 4096 samples) keeps the percentile
    memory constant under unbounded traffic.
  * **queue depth** — current and high-water pending rows, the signal
    that a model needs a bigger flush target (or more capacity).
  * **coalescing factor** — requests per engine step; 1.0 means the
    scheduler is adding latency without amortizing anything, ≫1 is the
    micro-batching win.
  * **fallback rate / compile count** — straight from the engine's
    thread-safe stats (accuracy-contract violations, trace activity).
  * **evictions / loads** — registry-level counters (cold-model churn).
"""

from __future__ import annotations

import collections
import threading

import numpy as np

DEFAULT_WINDOW = 4096


class LatencyWindow:
    """Bounded sample window with percentile export (thread-safe)."""

    def __init__(self, maxlen: int = DEFAULT_WINDOW):
        self._samples = collections.deque(maxlen=maxlen)
        self._count = 0
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))
            self._count += 1

    def snapshot(self) -> dict:
        with self._lock:
            samples = np.asarray(self._samples, np.float64)
            total = self._count
        if samples.size == 0:
            return {"n": 0, "p50_ms": None, "p99_ms": None}
        return {
            "n": total,                       # recorded ever; window may be smaller
            "p50_ms": round(float(np.percentile(samples, 50)) * 1e3, 4),
            "p99_ms": round(float(np.percentile(samples, 99)) * 1e3, 4),
        }


class ModelTelemetry:
    """Counters + latency window for one served model (one digest)."""

    def __init__(self, window: int = DEFAULT_WINDOW):
        self.latency = LatencyWindow(window)
        self._lock = threading.Lock()
        self._requests = 0
        self._rows = 0
        self._flushes = 0
        self._deadline_flushes = 0        # flushed because max_wait_us expired
        self._queue_rows = 0              # rows currently pending
        self._max_queue_rows = 0

    # ------------------------------------------------------------- recording

    def record_enqueue(self, rows: int) -> None:
        with self._lock:
            self._requests += 1
            self._rows += rows
            self._queue_rows += rows
            self._max_queue_rows = max(self._max_queue_rows, self._queue_rows)

    def record_flush(self, requests: int, rows: int, *, deadline: bool) -> None:
        with self._lock:
            self._flushes += 1
            self._deadline_flushes += int(deadline)
            self._queue_rows -= rows

    def record_latency(self, seconds: float) -> None:
        self.latency.record(seconds)

    # -------------------------------------------------------------- exporting

    def snapshot(self, engine=None) -> dict:
        with self._lock:
            out = {
                "requests": self._requests,
                "rows": self._rows,
                "flushes": self._flushes,
                "deadline_flushes": self._deadline_flushes,
                "queue_rows": self._queue_rows,
                "max_queue_rows": self._max_queue_rows,
                "coalescing_factor": round(
                    self._requests / max(1, self._flushes), 3
                ),
                "rows_per_flush": round(self._rows / max(1, self._flushes), 2),
            }
        out["latency"] = self.latency.snapshot()
        if engine is not None:
            eng = engine.stats.snapshot()
            out["engine"] = eng
            out["fallback_rate"] = eng["fallback_rate"]
            out["compiled_steps"] = eng["compiled_steps"]
        return out
