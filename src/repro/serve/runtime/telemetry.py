"""Per-model serving telemetry for the multi-tenant runtime.

One ``ModelTelemetry`` per served digest, fed by the micro-batcher
(enqueue / flush / materialize events) and merged with the engine's own
``EngineStats.snapshot()`` when exported. Everything is lock-guarded —
the writers are N client threads plus the flush thread.

The exported snapshot answers the operational questions the ROADMAP's
"millions of users" target implies:

  * **p50 / p99 latency** — end-to-end per request: enqueue into the
    scheduler queue → the coalesced result's host materialization. A
    bounded ring buffer (default 4096 samples) keeps the percentile
    memory constant under unbounded traffic.
  * **queue depth** — current and high-water pending rows, the signal
    that a model needs a bigger flush target (or more capacity).
  * **coalescing factor** — requests per engine step; 1.0 means the
    scheduler is adding latency without amortizing anything, ≫1 is the
    micro-batching win.
  * **fallback rate / compile count** — straight from the engine's
    thread-safe stats (accuracy-contract violations, trace activity).
  * **evictions / loads** — registry-level counters (cold-model churn).

Robustness counters (every failure mode the overload/fault/drift layer
can produce is observable — nothing sheds or fails silently):

  * **shed_requests / shed_rows** — rejected by admission control
    (bounded queue) with ``RuntimeOverloaded``;
  * **deadline_timeouts** — admitted requests failed with
    ``DeadlineExceeded`` because their per-submit deadline expired
    before a flush could include them;
  * **batch_failures / failed_requests / failed_rows** — engine-step
    exceptions scattered to exactly the affected batch's futures;
  * **tightened_waits** — flushes whose ``max_wait_us`` was shortened
    by queue pressure (the SLO-aware knob engaging);
  * **breaker** — current circuit state plus trip/probe counters,
    ``degraded_*`` accounting for batches served by the exact
    ``rbf_pred`` path while the breaker holds the fast path open, and
    ``breaker_shed_requests`` for open-breaker sheds when no exact
    model was published;
  * **canary / recompiles** — the ``DriftGuard`` self-healing loop's
    verdicts (recompiles triggered, canaries passed/failed);
  * **replicas** — per-replica flush/row/failure counters plus each
    replica's last observed breaker state: the scale-out dispatcher's
    observability (is load actually spreading? which replica is the
    one tripping?). The model-level ``breaker.state`` keeps its
    single-replica meaning and mirrors the most recent transition of
    ANY replica — per-replica truth lives here;
  * **fallback_window** — a bounded window of recent per-row validity
    (fast-path batches only), the drift signal ``DriftGuard`` watches:
    the LIFETIME fallback rate of a long-lived model dilutes a sudden
    input shift, the windowed rate does not.

Observability binding (PR 9): ``bind_obs(registry, labels)`` mirrors
every ``record_*`` call onto typed instruments in an
``obs.MetricsRegistry`` — counters for the full request-accounting
identity (served + failed + expired + breaker-shed + closed ==
admitted), gauges for queue depth, the §4 validity fraction /
windowed fallback rate, the EWMA step time, and per-replica breaker
state, and a latency histogram — dimensioned by (model_digest, alias,
family, dtype) plus replica/bucket/verdict where they apply. The
snapshot dict stays the source of truth for tests; the registry is
the Prometheus-facing projection of the SAME call sites, so the
conservation identity cannot diverge between the two.
"""

from __future__ import annotations

import collections
import math
import threading

DEFAULT_WINDOW = 4096
DEFAULT_VALIDITY_WINDOW = 256          # recent flushes tracked for drift
HEAL_HISTORY = 32                      # DriftGuard heal verdicts retained

BREAKER_STATE_VALUES = {"closed": 0, "half_open": 1, "open": 2}


def _nearest_rank(sorted_samples: list, pct: float) -> float:
    """Nearest-rank percentile: the ceil(p/100 * n)-th smallest sample.

    Always an OBSERVED sample — no interpolation — so low-traffic
    dashboard gauges step between real latencies instead of jittering
    through synthetic in-between values (n=1 returns that sample for
    every percentile; n=2 puts p50 on the 1st and p99 on the 2nd).
    """
    n = len(sorted_samples)
    idx = max(0, math.ceil((pct / 100.0) * n) - 1)
    return sorted_samples[min(idx, n - 1)]


class LatencyWindow:
    """Bounded sample window with percentile export (thread-safe)."""

    def __init__(self, maxlen: int = DEFAULT_WINDOW):
        self._samples = collections.deque(maxlen=maxlen)
        self._count = 0
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._samples.append(float(seconds))
            self._count += 1

    def snapshot(self) -> dict:
        with self._lock:
            samples = sorted(self._samples)
            total = self._count
        if not samples:
            return {"n": 0, "p50_ms": None, "p99_ms": None}
        return {
            "n": total,                       # recorded ever; window may be smaller
            "p50_ms": round(_nearest_rank(samples, 50) * 1e3, 4),
            "p99_ms": round(_nearest_rank(samples, 99) * 1e3, 4),
        }


class _BoundMetrics:
    """Typed-instrument projection of one model's telemetry.

    Holds the pre-resolved children for the base label set
    (model_digest, alias, family, dtype) plus family handles for the
    metrics that carry extra labels (replica, bucket, verdict,
    outcome). Created by ``ModelTelemetry.bind_obs``; every record_*
    site then feeds both the snapshot counters and these instruments.
    """

    BASE_LABELS = ("model_digest", "alias", "family", "dtype")

    def __init__(self, registry, labels: dict):
        self.registry = registry
        base = {k: str(labels.get(k, "")) for k in self.BASE_LABELS}
        self.base = base
        L = self.BASE_LABELS
        c, g, h = registry.counter, registry.gauge, registry.histogram

        def _c(name, help_text, extra=()):
            return c(name, help_text, L + tuple(extra))

        self.requests = _c(
            "repro_serve_requests_total", "Requests admitted to the queue."
        ).labels(**base)
        self.rows = _c(
            "repro_serve_rows_total", "Rows admitted to the queue."
        ).labels(**base)
        self.shed = _c(
            "repro_serve_shed_requests_total",
            "Requests rejected at admission (bounded queue).",
        ).labels(**base)
        self.served = _c(
            "repro_serve_served_requests_total",
            "Requests whose future resolved with scores.",
        ).labels(**base)
        self.served_rows = _c(
            "repro_serve_served_rows_total", "Rows scored and scattered back."
        ).labels(**base)
        self.failed = _c(
            "repro_serve_failed_requests_total",
            "Requests failed by an engine-step exception.",
        ).labels(**base)
        self.expired = _c(
            "repro_serve_deadline_timeouts_total",
            "Admitted requests expired before a flush included them.",
        ).labels(**base)
        self.closed = _c(
            "repro_serve_closed_requests_total",
            "Admitted requests failed because the batcher closed.",
        ).labels(**base)
        self.breaker_shed = _c(
            "repro_serve_breaker_shed_requests_total",
            "Requests shed under an open breaker with no exact fallback.",
        ).labels(**base)
        self.degraded = _c(
            "repro_serve_degraded_requests_total",
            "Requests served by the exact path under an open breaker.",
        ).labels(**base)
        self.flushes = _c(
            "repro_serve_flushes_total", "Coalesced engine flushes."
        ).labels(**base)
        self.batch_failures = _c(
            "repro_serve_batch_failures_total", "Engine flushes that raised."
        ).labels(**base)
        self.recompiles = _c(
            "repro_serve_recompiles_total", "DriftGuard recompiles triggered."
        ).labels(**base)
        self._canary = _c(
            "repro_serve_canary_total",
            "DriftGuard canary verdicts.",
            ("verdict",),
        )
        self._heals = _c(
            "repro_serve_heals_total",
            "DriftGuard heal attempts by outcome.",
            ("outcome",),
        )
        self._replica_flushes = _c(
            "repro_serve_replica_flushes_total",
            "Fast-path flushes per replica and shape bucket.",
            ("replica", "bucket"),
        )
        self._replica_failures = _c(
            "repro_serve_replica_failures_total",
            "Failed fast-path flushes per replica.",
            ("replica",),
        )
        self.queue_rows = g(
            "repro_serve_queue_rows", "Rows currently pending in the queue.", L
        ).labels(**base)
        self.validity_fraction = g(
            "repro_serve_validity_fraction",
            "Windowed fraction of fast-path rows inside the Eq 3.11 bound.",
            L,
        ).labels(**base)
        self.fallback_rate = g(
            "repro_serve_fallback_rate",
            "Windowed fraction of fast-path rows re-scored exactly.",
            L,
        ).labels(**base)
        self.step_time_ewma = g(
            "repro_serve_step_time_ewma_seconds",
            "EWMA of coalesced engine step wall time.",
            L,
        ).labels(**base)
        self._breaker_state = g(
            "repro_serve_breaker_state",
            "Per-replica breaker state (0=closed, 1=half_open, 2=open).",
            L + ("replica",),
        )
        self.latency = h(
            "repro_serve_request_latency_seconds",
            "End-to-end request latency (enqueue to materialize).",
            L,
        ).labels(**base)

    def canary(self, verdict: str):
        return self._canary.labels(**self.base, verdict=verdict)

    def heals(self, outcome: str):
        return self._heals.labels(**self.base, outcome=outcome)

    def replica_flushes(self, replica, bucket):
        return self._replica_flushes.labels(
            **self.base, replica=str(replica), bucket=str(bucket)
        )

    def replica_failures(self, replica):
        return self._replica_failures.labels(**self.base, replica=str(replica))

    def breaker_state(self, replica):
        return self._breaker_state.labels(**self.base, replica=str(replica))


class ModelTelemetry:
    """Counters + latency window for one served model (one digest)."""

    def __init__(self, window: int = DEFAULT_WINDOW,
                 validity_window: int = DEFAULT_VALIDITY_WINDOW):
        self.latency = LatencyWindow(window)
        self._lock = threading.Lock()
        self._requests = 0
        self._rows = 0
        self._flushes = 0
        self._deadline_flushes = 0        # flushed because max_wait_us expired
        self._queue_rows = 0              # rows currently pending
        self._max_queue_rows = 0
        # -- admission / deadline / failure accounting
        self._shed_requests = 0
        self._shed_rows = 0
        self._deadline_timeouts = 0
        self._batch_failures = 0
        self._failed_requests = 0
        self._failed_rows = 0
        self._tightened_waits = 0
        # -- circuit breaker / degraded serving
        self._breaker_state = "closed"
        self._breaker_trips = 0
        self._breaker_probes = 0
        self._degraded_flushes = 0
        self._degraded_requests = 0
        self._degraded_rows = 0
        self._breaker_shed_requests = 0
        # -- self-healing loop
        self._recompiles = 0
        self._canary_pass = 0
        self._canary_fail = 0
        self._heal_attempts = 0
        self._last_heal_trigger_at = None
        self._flipped_digests: list[str] = []
        self._heal_history = collections.deque(maxlen=HEAL_HISTORY)
        # -- terminal accounting (conservation: served + failed + expired
        #    + breaker_shed + closed == requests once drained)
        self._served_requests = 0
        self._served_rows = 0
        self._closed_requests = 0
        # -- EWMA engine step time (mirrored from the scheduler)
        self._step_time_ewma = None
        # -- drift signal: (rows, invalid_rows) per recent fast-path flush
        self._validity = collections.deque(maxlen=validity_window)
        # -- per-replica dispatch accounting (scale-out)
        self._replicas: dict[int, dict] = {}
        # -- typed-metrics projection (None until bind_obs)
        self._obs: _BoundMetrics | None = None

    def bind_obs(self, registry, labels: dict | None = None) -> None:
        """Mirror every future ``record_*`` onto typed instruments in
        ``registry`` (an ``obs.MetricsRegistry``), labelled by the given
        (model_digest, alias, family, dtype). Idempotent for the same
        registry; rebinding to a different registry replaces the mirror.
        """
        with self._lock:
            if self._obs is not None and self._obs.registry is registry:
                return
            self._obs = _BoundMetrics(registry, labels or {})

    # ------------------------------------------------------------- recording

    def record_enqueue(self, rows: int) -> None:
        with self._lock:
            self._requests += 1
            self._rows += rows
            self._queue_rows += rows
            self._max_queue_rows = max(self._max_queue_rows, self._queue_rows)
            depth = self._queue_rows
        m = self._obs
        if m is not None:
            m.requests.inc()
            m.rows.inc(rows)
            m.queue_rows.set(depth)

    def record_flush(self, requests: int, rows: int, *, deadline: bool,
                     tightened: bool = False) -> None:
        with self._lock:
            self._flushes += 1
            self._deadline_flushes += int(deadline)
            self._tightened_waits += int(tightened)
            self._queue_rows -= rows
            depth = self._queue_rows
        m = self._obs
        if m is not None:
            m.flushes.inc()
            m.queue_rows.set(depth)

    def record_latency(self, seconds: float) -> None:
        self.latency.record(seconds)
        m = self._obs
        if m is not None:
            m.latency.observe(seconds)

    def record_shed(self, rows: int) -> None:
        """Request rejected at admission (never entered the queue)."""
        with self._lock:
            self._shed_requests += 1
            self._shed_rows += rows
        m = self._obs
        if m is not None:
            m.shed.inc()

    def record_served(self, requests: int, rows: int) -> None:
        """Requests whose futures resolved with scores (fast OR degraded
        path) — the success leg of the conservation identity."""
        with self._lock:
            self._served_requests += requests
            self._served_rows += rows
        m = self._obs
        if m is not None:
            m.served.inc(requests)
            m.served_rows.inc(rows)

    def record_closed(self, requests: int, rows: int = 0) -> None:
        """Admitted requests failed because the batcher shut down."""
        with self._lock:
            self._closed_requests += requests
            self._queue_rows -= rows
            depth = self._queue_rows
        m = self._obs
        if m is not None:
            m.closed.inc(requests)
            m.queue_rows.set(depth)

    def record_step_time(self, seconds: float) -> None:
        """Mirror the scheduler's EWMA engine-step time estimate."""
        with self._lock:
            self._step_time_ewma = float(seconds)
        m = self._obs
        if m is not None:
            m.step_time_ewma.set(seconds)

    def record_deadline_timeout(self, requests: int = 1, rows: int = 0) -> None:
        """Admitted requests expired while queued (left without a flush)."""
        with self._lock:
            self._deadline_timeouts += requests
            self._queue_rows -= rows
            depth = self._queue_rows
        m = self._obs
        if m is not None:
            m.expired.inc(requests)
            m.queue_rows.set(depth)

    def record_batch_failure(self, requests: int, rows: int) -> None:
        """One engine step failed; its futures got the exception."""
        with self._lock:
            self._batch_failures += 1
            self._failed_requests += requests
            self._failed_rows += rows
        m = self._obs
        if m is not None:
            m.batch_failures.inc()
            m.failed.inc(requests)

    def _replica_locked(self, index: int) -> dict:
        return self._replicas.setdefault(int(index), {
            "flushes": 0,
            "requests": 0,
            "rows": 0,
            "failures": 0,
            "breaker_state": "closed",
            "trips": 0,
            "probes": 0,
        })

    def record_replica_flush(self, index: int, requests: int, rows: int,
                             bucket: int | None = None) -> None:
        """One fast-path flush served by replica ``index`` (``bucket`` is
        the padded shape bucket it dispatched into, when known)."""
        with self._lock:
            c = self._replica_locked(index)
            c["flushes"] += 1
            c["requests"] += requests
            c["rows"] += rows
        m = self._obs
        if m is not None:
            m.replica_flushes(index, bucket if bucket is not None else "").inc()

    def record_replica_failure(self, index: int) -> None:
        """One fast-path flush FAILED on replica ``index``."""
        with self._lock:
            self._replica_locked(index)["failures"] += 1
        m = self._obs
        if m is not None:
            m.replica_failures(index).inc()

    def record_breaker_state(self, state: str, *, tripped: bool = False,
                             probe: bool = False, replica: int = 0) -> None:
        with self._lock:
            # model-level state keeps its pre-replica meaning: the most
            # recent transition anywhere (exact for a single replica)
            self._breaker_state = state
            self._breaker_trips += int(tripped)
            self._breaker_probes += int(probe)
            c = self._replica_locked(replica)
            c["breaker_state"] = state
            c["trips"] += int(tripped)
            c["probes"] += int(probe)
        m = self._obs
        if m is not None:
            m.breaker_state(replica).set(BREAKER_STATE_VALUES.get(state, -1))

    def record_degraded(self, requests: int, rows: int) -> None:
        """One flush served by the exact path under an open breaker."""
        with self._lock:
            self._degraded_flushes += 1
            self._degraded_requests += requests
            self._degraded_rows += rows
        m = self._obs
        if m is not None:
            m.degraded.inc(requests)

    def record_breaker_shed(self, requests: int = 1) -> None:
        with self._lock:
            self._breaker_shed_requests += requests
        m = self._obs
        if m is not None:
            m.breaker_shed.inc(requests)

    def record_recompile(self) -> None:
        with self._lock:
            self._recompiles += 1
        m = self._obs
        if m is not None:
            m.recompiles.inc()

    def record_canary(self, passed: bool) -> None:
        with self._lock:
            if passed:
                self._canary_pass += 1
            else:
                self._canary_fail += 1
        m = self._obs
        if m is not None:
            m.canary("pass" if passed else "fail").inc()

    def record_heal(self, *, trigger_at: float, healed: bool,
                    old_digest: str = "", new_digest: str = "",
                    detail: dict | None = None, mirror: bool = False) -> None:
        """One DriftGuard heal attempt (trigger through verdict).

        ``trigger_at`` comes from the guard's injected clock, so tests
        with a fake clock see deterministic history timestamps.
        ``mirror=True`` marks the copy the guard writes onto the flipped-
        to digest's telemetry: it lands in the snapshot history but not
        the heals counter, so the process-wide metric counts each heal
        once.
        """
        with self._lock:
            self._heal_attempts += 1
            self._last_heal_trigger_at = float(trigger_at)
            entry = {
                "trigger_at": float(trigger_at),
                "healed": bool(healed),
                "old_digest": old_digest,
                "new_digest": new_digest,
            }
            if detail:
                entry.update(detail)
            self._heal_history.append(entry)
            if healed and new_digest:
                self._flipped_digests.append(new_digest)
                del self._flipped_digests[:-HEAL_HISTORY]
        m = self._obs
        if m is not None and not mirror:
            m.heals("healed" if healed else "failed").inc()

    def record_validity(self, rows: int, invalid: int) -> None:
        """Per-row validity of one FAST-PATH flush (drift window input).

        Degraded (breaker-open) flushes must NOT be recorded here: their
        rows are exact-served by construction and would read as 100%
        fallback, turning an engine fault into a phantom drift signal.
        """
        if rows <= 0:
            return
        with self._lock:
            self._validity.append((int(rows), int(invalid)))
            w_rows = sum(r for r, _ in self._validity)
            w_invalid = sum(i for _, i in self._validity)
        m = self._obs
        if m is not None and w_rows:
            rate = w_invalid / w_rows
            m.fallback_rate.set(rate)
            m.validity_fraction.set(1.0 - rate)

    def fallback_window(self) -> dict:
        """Recent-traffic fallback rate — the ``DriftGuard`` signal."""
        with self._lock:
            rows = sum(r for r, _ in self._validity)
            invalid = sum(i for _, i in self._validity)
        return {
            "rows": rows,
            "invalid": invalid,
            "rate": invalid / rows if rows else 0.0,
        }

    def reset_fallback_window(self) -> None:
        with self._lock:
            self._validity.clear()

    # -------------------------------------------------------------- exporting

    def snapshot(self, engine=None) -> dict:
        with self._lock:
            out = {
                "requests": self._requests,
                "rows": self._rows,
                "flushes": self._flushes,
                "deadline_flushes": self._deadline_flushes,
                "queue_rows": self._queue_rows,
                "max_queue_rows": self._max_queue_rows,
                "coalescing_factor": round(
                    self._requests / max(1, self._flushes), 3
                ),
                "rows_per_flush": round(self._rows / max(1, self._flushes), 2),
                "shed_requests": self._shed_requests,
                "shed_rows": self._shed_rows,
                "served_requests": self._served_requests,
                "served_rows": self._served_rows,
                "closed_requests": self._closed_requests,
                "deadline_timeouts": self._deadline_timeouts,
                "batch_failures": self._batch_failures,
                "failed_requests": self._failed_requests,
                "failed_rows": self._failed_rows,
                "tightened_waits": self._tightened_waits,
                "step_time_ewma_s": self._step_time_ewma,
                "breaker": {
                    "state": self._breaker_state,
                    "trips": self._breaker_trips,
                    "probes": self._breaker_probes,
                    "degraded_flushes": self._degraded_flushes,
                    "degraded_requests": self._degraded_requests,
                    "degraded_rows": self._degraded_rows,
                    "shed_requests": self._breaker_shed_requests,
                },
                "canary": {
                    "recompiles": self._recompiles,
                    "passed": self._canary_pass,
                    "failed": self._canary_fail,
                },
                "heals": {
                    "attempts": self._heal_attempts,
                    "last_trigger_at": self._last_heal_trigger_at,
                    "flipped_digests": list(self._flipped_digests),
                    "history": list(self._heal_history),
                },
                "replicas": {
                    str(i): dict(c)
                    for i, c in sorted(self._replicas.items())
                },
            }
        out["fallback_window"] = self.fallback_window()
        out["latency"] = self.latency.snapshot()
        if engine is not None:
            eng = engine.stats.snapshot()
            out["engine"] = eng
            out["fallback_rate"] = eng["fallback_rate"]
            out["compiled_steps"] = eng["compiled_steps"]
        return out
