"""``MicroBatcher`` — async request coalescing in front of one ``SVMEngine``.

The engine's fast path is a fixed-shape fused step over a power-of-two
shape bucket; a single-row request therefore pays for a whole
``min_bucket``-row step. Under concurrent traffic that cost is shared:
the batcher queues small requests per model and flushes them as ONE
engine submit — the rows land in the same padded bucket one request
would have paid for alone, so N coalesced requests cost ~1/N each.

Scheduling is queue + deadline, the classic micro-batching rule:

  * **bucket fills** — pending rows reach ``flush_rows`` (a bucket
    boundary of the engine, default ``min_bucket``): flush immediately,
    the step's padding waste is zero at that point;
  * **deadline expires** — the OLDEST queued request has waited
    ``max_wait_us``: flush whatever is pending. A lone request on an
    idle model therefore sees at most ``max_wait_us`` of added latency,
    and heavy traffic never waits at all (the bucket fills first).

Everything the engine guarantees survives coalescing:

  * **zero steady-state recompiles** — the concatenated rows go through
    ``engine.submit``'s existing bucket padding, so the flush hits the
    same bounded set of compiled variants (asserted in the throughput
    benchmark via ``jit_cache_size`` before/after);
  * **deferred sync** — the flush thread never blocks on device compute:
    futures resolve with ``SliceResult`` views of the shared
    ``EngineResult`` the moment the submit returns, and the one
    device→host sync happens when the FIRST client materializes (the
    engine's materialize lock makes that race safe);
  * **per-request row order** — ``EngineResult.split`` carves the
    coalesced result at the original request boundaries, so each caller
    sees its rows in the order it sent them, including rows the engine
    patched through the exact fallback path.
"""

from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serve.runtime.telemetry import ModelTelemetry

DEFAULT_MAX_WAIT_US = 200.0


class BatcherClosed(RuntimeError):
    """Raised by ``submit`` on a closed batcher (e.g. retired after an
    engine reload); ``Runtime`` re-resolves and retries on a fresh one."""


class _EmptyResult:
    """Zero-row result with the engine's output shapes; no device step."""

    def __init__(self, engine):
        k = engine.num_heads
        self.values = (np.zeros((0, k), np.float32) if engine.multiclass
                       else np.zeros((0,), np.float32))
        self.valid = np.zeros((0,), bool)
        self.labels = np.zeros((0,), np.int32)

    def __len__(self) -> int:
        return 0

    def block_until_ready(self):
        return self


class _Pending:
    __slots__ = ("Z", "future", "t_enqueue")

    def __init__(self, Z: np.ndarray, future: Future, t_enqueue: float):
        self.Z = Z
        self.future = future
        self.t_enqueue = t_enqueue


class MicroBatcher:
    """Coalesce concurrent ``submit`` calls into bucket-sized engine steps.

    ``submit(Z) -> Future[SliceResult]``: the future resolves as soon as
    the coalesced engine step is ENQUEUED on the device (deferred sync);
    materializing the result's ``.values`` / ``.labels`` / ``.valid``
    performs the one host transfer, shared with every sibling request.
    """

    def __init__(
        self,
        engine,
        *,
        max_wait_us: float = DEFAULT_MAX_WAIT_US,
        flush_rows: int | None = None,
        telemetry: ModelTelemetry | None = None,
        name: str = "model",
    ):
        if flush_rows is None:
            flush_rows = engine.min_bucket
        if flush_rows < 1 or flush_rows > engine.max_batch:
            raise ValueError(
                f"flush_rows must be in [1, {engine.max_batch}], got {flush_rows}"
            )
        self.engine = engine
        self.max_wait_s = max_wait_us * 1e-6
        self.flush_rows = flush_rows
        self.telemetry = telemetry if telemetry is not None else ModelTelemetry()
        self.name = name
        self._queue: collections.deque[_Pending] = collections.deque()
        self._queued_rows = 0
        self._cond = threading.Condition()
        self._closed = False
        self._worker = threading.Thread(
            target=self._run, name=f"microbatch-{name}", daemon=True
        )
        self._worker.start()

    # ---------------------------------------------------------------- client

    def submit(self, Z) -> Future:
        """Enqueue one request; returns a future of its ``SliceResult``."""
        Z = np.asarray(Z, dtype=np.float32)
        if Z.ndim == 1:
            Z = Z[None, :]
        if Z.ndim != 2 or Z.shape[1] != self.engine.d:
            raise ValueError(
                f"expected (n, {self.engine.d}) batch, got {Z.shape}"
            )
        fut: Future = Future()
        if Z.shape[0] == 0:                       # nothing to coalesce
            with self._cond:
                if self._closed:
                    raise BatcherClosed(f"MicroBatcher({self.name!r}) is closed")
            fut.set_result(_EmptyResult(self.engine))
            return fut
        item = _Pending(Z, fut, time.perf_counter())
        with self._cond:
            if self._closed:
                raise BatcherClosed(f"MicroBatcher({self.name!r}) is closed")
            self._queue.append(item)
            self._queued_rows += Z.shape[0]
            self.telemetry.record_enqueue(Z.shape[0])
            self._cond.notify()
        return fut

    def flush(self) -> None:
        """Drain the queue synchronously (tests, shutdown)."""
        with self._cond:
            batch = self._drain_locked()
        if batch:
            self._execute(batch, deadline=False)

    def close(self) -> None:
        """Stop the flush thread; pending requests are flushed first."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._worker.join(timeout=5.0)
        self.flush()                               # anything enqueued at the wire

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ---------------------------------------------------------------- worker

    def _drain_locked(self) -> list[_Pending]:
        batch = list(self._queue)
        self._queue.clear()
        self._queued_rows = 0
        return batch

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if self._closed:
                    batch = self._drain_locked()
                    deadline_hit = False
                elif self._queued_rows >= self.flush_rows:
                    batch, deadline_hit = self._drain_locked(), False
                else:
                    oldest = self._queue[0].t_enqueue
                    remaining = oldest + self.max_wait_s - time.perf_counter()
                    if remaining > 0:
                        self._cond.wait(timeout=remaining)
                        continue                   # re-evaluate both conditions
                    batch, deadline_hit = self._drain_locked(), True
            if batch:
                self._execute(batch, deadline=deadline_hit)
            if self._closed and not batch:
                return

    def _execute(self, batch: list[_Pending], *, deadline: bool) -> None:
        sizes = [p.Z.shape[0] for p in batch]
        rows = int(sum(sizes))
        try:
            Z = np.concatenate([p.Z for p in batch], axis=0)
            result = self.engine.submit(Z)
            # e2e latency closes when the SHARED result first materializes
            # (one sample per coalesced request, recorded by whichever
            # client thread syncs first).
            enqueued = [p.t_enqueue for p in batch]
            telemetry = self.telemetry

            def _on_materialize(ts=enqueued, tel=telemetry):
                done = time.perf_counter()
                for t0 in ts:
                    tel.record_latency(done - t0)

            result.on_materialize = _on_materialize
            slices = result.split(sizes)
        except BaseException as e:                 # scatter the failure too
            self.telemetry.record_flush(len(batch), rows, deadline=deadline)
            for p in batch:
                if p.future.set_running_or_notify_cancel():
                    p.future.set_exception(e)
            return
        self.telemetry.record_flush(len(batch), rows, deadline=deadline)
        for p, s in zip(batch, slices):
            # a client may have cancelled while queued; a cancelled future
            # must not take the whole flush worker down with it
            if p.future.set_running_or_notify_cancel():
                p.future.set_result(s)
