"""``MicroBatcher`` — async request coalescing in front of one ``SVMEngine``.

The engine's fast path is a fixed-shape fused step over a power-of-two
shape bucket; a single-row request therefore pays for a whole
``min_bucket``-row step. Under concurrent traffic that cost is shared:
the batcher queues small requests per model and flushes them as ONE
engine submit — the rows land in the same padded bucket one request
would have paid for alone, so N coalesced requests cost ~1/N each.

Scheduling is queue + deadline, the classic micro-batching rule:

  * **bucket fills** — pending rows reach ``flush_rows`` (a bucket
    boundary of the engine, default ``min_bucket``): flush immediately,
    the step's padding waste is zero at that point;
  * **deadline expires** — the OLDEST queued request has waited
    ``max_wait_us``: flush whatever is pending. A lone request on an
    idle model therefore sees at most ``max_wait_us`` of added latency,
    and heavy traffic never waits at all (the bucket fills first).

Robustness layer (overload, faults, graceful degradation):

  * **admission control** — ``max_queue_rows`` bounds the queue: a
    submit that would grow the queue past the bound is SHED with a
    typed ``RuntimeOverloaded`` carrying ``retry_after_s`` (estimated
    from the measured per-step service time), instead of queueing
    unboundedly. The queue is a shock absorber, not a reservoir: under
    sustained overload, bounded depth means bounded latency for every
    request that IS admitted.
  * **per-submit deadlines** — ``submit(Z, deadline_s=...)`` fails the
    future with ``DeadlineExceeded`` if the request cannot reach a
    flush in time (checked both while queued and again at flush
    assembly, so a slow engine step ahead of it cannot sneak an expired
    request into a batch).
  * **SLO-aware wait tightening** — under queue pressure the effective
    ``max_wait_us`` shrinks proportionally to queue fullness (floored
    at 10%): a loaded batcher stops trading latency for coalescing it
    is already getting for free.
  * **fault isolation** — an exception from the engine step fails ONLY
    that batch's futures; the flush worker survives and keeps serving.
    Repeated consecutive failures trip a per-model ``CircuitBreaker``:
    while open, traffic degrades to the exact streaming ``rbf_pred``
    path (``engine.submit_exact``) if an exact model was published, or
    is shed with ``RuntimeOverloaded`` if not. After ``reset_after_s``
    the breaker half-opens and sends ONE probe batch down the fast
    path: success closes it, failure re-opens it.
  * **no hung futures** — ``close()`` flushes what it can and resolves
    anything left with ``BatcherClosed``; a crashed worker resolves the
    queue exceptionally on the way out. Every admitted future
    terminates, exactly once.

Scale-out layer (``engines=[...]``, PR 7): the batcher can front N
REPLICA engines built from the same digest (content addressing makes
them interchangeable — same artifact bytes, same compiled step). One
coalescing queue feeds a least-loaded dispatcher: each flush routes to
the admitted replica with the fewest in-flight rows, round-robin among
ties. Every replica carries its OWN circuit breaker, so a faulting
device degrades only itself — flushes simply stop selecting it while
its siblings keep the fast path, and the half-open probe window re-
admits it replica-by-replica. Only when EVERY replica refuses the fast
path does the batcher fall back to the degraded exact path (or shed).
With more than one replica each gets a dedicated dispatch thread:
host-side padding + device dispatch for replica i never head-of-line
blocks replica j, which is what turns N devices into ~N× throughput.
Flushes are capped at the engine's ``max_batch`` rows (the engine's
own chunking unit), so a deep queue SPREADS across replicas instead of
riding one replica as a single mega-flush the engine would chunk
serially.
With a single replica (the default) dispatch stays inline on the flush
thread — byte-identical behavior to the pre-replica batcher.

Everything the engine guarantees survives coalescing:

  * **zero steady-state recompiles** — the concatenated rows go through
    ``engine.submit``'s existing bucket padding, so the flush hits the
    same bounded set of compiled variants (asserted in the throughput
    benchmark via ``jit_cache_size`` before/after);
  * **deferred sync** — the flush thread never blocks on device compute:
    futures resolve with ``SliceResult`` views of the shared
    ``EngineResult`` the moment the submit returns, and the one
    device→host sync happens when the FIRST client materializes (the
    engine's materialize lock makes that race safe);
  * **per-request row order** — ``EngineResult.split`` carves the
    coalesced result at the original request boundaries, so each caller
    sees its rows in the order it sent them, including rows the engine
    patched through the exact fallback path.
"""

from __future__ import annotations

import collections
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serve.runtime.errors import (
    BatcherClosed,
    DeadlineExceeded,
    RuntimeOverloaded,
)
from repro.serve.runtime.faults import ENGINE_STEP, FaultInjector
from repro.serve.runtime.telemetry import ModelTelemetry

DEFAULT_MAX_WAIT_US = 200.0

# SLO tightening floor: a fully-pressured queue still waits 10% of
# max_wait_us (zero would busy-spin the flush thread on a trickle).
MIN_WAIT_FRACTION = 0.1

# A flush counts as "tightened" in telemetry only when pressure cut the
# wait by more than 10% — any non-empty queue shortens it a little, and
# counting that would make the counter fire on every deadline flush.
TIGHTENED_BELOW = 0.9


class CircuitBreaker:
    """Per-model circuit over the engine fast path.

    closed --[``fail_threshold`` consecutive step failures]--> open
    open   --[``reset_after_s`` elapsed]--> half_open (one probe batch)
    half_open --[probe succeeds]--> closed / --[probe fails]--> open

    Internally locked: with replica dispatch threads, ``allow_fast``
    (flush thread) and ``record_*`` (the replica's dispatch thread) may
    race; ``state`` reads from other threads stay single attribute loads.
    """

    def __init__(self, *, fail_threshold: int = 3, reset_after_s: float = 0.25,
                 clock=time.monotonic):
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        self.fail_threshold = int(fail_threshold)
        self.reset_after_s = float(reset_after_s)
        self._clock = clock
        self.state = "closed"
        self.consecutive_failures = 0
        self._opened_at = 0.0
        self._lock = threading.Lock()

    def clone(self) -> "CircuitBreaker":
        """A fresh breaker with this one's configuration (per-replica)."""
        return CircuitBreaker(fail_threshold=self.fail_threshold,
                              reset_after_s=self.reset_after_s,
                              clock=self._clock)

    def allow_fast(self) -> bool:
        """May the next batch use the fast path? Transitions open →
        half_open when the probe window arrives (that batch IS the probe)."""
        with self._lock:
            if self.state == "open":
                if self._clock() - self._opened_at >= self.reset_after_s:
                    self.state = "half_open"
                    return True
                return False
            return True                 # closed, or half_open (another probe)

    def record_success(self) -> None:
        with self._lock:
            self.consecutive_failures = 0
            self.state = "closed"

    def record_failure(self) -> None:
        with self._lock:
            self.consecutive_failures += 1
            if self.state == "half_open" or \
                    self.consecutive_failures >= self.fail_threshold:
                self.state = "open"
                self._opened_at = self._clock()

    def retry_after(self) -> float:
        """Time until the breaker would next admit a probe (0 if not open)."""
        with self._lock:
            if self.state != "open":
                return 0.0
            return max(
                0.0, self.reset_after_s - (self._clock() - self._opened_at)
            )

    def snapshot(self) -> dict:
        return {
            "state": self.state,
            "consecutive_failures": self.consecutive_failures,
            "fail_threshold": self.fail_threshold,
            "reset_after_s": self.reset_after_s,
        }


def _resolve_breaker(breaker) -> CircuitBreaker | None:
    """True → default breaker; dict → kwargs; instance → itself; falsy → off."""
    if breaker is True:
        return CircuitBreaker()
    if isinstance(breaker, dict):
        return CircuitBreaker(**breaker)
    if isinstance(breaker, CircuitBreaker) or breaker is None or breaker is False:
        return breaker or None
    raise TypeError(f"breaker must be bool, dict or CircuitBreaker, got {breaker!r}")


class _Replica:
    """One engine instance behind the batcher (usually one device).

    Owns its breaker (a faulting replica degrades only itself) and —
    when the batcher runs more than one replica — a dedicated dispatch
    thread, so padding + device dispatch for one replica never blocks
    its siblings. ``inflight_rows`` (guarded by the batcher's
    accounting lock) counts rows dispatched but not yet materialized or
    failed; it is the least-loaded dispatch signal.
    """

    __slots__ = ("index", "engine", "breaker", "inflight_rows", "flushes",
                 "rows", "failures", "last_state", "jobs", "thread")

    def __init__(self, index: int, engine, breaker: CircuitBreaker | None):
        self.index = index
        self.engine = engine
        self.breaker = breaker
        self.inflight_rows = 0
        self.flushes = 0
        self.rows = 0
        self.failures = 0
        self.last_state = "closed"
        self.jobs: queue.SimpleQueue | None = None   # set when threaded
        self.thread: threading.Thread | None = None


class _EmptyResult:
    """Zero-row result with the engine's output shapes; no device step."""

    def __init__(self, engine):
        k = engine.num_heads
        self.values = (np.zeros((0, k), np.float32) if engine.multiclass
                       else np.zeros((0,), np.float32))
        self.valid = np.zeros((0,), bool)
        self.labels = np.zeros((0,), np.int32)

    def __len__(self) -> int:
        return 0

    def block_until_ready(self):
        return self


class _Pending:
    __slots__ = ("Z", "future", "t_enqueue", "deadline", "trace")

    def __init__(self, Z: np.ndarray, future: Future, t_enqueue: float,
                 deadline: float | None = None, trace: str | None = None):
        self.Z = Z
        self.future = future
        self.t_enqueue = t_enqueue
        self.deadline = deadline          # absolute perf_counter time, or None
        self.trace = trace                # obs trace id linking this
                                          # request's lifecycle spans


class MicroBatcher:
    """Coalesce concurrent ``submit`` calls into bucket-sized engine steps.

    ``submit(Z) -> Future[SliceResult]``: the future resolves as soon as
    the coalesced engine step is ENQUEUED on the device (deferred sync);
    materializing the result's ``.values`` / ``.labels`` / ``.valid``
    performs the one host transfer, shared with every sibling request.

    Robustness knobs (all optional; defaults preserve PR-4 behavior
    except the breaker, which is on and inert until steps actually fail):

      * ``max_queue_rows`` — admission bound; ``None`` = unbounded.
      * ``breaker`` — ``True`` (default config), ``False``/``None``
        (off), a kwargs dict, or a ``CircuitBreaker``.
      * ``fault_injector`` — a ``faults.FaultInjector`` consulted at the
        ``engine_step`` site before every fast-path flush (chaos tests).
      * ``engines`` — replica engines for the same digest
        (``engines[0]`` must be ``engine``); flushes spread over them
        least-loaded, each behind its own breaker clone.
      * ``tracer`` — an ``obs.Tracer``; when given, every request's
        lifecycle (admission → queue wait → dispatch → engine step →
        scatter → sync, plus shed/expired/failed/closed verdicts and
        breaker transitions) is recorded as linked spans under this
        batcher's ``name``.
    """

    def __init__(
        self,
        engine,
        *,
        max_wait_us: float = DEFAULT_MAX_WAIT_US,
        flush_rows: int | None = None,
        telemetry: ModelTelemetry | None = None,
        name: str = "model",
        max_queue_rows: int | None = None,
        breaker=True,
        fault_injector: FaultInjector | None = None,
        engines: list | None = None,
        tracer=None,
    ):
        engs = [engine] if engines is None else list(engines)
        if not engs or engs[0] is not engine:
            raise ValueError("engines[0] must be the primary engine")
        if flush_rows is None:
            flush_rows = engine.min_bucket
        if flush_rows < 1 or flush_rows > engine.max_batch:
            raise ValueError(
                f"flush_rows must be in [1, {engine.max_batch}], got {flush_rows}"
            )
        if max_queue_rows is not None and max_queue_rows < flush_rows:
            raise ValueError(
                f"max_queue_rows ({max_queue_rows}) must be >= flush_rows "
                f"({flush_rows}) or admission would starve every flush"
            )
        self.engine = engine
        self.max_wait_s = max_wait_us * 1e-6
        self.flush_rows = flush_rows
        self.max_queue_rows = max_queue_rows
        self.telemetry = telemetry if telemetry is not None else ModelTelemetry()
        self.name = name
        # replica 0 keeps the caller-supplied breaker (and the public
        # ``self.breaker`` back-compat handle); siblings get fresh clones
        # of the same config so one replica's failures never bleed into
        # another's consecutive-failure count
        primary = _resolve_breaker(breaker)
        self.breaker = primary
        self.replicas = [
            _Replica(i, eng, primary if i == 0
                     else (primary.clone() if primary is not None else None))
            for i, eng in enumerate(engs)
        ]
        self.faults = fault_injector
        # surface every replica's breaker gauge from birth (closed == 0)
        # rather than waiting for a first transition to materialize it
        for r in self.replicas:
            if r.breaker is not None:
                self.telemetry.record_breaker_state("closed", replica=r.index)
        # obs.Tracer (or None): every admitted request gets a trace id at
        # submit; lifecycle spans (queue wait, dispatch, engine step,
        # scatter, sync, verdicts) link to it. Span recording is a dict
        # append under one lock — cheap enough for the hot path.
        self._tracer = tracer
        self._cfg_strs: dict[int, str] = {}
        self._step_time_s = self.max_wait_s or 1e-4   # EWMA of measured steps
        self._queue: collections.deque[_Pending] = collections.deque()
        self._queued_rows = 0
        self._cond = threading.Condition()
        self._acct = threading.Lock()     # replica inflight/counter guard
        self._rr = 0                      # round-robin tiebreak cursor
        self._closed = False
        if len(self.replicas) > 1:
            for r in self.replicas:
                r.jobs = queue.SimpleQueue()
                r.thread = threading.Thread(
                    target=self._replica_run, args=(r,),
                    name=f"microbatch-{name}-r{r.index}", daemon=True,
                )
                r.thread.start()
        self._worker = threading.Thread(
            target=self._run, name=f"microbatch-{name}", daemon=True
        )
        self._worker.start()

    # ---------------------------------------------------------------- client

    def submit(self, Z, *, deadline_s: float | None = None) -> Future:
        """Enqueue one request; returns a future of its ``SliceResult``.

        Raises ``RuntimeOverloaded`` (typed, with ``retry_after_s``) when
        the bounded queue is full, ``BatcherClosed`` after ``close()``.
        With ``deadline_s`` the future fails with ``DeadlineExceeded``
        if the request cannot be flushed within that many seconds of
        submission.
        """
        Z = np.asarray(Z, dtype=np.float32)
        if Z.ndim == 1:
            Z = Z[None, :]
        if Z.ndim != 2 or Z.shape[1] != self.engine.d:
            raise ValueError(
                f"expected (n, {self.engine.d}) batch, got {Z.shape}"
            )
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, got {deadline_s}")
        fut: Future = Future()
        if Z.shape[0] == 0:                       # nothing to coalesce
            with self._cond:
                if self._closed:
                    raise BatcherClosed(f"MicroBatcher({self.name!r}) is closed")
            fut.set_result(_EmptyResult(self.engine))
            return fut
        now = time.perf_counter()
        tr = self._tracer
        item = _Pending(Z, fut, now,
                        None if deadline_s is None else now + deadline_s,
                        trace=tr.new_trace() if tr is not None else None)
        with self._cond:
            if self._closed:
                raise BatcherClosed(f"MicroBatcher({self.name!r}) is closed")
            rows = Z.shape[0]
            if (self.max_queue_rows is not None
                    and self._queued_rows > 0
                    and self._queued_rows + rows > self.max_queue_rows):
                # shed BEFORE enqueueing (the queue is the bound); an
                # empty queue always admits so a single request larger
                # than the bound is still servable (the engine chunks it)
                self.telemetry.record_shed(rows)
                retry = self._retry_after_locked()
                self._span("request.shed", trace_id=item.trace,
                           attrs={"rows": rows, "retry_after_s": retry})
                raise RuntimeOverloaded(
                    f"model {self.name!r}: queue full "
                    f"({self._queued_rows}/{self.max_queue_rows} rows)",
                    retry_after_s=retry,
                )
            self._queue.append(item)
            self._queued_rows += rows
            self.telemetry.record_enqueue(rows)
            self._span("request.admitted", trace_id=item.trace,
                       t_start=now, attrs={
                           "rows": rows,
                           "deadline": item.deadline is not None,
                       })
            self._cond.notify()
        return fut

    def _span(self, name: str, **kw) -> str | None:
        """Record one span under this batcher's model key (no-op untraced)."""
        tr = self._tracer
        if tr is None:
            return None
        return tr.span(self.name, name, **kw)

    def _retry_after_locked(self) -> float:
        """Expected time for the current queue to drain: queued flushes ×
        the EWMA of measured step time (+ one flush wait)."""
        flushes = max(1.0, self._queued_rows / self.flush_rows)
        return flushes * self._step_time_s + self.max_wait_s

    def flush(self) -> None:
        """Drain the queue synchronously (tests, shutdown)."""
        with self._cond:
            batch = self._drain_locked()
        if batch:
            self._execute(batch, deadline=False)

    def close(self) -> None:
        """Stop the flush thread; every pending future RESOLVES.

        Requests already queued are flushed (served or failed by the
        engine's verdict); anything left after the worker exits — e.g. a
        worker that died, or raced past the drain — is failed with
        ``BatcherClosed``. A caller blocked on ``future.result()`` is
        never left hanging.
        """
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._worker.join(timeout=5.0)
        self.flush()                               # anything enqueued at the wire
        for r in self.replicas:                    # drain replica dispatchers:
            if r.jobs is not None:                 # the sentinel queues BEHIND
                r.jobs.put(None)                   # any still-pending flushes
        for r in self.replicas:
            if r.thread is not None:
                r.thread.join(timeout=5.0)
        with self._cond:                           # belt and braces: no future
            leftovers = self._drain_locked()       # survives close unresolved
        if leftovers:
            self.telemetry.record_closed(
                len(leftovers), sum(p.Z.shape[0] for p in leftovers)
            )
        self._fail_batch(leftovers,
                         BatcherClosed(f"MicroBatcher({self.name!r}) is closed"),
                         verdict="closed")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ---------------------------------------------------------------- worker

    def _drain_locked(self, limit: int | None = None) -> list[_Pending]:
        """Pop queued requests: all of them, or whole requests up to
        ``limit`` rows (always at least one — a single oversized request
        still flushes; the engine chunks it internally)."""
        if limit is None:
            batch = list(self._queue)
            self._queue.clear()
            self._queued_rows = 0
            return batch
        batch, rows = [], 0
        while self._queue:
            r = self._queue[0].Z.shape[0]
            if batch and rows + r > limit:
                break
            batch.append(self._queue.popleft())
            rows += r
        self._queued_rows -= rows
        return batch

    def _flush_limit(self) -> int:
        """Max rows per flush: the engine's ``max_batch``.

        The engine chunks anything larger into sequential ``max_batch``
        steps anyway, so an unbounded flush is one giant serialized
        submit — under replicas it would ride ONE replica while its
        siblings idle. Capping the flush at the engine's own compute
        unit keeps dispatch and compute granularity aligned and lets
        the least-loaded dispatcher spread a deep queue."""
        return self.engine.max_batch

    def _pop_expired_locked(self, now: float) -> list[_Pending]:
        """Remove queued items whose deadline has passed; returns them."""
        if not any(p.deadline is not None for p in self._queue):
            return []
        live, expired = [], []
        for p in self._queue:
            (expired if p.deadline is not None and now >= p.deadline
             else live).append(p)
        if expired:
            self._queue = collections.deque(live)
            self._queued_rows = sum(p.Z.shape[0] for p in live)
        return expired

    def _effective_wait_locked(self) -> float:
        """``max_wait_s`` tightened by queue pressure (SLO-aware): a
        batcher at 60% of its admission bound only waits 40% as long."""
        if self.max_queue_rows is None:
            return self.max_wait_s
        pressure = self._queued_rows / self.max_queue_rows
        return self.max_wait_s * min(1.0, max(MIN_WAIT_FRACTION, 1.0 - pressure))

    def _run(self) -> None:
        try:
            while True:
                expired = None
                with self._cond:
                    while not self._queue and not self._closed:
                        self._cond.wait()
                    if self._closed:
                        batch, deadline_hit, tightened = \
                            self._drain_locked(), False, False
                    elif self._queued_rows >= self.flush_rows:
                        batch, deadline_hit, tightened = \
                            self._drain_locked(self._flush_limit()), False, False
                    else:
                        now = time.perf_counter()
                        expired = self._pop_expired_locked(now)
                        batch = None
                        if not expired:
                            wait_s = self._effective_wait_locked()
                            wake = self._queue[0].t_enqueue + wait_s
                            dls = [p.deadline for p in self._queue
                                   if p.deadline is not None]
                            if dls:
                                wake = min(wake, min(dls))
                            remaining = wake - now
                            if remaining > 0:
                                self._cond.wait(timeout=remaining)
                                continue                   # re-evaluate
                            batch, deadline_hit = \
                                self._drain_locked(self._flush_limit()), True
                            tightened = wait_s < self.max_wait_s * TIGHTENED_BELOW
                if expired:
                    self._fail_expired(expired)
                    continue
                if batch:
                    self._execute(batch, deadline=deadline_hit,
                                  tightened=tightened)
                if self._closed and not batch:
                    return
        finally:
            # the worker exits via close() or a crash; either way nothing
            # may be left in the queue to hang a caller forever
            with self._cond:
                self._closed = True
                leftovers = self._drain_locked()
            if leftovers:
                self.telemetry.record_closed(
                    len(leftovers), sum(p.Z.shape[0] for p in leftovers)
                )
            self._fail_batch(
                leftovers,
                BatcherClosed(f"MicroBatcher({self.name!r}) worker exited"),
                verdict="closed",
            )

    # -------------------------------------------------------------- execution

    def _fail_batch(self, batch: list[_Pending], exc: BaseException,
                    verdict: str | None = "failed",
                    attrs: dict | None = None) -> None:
        # ``verdict`` names the terminal span ("failed" / "closed");
        # None means the caller already recorded its own verdict spans
        for p in batch:
            if verdict is not None:
                span_attrs = {"rows": p.Z.shape[0], "error": type(exc).__name__}
                if attrs:
                    span_attrs.update(attrs)
                self._span(f"request.{verdict}", trace_id=p.trace,
                           t_start=p.t_enqueue, attrs=span_attrs)
            # a client may have cancelled while queued; a cancelled future
            # must not take the whole flush worker down with it
            if p.future.set_running_or_notify_cancel():
                p.future.set_exception(exc)

    def _fail_expired(self, expired: list[_Pending]) -> None:
        rows = sum(p.Z.shape[0] for p in expired)
        self.telemetry.record_deadline_timeout(len(expired), rows)
        now = time.perf_counter()
        for p in expired:
            self._span("request.expired", trace_id=p.trace,
                       t_start=p.t_enqueue, t_end=now,
                       attrs={"rows": p.Z.shape[0],
                              "queued_s": now - p.t_enqueue})
        self._fail_batch(expired, DeadlineExceeded(
            f"model {self.name!r}: {len(expired)} request(s) expired "
            f"before a flush could serve them"
        ), verdict=None)

    def _sync_breaker_telemetry(self, replica: _Replica) -> None:
        if replica.breaker is None:
            return
        st = replica.breaker.state
        if st != replica.last_state:
            self.telemetry.record_breaker_state(
                st,
                tripped=(st == "open"),
                probe=(st == "half_open"),
                replica=replica.index,
            )
            self._span("breaker.transition", attrs={
                "replica": replica.index,
                "from": replica.last_state,
                "to": st,
            })
            replica.last_state = st

    def _select_replica(self) -> _Replica | None:
        """Least-loaded replica whose breaker admits the fast path
        (round-robin among ties); ``None`` when every replica refuses —
        the all-breakers-open signal that degrades the whole flush.
        ``allow_fast`` is consulted per replica, so an open sibling is
        simply skipped while its probe window has not arrived."""
        n = len(self.replicas)
        allowed = [r for r in self.replicas
                   if r.breaker is None or r.breaker.allow_fast()]
        if not allowed:
            return None
        with self._acct:
            chosen = min(allowed, key=lambda r: (r.inflight_rows,
                                                 (r.index - self._rr) % n))
            self._rr = (chosen.index + 1) % n
        return chosen

    def _execute(self, batch: list[_Pending], *, deadline: bool,
                 tightened: bool = False) -> None:
        # re-check deadlines at flush assembly: a slow step ahead of this
        # batch may have burned the queue time an expired item had left
        now = time.perf_counter()
        live, expired = [], []
        for p in batch:
            (expired if p.deadline is not None and now >= p.deadline
             else live).append(p)
        if expired:
            self._fail_expired(expired)
        batch = live
        if not batch:
            return
        sizes = [p.Z.shape[0] for p in batch]
        rows = int(sum(sizes))

        replica = self._select_replica()
        for r in self.replicas:
            self._sync_breaker_telemetry(r)       # open -> half_open probes
        if replica is None:                       # every breaker refused
            self._execute_degraded(batch, sizes, rows,
                                   deadline=deadline, tightened=tightened)
            return
        with self._acct:
            replica.inflight_rows += rows
        if replica.jobs is not None:              # threaded replica dispatch
            replica.jobs.put((batch, sizes, rows, deadline, tightened))
            return
        self._dispatch(replica, batch, sizes, rows,
                       deadline=deadline, tightened=tightened)

    def _replica_run(self, replica: _Replica) -> None:
        while True:
            job = replica.jobs.get()
            if job is None:
                return
            batch, sizes, rows, deadline, tightened = job
            try:
                self._dispatch(replica, batch, sizes, rows,
                               deadline=deadline, tightened=tightened)
            except BaseException as e:            # _dispatch's own handling
                for p in batch:                   # failed: nothing may hang
                    if not p.future.done():
                        try:
                            p.future.set_exception(e)
                        except Exception:
                            pass

    def _dispatch(self, replica: _Replica, batch: list[_Pending], sizes,
                  rows: int, *, deadline: bool, tightened: bool) -> None:
        """One fast-path flush on ``replica`` — inline on the flush
        thread (single replica) or on the replica's dispatch thread."""
        t0 = time.perf_counter()
        tr = self._tracer
        flush_trace = tr.new_trace() if tr is not None else None
        bucket = replica.engine.bucket_for(
            min(rows, replica.engine.max_batch)
        )
        def _emit_queue_waits():
            # coalesce: each request's time in the queue, linked both to
            # its own trace and (via attrs) to the flush that drained it.
            # Emitted AFTER the engine step is dispatched: span bookkeeping
            # for a deep coalesced batch then overlaps the asynchronous
            # XLA work instead of sitting between the queue and the MXU.
            if tr is not None:
                for p in batch:
                    self._span("request.queue_wait", trace_id=p.trace,
                               t_start=p.t_enqueue, t_end=t0,
                               attrs={"rows": p.Z.shape[0],
                                      "flush": flush_trace})

        try:
            if self.faults is not None:
                if len(self.replicas) > 1:
                    self.faults.check_replica(ENGINE_STEP, replica.index)
                else:
                    self.faults.check(ENGINE_STEP)
            Z = np.concatenate([p.Z for p in batch], axis=0)
            compiled_before = replica.engine.stats.compiled_steps
            result = replica.engine.submit(Z)
            recompiled = replica.engine.stats.compiled_steps > compiled_before
            # e2e latency closes when the SHARED result first materializes
            # (one sample per coalesced request, recorded by whichever
            # client thread syncs first); per-row validity feeds the
            # drift window the DriftGuard watches.
            enqueued = [p.t_enqueue for p in batch]
            telemetry = self.telemetry

            def _on_materialize(done, ts=enqueued, tel=telemetry, n=rows,
                                rep=replica, ftrace=flush_trace, t_sync=t0):
                t_done = time.perf_counter()
                for t_enq in ts:
                    tel.record_latency(t_done - t_enq)
                valid = np.asarray(done[1])
                invalid = int(n - int(valid.sum()))
                tel.record_validity(n, invalid)
                self._span("flush.sync", trace_id=ftrace,
                           t_start=t_sync, t_end=t_done,
                           attrs={"replica": rep.index, "rows": n})
                # fast-path ONLY: degraded flushes never emit a validity
                # span (mirrors record_validity's drift-window contract)
                self._span("flush.validity", trace_id=ftrace,
                           t_end=t_done, attrs={"replica": rep.index,
                                                "rows": n,
                                                "invalid": invalid})
                with self._acct:
                    rep.inflight_rows -= n

            result.on_materialize = _on_materialize
            slices = result.split(sizes)
        except BaseException as e:                 # scatter the failure too
            with self._acct:
                replica.inflight_rows -= rows
                replica.failures += 1
            self.telemetry.record_flush(len(batch), rows, deadline=deadline,
                                        tightened=tightened)
            self.telemetry.record_batch_failure(len(batch), rows)
            self.telemetry.record_replica_failure(replica.index)
            _emit_queue_waits()          # the wait happened even if the step failed
            self._span("flush.failed", trace_id=flush_trace, t_start=t0,
                       attrs={"replica": replica.index, "rows": rows,
                              "error": type(e).__name__})
            if replica.breaker is not None:
                replica.breaker.record_failure()
                self._sync_breaker_telemetry(replica)
            self._fail_batch(batch, e, attrs={"replica": replica.index})
            return
        if replica.breaker is not None:
            replica.breaker.record_success()
            self._sync_breaker_telemetry(replica)
        with self._acct:
            # EWMA of step enqueue time feeds the retry_after_s estimate
            self._step_time_s = 0.8 * self._step_time_s + \
                0.2 * (time.perf_counter() - t0)
            step_ewma = self._step_time_s
            replica.flushes += 1
            replica.rows += rows
        # resolve every future FIRST: clients can start materializing the
        # (asynchronously computing) result — which drops the GIL inside
        # XLA — while the span/telemetry bookkeeping below runs in Python
        for p, s in zip(batch, slices):
            if p.future.set_running_or_notify_cancel():
                p.future.set_result(s)
        self.telemetry.record_step_time(step_ewma)
        self.telemetry.record_flush(len(batch), rows, deadline=deadline,
                                    tightened=tightened)
        self.telemetry.record_replica_flush(replica.index, len(batch), rows,
                                            bucket=bucket)
        self.telemetry.record_served(len(batch), rows)
        if tr is not None:
            cfg_str = self._cfg_strs.get(bucket)
            if recompiled or cfg_str is None:
                # dataclass repr is slow; cache per bucket, refresh on
                # recompile (the one event that can change the config)
                cfg_str = str(replica.engine.bucket_configs.get(bucket))
                self._cfg_strs[bucket] = cfg_str
            # one batched enqueue for the whole flush: step + dispatch
            # plus per-request queue-wait (linked to the flush trace via
            # attrs) and served verdicts — same spans and the same id
            # order as per-call emission, a fraction of the hot-path cost
            now = tr.clock()
            ridx = replica.index
            events = [
                ("engine.step", flush_trace, None, t0, now, {
                    "replica": ridx,
                    "bucket": bucket,
                    "tile_config": cfg_str,
                    "recompiled": recompiled,
                    "rows": rows,
                }),
            ]
            for p in batch:
                events.append(
                    ("request.queue_wait", p.trace, None, p.t_enqueue, t0,
                     {"rows": p.Z.shape[0], "flush": flush_trace})
                )
            events.append(
                ("flush.dispatch", flush_trace, None, t0, now,
                 {"replica": ridx, "requests": len(batch), "rows": rows,
                  "bucket": bucket, "deadline": deadline,
                  "tightened": tightened})
            )
            for p in batch:
                events.append(
                    ("request.served", p.trace, None, p.t_enqueue, now,
                     {"rows": p.Z.shape[0], "replica": ridx,
                      "flush": flush_trace})
                )
            tr.span_many(self.name, events)

    def _execute_degraded(self, batch: list[_Pending], sizes, rows: int, *,
                          deadline: bool, tightened: bool) -> None:
        """Breaker-open serving: exact ``rbf_pred`` path, or shed.

        Reached only when EVERY replica's breaker refuses the fast path;
        it runs inline on the flush thread against the primary engine
        (the exact path is the already-degraded slow lane — fanning it
        out across replicas would just multiply pressure on the host).
        """
        t0 = time.perf_counter()
        tr = self._tracer
        flush_trace = tr.new_trace() if tr is not None else None
        if not getattr(self.engine, "exact_available", False):
            # soonest probe window across replicas: the honest retry hint
            retry = min((r.breaker.retry_after() for r in self.replicas
                         if r.breaker is not None), default=0.0)
            self.telemetry.record_flush(len(batch), rows, deadline=deadline,
                                        tightened=tightened)
            self.telemetry.record_breaker_shed(len(batch))
            self._fail_batch(batch, RuntimeOverloaded(
                f"model {self.name!r}: circuit breaker open and no exact "
                f"model published to degrade to",
                retry_after_s=retry or self.max_wait_s,
            ), attrs={"reason": "breaker_shed"})
            return
        try:
            Z = np.concatenate([p.Z for p in batch], axis=0)
            result = self.engine.submit_exact(Z)
            enqueued = [p.t_enqueue for p in batch]
            telemetry = self.telemetry

            # latency only — degraded rows are exact-served and must NOT
            # feed the drift window (a fault is not input drift); for the
            # same reason no flush.validity span is emitted here
            def _on_materialize(done, ts=enqueued, tel=telemetry,
                                ftrace=flush_trace, n=rows, t_sync=t0):
                t_done = time.perf_counter()
                for t_enq in ts:
                    tel.record_latency(t_done - t_enq)
                self._span("flush.sync", trace_id=ftrace,
                           t_start=t_sync, t_end=t_done,
                           attrs={"rows": n, "degraded": True})

            result.on_materialize = _on_materialize
            slices = result.split(sizes)
        except BaseException as e:
            self.telemetry.record_flush(len(batch), rows, deadline=deadline,
                                        tightened=tightened)
            self.telemetry.record_batch_failure(len(batch), rows)
            self._span("flush.failed", trace_id=flush_trace, t_start=t0,
                       attrs={"rows": rows, "degraded": True,
                              "error": type(e).__name__})
            self._fail_batch(batch, e, attrs={"degraded": True})
            return
        self.telemetry.record_flush(len(batch), rows, deadline=deadline,
                                    tightened=tightened)
        self.telemetry.record_degraded(len(batch), rows)
        self.telemetry.record_served(len(batch), rows)
        self._span("flush.degraded", trace_id=flush_trace, t_start=t0,
                   attrs={"requests": len(batch), "rows": rows})
        for p, s in zip(batch, slices):
            self._span("request.served", trace_id=p.trace,
                       t_start=p.t_enqueue, attrs={
                           "rows": p.Z.shape[0],
                           "degraded": True,
                           "flush": flush_trace,
                       })
            if p.future.set_running_or_notify_cancel():
                p.future.set_result(s)
