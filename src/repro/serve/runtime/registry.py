"""``ArtifactRegistry`` — a content-addressed, multi-tenant model store.

PR 3 made ``CompiledArtifact.save`` byte-deterministic precisely so a
store could key on content; this module is that store. Identity is the
SHA-256 of the artifact's deterministic bytes (``CompiledArtifact
.digest()``), which means:

  * **dedupe for free** — registering the same compile twice (same model,
    same seed, any process) lands on one entry, one engine, one copy of
    the arrays in memory;
  * **lazy directory loads** — a directory of ``.npz`` artifacts is
    indexed by hashing FILE bytes (``save`` writes exactly
    ``to_bytes()``, so the file hash IS the artifact digest) without
    deserializing a single array; arrays load on first use;
  * **aliases** — mutable names (``mnist@latest``) over immutable
    digests, git-tag style. ``set_alias`` is atomic under the registry
    lock: a reader resolves either the old digest or the new one, never
    a torn state, and in-flight requests hold a reference to the OLD
    engine so a hot-swap never yanks a model mid-batch.
  * **LRU engine eviction** — built engines (compiled steps + device
    arrays) are the expensive part; under a ``memory_budget_bytes`` cap
    the registry drops the least-recently-used cold engines. An entry
    backed by a file also drops its arrays (reloadable); an in-memory
    registration keeps them (they are the only copy). Eviction never
    touches the entry's identity — the digest and aliases survive, and
    the next use transparently reloads.
  * **corruption quarantine** — content addressing makes disk integrity
    CHECKABLE, so the registry checks it: ``add_file`` structurally
    validates the ``.npz`` (zip CRC over every member + header present)
    and raises a typed ``ArtifactCorrupt`` for a flipped-bytes or
    truncated file; every load-from-path re-hashes the file and refuses
    to build an engine unless the SHA-256 still equals the registered
    digest — a file mutated on disk AFTER indexing can never serve
    under its old identity. A corrupt entry is QUARANTINED: subsequent
    resolves fail fast with the stored reason instead of re-reading a
    bad file in a retry loop. (Injected transient load faults — the
    chaos harness's ``registry_load`` site — do NOT quarantine: the
    next resolve retries, which is the point of "transient".)
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import os
import threading
import zipfile

import jax

from repro.core.families import CompiledArtifact
from repro.core.families.base import _HEADER_MEMBER
from repro.serve.runtime.errors import ArtifactCorrupt, ModelNotFound
from repro.serve.runtime.faults import REGISTRY_LOAD, FaultInjector
from repro.serve.runtime.publish import PublishSpec, resolve_spec
from repro.serve.svm_engine import SVMEngine

_DIGEST_LEN = 64           # sha256 hex


@dataclasses.dataclass
class RegistryEntry:
    """One immutable model identity and its (re)loadable serving state."""

    digest: str
    path: str | None = None                 # reload source for lazy/evicted
    artifact: CompiledArtifact | None = None
    exact: object | None = None             # SVMModel for the exact fallback
    engine: SVMEngine | None = None         # primary replica (replicas[0])
    replicas: int = 1                       # engines to build from this digest
    engines: list = dataclasses.field(default_factory=list)
    warmup: bool | None = None              # per-model warmup_on_load override
    nbytes: int = 0                         # resident bytes once known
    tick: int = 0                           # LRU clock stamp
    evictions: int = 0
    quarantined: str | None = None          # corruption reason; fail fast
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)


def _hash_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _validate_npz(path: str, digest: str) -> None:
    """Structural check of a saved artifact: a readable zip, every member
    CRC-clean, header member present. Catches truncation and byte flips
    without deserializing any array (CRC pass streams the file once).
    """
    try:
        with zipfile.ZipFile(path) as zf:
            bad = zf.testzip()
            names = set(zf.namelist())
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise ArtifactCorrupt(
            f"{path} is not a readable artifact npz: {e}",
            digest=digest, path=path,
        ) from e
    if bad is not None:
        raise ArtifactCorrupt(
            f"{path}: member {bad!r} fails CRC (corrupt bytes)",
            digest=digest, path=path,
        )
    if f"{_HEADER_MEMBER}.npy" not in names:
        raise ArtifactCorrupt(
            f"{path}: missing {_HEADER_MEMBER!r} header (truncated or not "
            f"an artifact)",
            digest=digest, path=path,
        )


class ArtifactRegistry:
    def __init__(
        self,
        *,
        memory_budget_bytes: int | None = None,
        warmup_on_load: bool = True,
        engine_opts: dict | None = None,
        fault_injector: FaultInjector | None = None,
        obs=None,
    ):
        self.memory_budget_bytes = memory_budget_bytes
        self.warmup_on_load = warmup_on_load
        self.engine_opts = dict(engine_opts or {})
        self.faults = fault_injector         # consulted at every path load
        # obs.Observability (or None): engine loads, evictions and
        # quarantines are recorded as spans under the digest prefix and
        # as model_digest-labelled counters. Runtime injects its bundle
        # here when the caller did not.
        self.obs = obs
        self._entries: dict[str, RegistryEntry] = {}
        self._aliases: dict[str, str] = {}
        self._lock = threading.RLock()
        self._clock = itertools.count(1)
        self._evict_listeners: list = []
        self.loads = 0                       # engine builds (incl. reloads)
        self.hits = 0                        # get_engine served from memory
        self.eviction_count = 0
        self.quarantine_count = 0

    def _obs_event(self, span_name: str, counter_name: str, help_text: str,
                   digest: str, attrs: dict | None = None) -> None:
        """Record one registry lifecycle event (span + counter). Must be
        called OUTSIDE the registry lock — the tracer/metric locks are
        independent, but registry events are rare enough that holding
        ``self._lock`` across them would be pure contention."""
        obs = self.obs
        if obs is None:
            return
        obs.tracer.span(digest[:12], span_name, attrs=attrs)
        obs.metrics.counter(
            counter_name, help_text, ("model_digest",)
        ).labels(model_digest=digest[:12]).inc()

    def add_evict_listener(self, fn) -> None:
        """``fn(digest)`` fires after an engine eviction, OUTSIDE the
        registry lock — the hook ``Runtime`` uses to retire the digest's
        batcher so eviction actually releases the engine's memory (an
        idle batcher would otherwise pin it forever)."""
        self._evict_listeners.append(fn)

    # -------------------------------------------------------------- indexing

    def register(
        self,
        artifact: CompiledArtifact,
        spec: PublishSpec | None = None,
        *,
        alias: str | None = None,
        exact=None,
        path: str | None = None,
        replicas: int | None = None,
    ) -> str:
        """Index ``artifact`` under its content digest; returns the digest.

        Options travel in one ``PublishSpec`` — the same shape
        ``Runtime.publish`` and the HTTP management API serialize (the
        bare ``alias``/``exact``/``path``/``replicas`` kwargs are
        deprecated-but-accepted aliases for ``spec=PublishSpec(...)``).

        Re-registering an identical compile is a no-op on the entry
        (dedupe); ``alias``/``exact``/``path`` still update, so a caller
        can attach a fallback model or a name to an existing digest.

        ``replicas=N`` asks for N engines from this one digest (content
        addressing makes them trivially consistent — same bytes, same
        compiled step), each pinned round-robin to a local device.
        ``None`` leaves the entry's current replica count alone, so a
        plain re-register never silently collapses a scaled-out model.
        """
        spec = resolve_spec(spec, caller="ArtifactRegistry.register",
                            alias=alias, exact=exact, path=path,
                            replicas=replicas)
        digest = artifact.digest()
        with self._lock:
            entry = self._entries.get(digest)
            if entry is None:
                entry = RegistryEntry(digest=digest, artifact=artifact)
                self._entries[digest] = entry
            elif entry.artifact is None:
                entry.artifact = artifact
            if spec.exact is not None:
                entry.exact = spec.exact
            if spec.path is not None:
                entry.path = spec.path
            if spec.warmup is not None:
                entry.warmup = spec.warmup
            if spec.replicas is not None:
                r = int(spec.replicas)
                if r != entry.replicas:
                    # retire every built replica atomically: the next
                    # resolve rebuilds at the new count, and the runtime's
                    # engine-identity check retires the stale batcher
                    entry.replicas = r
                    entry.engines = []
                    entry.engine = None
            if spec.alias is not None:
                self._aliases[spec.alias] = digest
        return digest

    def add_file(self, path: str, spec: PublishSpec | None = None, *,
                 alias: str | None = None, exact=None) -> str:
        """Index one saved artifact WITHOUT loading its arrays.

        ``save`` writes exactly ``to_bytes()``, so hashing the file bytes
        yields the same digest ``artifact.digest()`` would — content
        addressing straight off the filesystem.

        The file is structurally validated first (zip CRC + header): a
        corrupt or truncated artifact raises ``ArtifactCorrupt`` and is
        never indexed — a bad file must not acquire an identity.

        ``spec`` carries the publication options (alias/replicas/warmup/
        exact; its ``path`` field is ignored — the positional ``path``
        is authoritative here). The bare ``alias``/``exact`` kwargs
        remain first-class for this entry point (not deprecated): a
        file index is the one place the file IS the argument.
        """
        if spec is None:
            spec = PublishSpec(alias=alias, exact=exact)
        elif alias is not None or exact is not None:
            raise TypeError(
                "ArtifactRegistry.add_file: pass either spec= or "
                "alias=/exact=, not both"
            )
        digest = _hash_file(path)
        _validate_npz(path, digest)
        with self._lock:
            entry = self._entries.get(digest)
            if entry is None:
                entry = RegistryEntry(digest=digest, path=path)
                self._entries[digest] = entry
            elif entry.path is None:
                entry.path = path
            if spec.exact is not None:
                entry.exact = spec.exact
            if spec.warmup is not None:
                entry.warmup = spec.warmup
            if spec.replicas is not None and spec.replicas != entry.replicas:
                entry.replicas = int(spec.replicas)
                entry.engines = []
                entry.engine = None
            if spec.alias is not None:
                self._aliases[spec.alias] = digest
        return digest

    def add_directory(self, dirpath: str, *, tag: str = "latest") -> dict[str, str]:
        """Lazily index every ``*.npz`` under ``dirpath``.

        Each file gets the alias ``<stem>@<tag>`` (stems sorted, so a
        duplicated stem deterministically resolves to the lexicographically
        last file). Returns ``{alias: digest}`` for what was indexed.
        """
        added: dict[str, str] = {}
        for name in sorted(os.listdir(dirpath)):
            if not name.endswith(".npz"):
                continue
            stem = name[: -len(".npz")]
            alias = f"{stem}@{tag}"
            added[alias] = self.add_file(os.path.join(dirpath, name), alias=alias)
        return added

    # --------------------------------------------------------------- aliases

    def set_alias(self, alias: str, ref: str) -> str:
        """Atomically point ``alias`` at ``ref`` (digest or other alias).

        This is the hot-swap primitive: publish the new artifact (its
        digest is already immutable in the store), then flip the alias.
        Readers between the two states see a complete old model or a
        complete new model; requests already holding the old engine
        finish on it untouched.
        """
        with self._lock:
            digest = self.resolve(ref)
            self._aliases[alias] = digest
            return digest

    def publish(self, alias: str, artifact: CompiledArtifact,
                spec: PublishSpec | None = None, *, exact=None,
                replicas: int | None = None) -> str:
        """Register + flip ``alias`` in one atomic step; returns the digest."""
        spec = resolve_spec(spec, caller="ArtifactRegistry.publish",
                            exact=exact, replicas=replicas)
        spec = dataclasses.replace(spec, alias=alias)
        with self._lock:
            return self.register(artifact, spec)

    def aliases(self) -> dict[str, str]:
        with self._lock:
            return dict(self._aliases)

    def resolve(self, ref: str) -> str:
        """``ref`` → digest: exact digest, alias, ``ref@latest``, or a
        unique digest prefix (git-style)."""
        with self._lock:
            if len(ref) == _DIGEST_LEN and ref in self._entries:
                return ref
            if ref in self._aliases:
                return self._aliases[ref]
            tagged = f"{ref}@latest"
            if tagged in self._aliases:
                return self._aliases[tagged]
            matches = [d for d in self._entries if d.startswith(ref)]
            if len(matches) == 1:
                return matches[0]
            if len(matches) > 1:
                raise ModelNotFound(
                    f"ambiguous model ref {ref!r} ({len(matches)} matches)",
                    ref=ref,
                )
            raise ModelNotFound(
                f"unknown model ref {ref!r}; known aliases: "
                f"{sorted(self._aliases)}",
                ref=ref,
            )

    # --------------------------------------------------------------- serving

    def get_engine(self, ref: str) -> tuple[str, SVMEngine]:
        """(digest, primary ready engine) for ``ref``; builds on miss."""
        digest, engines = self.get_engines(ref)
        return digest, engines[0]

    def get_engines(self, ref: str) -> tuple[str, list[SVMEngine]]:
        """(digest, replica engines) for ``ref``; loads/builds/warms on miss.

        The build happens under the ENTRY lock, not the registry lock, so
        warming one cold model never stalls lookups of hot ones. All of
        the entry's replicas are built together (and evicted together):
        a caller never observes a half-scaled model.

        Raises ``ArtifactCorrupt`` (fail-fast, no disk retry) for a
        quarantined entry, and quarantines on the spot if the reload
        finds the file's hash no longer matches the registered digest.
        """
        with self._lock:
            digest = self.resolve(ref)
            entry = self._entries[digest]
            if entry.quarantined is not None:
                raise ArtifactCorrupt(
                    f"model {digest[:12]} is quarantined: {entry.quarantined}",
                    digest=digest, path=entry.path,
                )
            entry.tick = next(self._clock)
            engines = list(entry.engines)
            want = max(1, entry.replicas)
        if len(engines) == want:
            self.hits += 1                   # approximate under race; fine
            return digest, engines
        with entry.lock:
            with self._lock:                 # re-check under the build lock
                engines = list(entry.engines)
                want = max(1, entry.replicas)
            if len(engines) != want:
                artifact = entry.artifact
                if artifact is None:
                    if entry.path is None:
                        raise RuntimeError(
                            f"entry {digest[:12]} has no artifact and no path"
                        )
                    artifact = self._load_verified(entry)
                warm = (self.warmup_on_load if entry.warmup is None
                        else entry.warmup)
                engines = self._build_replicas(artifact, entry.exact, want,
                                               warmup=warm)
                with self._lock:
                    entry.artifact = artifact
                    # each replica bakes its own device copy of the arrays
                    entry.nbytes = artifact.nbytes() * want
                    entry.engines = engines
                    entry.engine = engines[0]
                    self.loads += 1
                self._obs_event(
                    "registry.load", "repro_registry_loads_total",
                    "Engine builds (including reloads after eviction).",
                    digest, attrs={"replicas": want,
                                   "nbytes": artifact.nbytes() * want,
                                   "warmed": warm},
                )
        self._evict_to_budget(keep=digest)
        return digest, engines

    def _build_replicas(self, artifact, exact, count: int, *,
                        warmup: bool | None = None) -> list[SVMEngine]:
        """``count`` engines off one artifact, pinned round-robin across
        local devices (pinning is skipped when the caller already chose
        placement via ``device=`` / ``head_mesh=`` engine opts)."""
        if warmup is None:
            warmup = self.warmup_on_load
        devices = jax.local_devices()
        engines = []
        for i in range(count):
            opts = dict(self.engine_opts)
            if (count > 1 and "device" not in opts
                    and "head_mesh" not in opts):
                opts["device"] = devices[i % len(devices)]
            engine = SVMEngine(artifact, exact, **opts)
            if warmup:
                engine.warmup()
            engines.append(engine)
        return engines

    def _quarantine(self, entry: RegistryEntry, reason: str) -> None:
        with self._lock:
            if entry.quarantined is not None:
                return
            entry.quarantined = reason
            self.quarantine_count += 1
        self._obs_event(
            "registry.quarantine", "repro_registry_quarantined_total",
            "Entries quarantined for content-identity violations.",
            entry.digest, attrs={"reason": reason},
        )

    def _load_verified(self, entry: RegistryEntry) -> CompiledArtifact:
        """(Re)load ``entry.path`` with identity verification.

        Every path load — first lazy load AND reload-after-evict —
        re-hashes the file: content addressing means the digest is not
        provenance metadata but the entry's NAME, so a file whose bytes
        changed on disk simply is not this model anymore. Mismatch or an
        unparseable file quarantines the entry (fail fast on the next
        resolve, no retry loop against a bad disk).
        """
        if self.faults is not None:
            # transient injected load failure: raises InjectedFault and
            # deliberately does NOT quarantine — the next resolve retries
            self.faults.check(REGISTRY_LOAD)
        actual = _hash_file(entry.path)
        if actual != entry.digest:
            reason = (f"file hash {actual[:12]} != registered digest "
                      f"{entry.digest[:12]} (mutated on disk)")
            self._quarantine(entry, reason)
            raise ArtifactCorrupt(
                f"{entry.path}: {reason}", digest=entry.digest, path=entry.path
            )
        try:
            return CompiledArtifact.load(entry.path)
        except Exception as e:
            reason = f"unparseable artifact file: {e}"
            self._quarantine(entry, reason)
            raise ArtifactCorrupt(
                f"{entry.path}: {reason}", digest=entry.digest, path=entry.path
            ) from e

    def evict(self, ref: str) -> str:
        """Administratively drop ``ref``'s built engines; returns the digest.

        Same semantics as a budget eviction: identity (digest, aliases,
        registration) survives, the next use transparently rebuilds. An
        in-memory registration keeps its artifact (it is the only copy);
        a file-backed one drops the arrays too. Evict listeners fire
        outside the lock so the runtime retires the digest's batcher.
        """
        with self._lock:
            digest = self.resolve(ref)
            entry = self._entries[digest]
            had_engine = entry.engine is not None
            entry.engine = None
            entry.engines = []
            if entry.path is not None:
                entry.artifact = None
            if had_engine:
                entry.evictions += 1
                self.eviction_count += 1
        if had_engine:
            self._obs_event(
                "registry.evict", "repro_registry_evictions_total",
                "Engines evicted under the memory budget.",
                digest, attrs={"reason": "admin"},
            )
            for fn in self._evict_listeners:
                fn(digest)
        return digest

    def set_replicas(self, ref: str, replicas: int) -> str:
        """Re-scale ``ref`` to ``replicas`` engines; returns the digest.

        Retires every built replica atomically (the next resolve rebuilds
        at the new count) and notifies evict listeners so the runtime
        swaps the digest's batcher onto the fresh engine set.
        """
        r = int(replicas)
        if r < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        with self._lock:
            digest = self.resolve(ref)
            entry = self._entries[digest]
            changed = r != entry.replicas
            if changed:
                entry.replicas = r
                entry.engines = []
                entry.engine = None
        if changed:
            for fn in self._evict_listeners:
                fn(digest)
        return digest

    def loaded_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._entries.values() if e.engine is not None)

    def _evict_to_budget(self, keep: str | None = None) -> int:
        """Drop LRU engines until loaded bytes fit the budget; returns count.

        The entry most recently touched (``keep``) is never evicted — the
        budget is a pressure valve, not a correctness gate, and evicting
        the model being served would thrash.
        """
        if self.memory_budget_bytes is None:
            return 0
        evicted: list[str] = []
        with self._lock:
            loaded = [e for e in self._entries.values() if e.engine is not None]
            total = sum(e.nbytes for e in loaded)
            for entry in sorted(loaded, key=lambda e: e.tick):
                if total <= self.memory_budget_bytes:
                    break
                if entry.digest == keep:
                    continue
                entry.engine = None          # every replica retires together:
                entry.engines = []           # eviction is all-or-nothing
                if entry.path is not None:
                    entry.artifact = None    # reloadable: drop the arrays too
                entry.evictions += 1
                total -= entry.nbytes
                evicted.append(entry.digest)
                self.eviction_count += 1
        for digest in evicted:               # listeners run outside the lock
            self._obs_event(
                "registry.evict", "repro_registry_evictions_total",
                "Engines evicted under the memory budget.",
                digest,
            )
            for fn in self._evict_listeners:
                fn(digest)
        return len(evicted)

    # ------------------------------------------------------------- telemetry

    def list_models(self) -> list[dict]:
        """One JSON-able row per registered digest — the management
        API's ``GET /v1/models`` body."""
        with self._lock:
            alias_of: dict[str, list[str]] = {}
            for a, d in self._aliases.items():
                alias_of.setdefault(d, []).append(a)
            return [
                {
                    "digest": e.digest,
                    "aliases": sorted(alias_of.get(e.digest, [])),
                    "loaded": e.engine is not None,
                    "replicas": e.replicas,
                    "path": e.path,
                    "nbytes": e.nbytes,
                    "evictions": e.evictions,
                    "quarantined": e.quarantined,
                }
                for e in self._entries.values()
            ]

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "models": len(self._entries),
                "loaded": sum(
                    1 for e in self._entries.values() if e.engine is not None
                ),
                "loaded_bytes": sum(
                    e.nbytes for e in self._entries.values() if e.engine is not None
                ),
                "memory_budget_bytes": self.memory_budget_bytes,
                "loads": self.loads,
                "hits": self.hits,
                "evictions": self.eviction_count,
                "quarantined": self.quarantine_count,
                "aliases": dict(self._aliases),
            }
