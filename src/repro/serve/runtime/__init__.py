"""Multi-tenant serving runtime: many models × many concurrent callers.

The layer the ROADMAP's "heavy traffic from millions of users" target
needs on top of ``SVMEngine``:

  * ``ArtifactRegistry`` — content-addressed model store (SHA-256 of the
    deterministic artifact bytes), named aliases with atomic hot-swap,
    lazy directory loads, LRU engine eviction under a memory budget;
  * ``MicroBatcher`` — async scheduler coalescing concurrent small
    requests into the engine's power-of-two buckets (flush on bucket
    fill or ``max_wait_us`` deadline), scattering results back to
    per-request futures without losing the engine's deferred-sync or
    zero-recompile properties;
  * ``Runtime`` — the front door (``submit(model, Z) -> future``),
    per-model telemetry (p50/p99, queue depth, coalescing factor,
    fallback rate, evictions).
"""

from repro.serve.runtime.registry import ArtifactRegistry, RegistryEntry
from repro.serve.runtime.runtime import Runtime
from repro.serve.runtime.scheduler import BatcherClosed, MicroBatcher
from repro.serve.runtime.telemetry import LatencyWindow, ModelTelemetry

__all__ = [
    "ArtifactRegistry",
    "BatcherClosed",
    "LatencyWindow",
    "MicroBatcher",
    "ModelTelemetry",
    "RegistryEntry",
    "Runtime",
]
