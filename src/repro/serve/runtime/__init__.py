"""Multi-tenant serving runtime: many models × many concurrent callers.

The layer the ROADMAP's "heavy traffic from millions of users" target
needs on top of ``SVMEngine``:

  * ``ArtifactRegistry`` — content-addressed model store (SHA-256 of the
    deterministic artifact bytes), named aliases with atomic hot-swap,
    lazy directory loads, LRU engine eviction under a memory budget,
    corruption quarantine (``ArtifactCorrupt``) with SHA re-verification
    on every load from disk;
  * ``MicroBatcher`` — async scheduler coalescing concurrent small
    requests into the engine's power-of-two buckets (flush on bucket
    fill or ``max_wait_us`` deadline), scattering results back to
    per-request futures without losing the engine's deferred-sync or
    zero-recompile properties; bounded-queue admission control
    (``RuntimeOverloaded``), per-submit deadlines (``DeadlineExceeded``),
    and a per-model ``CircuitBreaker`` that degrades repeated engine
    failures to the exact streaming ``rbf_pred`` path;
  * ``Runtime`` — the front door (``submit(model, Z) -> future``),
    per-model telemetry (p50/p99, queue depth, coalescing factor,
    fallback rate, evictions, shed/timeout/failure/breaker counters);
  * ``DriftGuard`` — the self-healing loop: windowed fallback-rate
    watch, reservoir-sampled recompile, exact-RBF canary, atomic alias
    flip;
  * ``FaultInjector`` — deterministic chaos harness (seeded engine
    faults, slow steps, registry load failures, file corruption).
"""

from repro.serve.runtime.errors import (
    ArtifactCorrupt,
    BatcherClosed,
    DeadlineExceeded,
    InjectedFault,
    ModelNotFound,
    RuntimeOverloaded,
    ServingError,
)
from repro.serve.runtime.faults import ENGINE_STEP, REGISTRY_LOAD, FaultInjector
from repro.serve.runtime.guard import DriftGuard, ReservoirSampler
from repro.serve.runtime.obs import (
    MetricsRegistry,
    Observability,
    Tracer,
    render_prometheus,
)
from repro.serve.runtime.publish import PublishSpec
from repro.serve.runtime.registry import ArtifactRegistry, RegistryEntry
from repro.serve.runtime.runtime import Runtime
from repro.serve.runtime.scheduler import CircuitBreaker, MicroBatcher
from repro.serve.runtime.telemetry import LatencyWindow, ModelTelemetry

__all__ = [
    "ENGINE_STEP",
    "REGISTRY_LOAD",
    "ArtifactCorrupt",
    "ArtifactRegistry",
    "BatcherClosed",
    "CircuitBreaker",
    "DeadlineExceeded",
    "DriftGuard",
    "FaultInjector",
    "InjectedFault",
    "LatencyWindow",
    "MetricsRegistry",
    "MicroBatcher",
    "ModelNotFound",
    "ModelTelemetry",
    "Observability",
    "PublishSpec",
    "RegistryEntry",
    "ReservoirSampler",
    "Runtime",
    "RuntimeOverloaded",
    "ServingError",
    "Tracer",
    "render_prometheus",
]
