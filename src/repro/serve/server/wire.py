"""Wire schemas for the HTTP front door.

One module owns every JSON shape that crosses the network, so the
contract documented in ``serve/server/README.md`` has exactly one
implementation to drift from. Two rules govern the shapes:

  * **Errors are the taxonomy.** Every error body is
    ``{"error": ServingError.to_wire()}`` — the stable ``code`` /
    ``status`` / ``message`` triple (plus per-type extras such as
    ``retry_after_s``). Malformed requests raise ``InvalidRequest``,
    which is itself a ``ServingError`` (code ``invalid_request``,
    HTTP 400), so the app's single attribute-based error mapper covers
    client mistakes and runtime sheds alike.
  * **Predictions carry the §4 verdicts.** A predict response is not
    just scores: every row ships its run-time validity bit (the
    paper's certificate that the fast path was trustworthy for THAT
    row), the serving digest (so a client can pin what scored it),
    and the model's family/dtype provenance.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.serve.runtime.errors import ServingError

# Request bodies are bounded: a predict payload is rows of floats, a
# publish payload is one artifact — 64 MiB covers both with headroom
# while keeping a malicious body from ballooning the process.
MAX_BODY_BYTES = 64 << 20


class InvalidRequest(ServingError, ValueError):
    """Malformed request body / params — the client's bug, HTTP 400."""

    code = "invalid_request"
    http_status = 400


@dataclasses.dataclass
class Request:
    """One parsed HTTP request, transport-agnostic (the ASGI app and
    the stdlib socket adapter both build exactly this)."""

    method: str
    path: str
    headers: dict                        # lower-cased names -> values
    body: bytes = b""


@dataclasses.dataclass
class Response:
    """One response; ``headers`` are extras beyond Content-Type/-Length."""

    status: int = 200
    body: bytes = b""
    content_type: str = "application/json"
    headers: tuple = ()


def parse_json(body: bytes) -> dict:
    if not body:
        raise InvalidRequest("empty body; expected a JSON object")
    try:
        data = json.loads(body)
    except ValueError as e:
        raise InvalidRequest(f"body is not valid JSON: {e}") from e
    if not isinstance(data, dict):
        raise InvalidRequest(
            f"expected a JSON object, got {type(data).__name__}"
        )
    return data


def parse_predict(data: dict) -> tuple[np.ndarray, float | None]:
    """``{"rows": [[...], ...], "deadline_s": 0.5?}`` → (Z, deadline_s).

    Rows must be a non-empty rectangular 2-D array of finite-parseable
    numbers; shape errors fail here with a 400, not deep in the engine
    with a 500.
    """
    if "rows" not in data:
        raise InvalidRequest('missing "rows": expected [[...], ...]')
    rows = data["rows"]
    try:
        Z = np.asarray(rows, dtype=np.float64)
    except (TypeError, ValueError) as e:
        raise InvalidRequest(f'"rows" is not numeric: {e}') from e
    if Z.ndim == 1 and Z.size:
        Z = Z[None, :]                       # single row convenience
    if Z.ndim != 2 or Z.shape[0] == 0 or Z.shape[1] == 0:
        raise InvalidRequest(
            f'"rows" must be a non-empty 2-D array, got shape {Z.shape}'
        )
    deadline_s = data.get("deadline_s")
    if deadline_s is not None:
        try:
            deadline_s = float(deadline_s)
        except (TypeError, ValueError) as e:
            raise InvalidRequest(f'"deadline_s" is not a number: {e}') from e
        if deadline_s <= 0:
            raise InvalidRequest(f'"deadline_s" must be > 0, got {deadline_s}')
    return Z, deadline_s


def predict_response(digest: str, values, valid, labels, *,
                     family: str = "", dtype: str = "") -> dict:
    """The scoring contract: per-row scores + §4 validity + provenance."""
    return {
        "digest": digest,
        "family": family,
        "dtype": dtype,
        "n": int(np.asarray(values).shape[0]),
        "scores": np.asarray(values).tolist(),
        "labels": np.asarray(labels).tolist(),
        "valid": [bool(v) for v in np.asarray(valid)],
    }


def error_body(exc: ServingError) -> dict:
    return {"error": exc.to_wire()}


def dump_json(obj) -> bytes:
    return json.dumps(obj, sort_keys=True).encode("utf-8")
