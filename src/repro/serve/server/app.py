"""``create_app`` — the ASGI 3 application over a ``Runtime``.

The app is a plain ASGI callable (``await app(scope, receive, send)``)
with no framework dependency: CI images do not ship FastAPI, and the
surface is small enough that the standard protocol IS the framework.
It composes the other modules — ``routes`` for handlers, ``wire`` for
shapes, ``tenancy`` for admission — and owns exactly two concerns:

  * **routing** — a literal table of ``(method, pattern)`` pairs where
    a pattern segment ``{ref}`` captures one path segment. Google-style
    custom verbs (``/v1/models/{ref}:predict``) keep actions on a
    resource without overloading POST semantics.
  * **error mapping** — one ``except Exception`` around dispatch that
    maps BY ATTRIBUTE: anything carrying ``http_status``/``to_wire``
    (i.e. any ``ServingError``, including ones that do not exist yet)
    becomes ``{"error": {code, status, message, ...}}`` with its
    status; a 429 with ``retry_after_s`` grows a ``Retry-After``
    header. There is deliberately no isinstance ladder to extend —
    defining a new error type IS wiring it end to end.

Everything else (HTTP parsing, sockets) lives in ``httpd``, which
adapts a TCP byte stream onto this same callable.
"""

from __future__ import annotations

import math
import tempfile

from repro.serve.runtime.runtime import Runtime
from repro.serve.server import routes
from repro.serve.server.tenancy import TenantTable
from repro.serve.server.wire import (
    MAX_BODY_BYTES,
    InvalidRequest,
    Request,
    Response,
    dump_json,
    error_body,
)

_ROUTES = (
    ("GET", "/healthz", routes.healthz),
    ("GET", "/metrics", routes.metrics),
    ("GET", "/v1/models", routes.list_models),
    ("POST", "/v1/models", routes.publish),
    ("GET", "/v1/stats", routes.runtime_stats),
    ("GET", "/v1/tenants", routes.tenants),
    ("POST", "/v1/models/{ref}:predict", routes.predict),
    ("POST", "/v1/models/{ref}:alias", routes.set_alias),
    ("POST", "/v1/models/{ref}:replicas", routes.set_replicas),
    ("POST", "/v1/models/{ref}:evict", routes.evict),
    ("GET", "/v1/models/{ref}/stats", routes.stats),
)


def _match(pattern: str, path: str):
    """Match ``path`` against ``pattern``; ``{name}`` captures one
    segment (including a ``:verb`` suffix when the pattern has one).
    Returns the captured args tuple or None."""
    pparts = pattern.split("/")
    parts = path.split("/")
    if len(pparts) != len(parts):
        return None
    args = []
    for pp, p in zip(pparts, parts):
        if pp.startswith("{"):
            close = pp.index("}")
            suffix = pp[close + 1:]          # e.g. ":predict" or ""
            if suffix:
                if not p.endswith(suffix):
                    return None
                p = p[: -len(suffix)]
            if not p:
                return None
            args.append(p)
        elif pp != p:
            return None
    return tuple(args)


class App:
    """ASGI 3 callable serving one ``Runtime``.

    ``app.runtime`` / ``app.tenants`` / ``app.spool_dir`` are the state
    the handlers in ``routes`` read. The app does not own the runtime's
    lifetime unless it created it (``create_app`` with no runtime):
    then ``close()`` tears the runtime down too.
    """

    def __init__(self, runtime: Runtime, tenants: TenantTable,
                 spool_dir: str, *, owns_runtime: bool):
        self.runtime = runtime
        self.tenants = tenants
        self.spool_dir = spool_dir
        self._owns_runtime = owns_runtime

    # -- dispatch ----------------------------------------------------------

    async def handle(self, request: Request) -> Response:
        """Route + run one request; every failure becomes a wire error."""
        try:
            found_path = False
            for method, pattern, handler in _ROUTES:
                args = _match(pattern, request.path)
                if args is None:
                    continue
                found_path = True
                if method == request.method:
                    return await handler(self, request, *args)
            if found_path:
                return self._error_response(
                    405, {"error": {
                        "code": "method_not_allowed", "status": 405,
                        "message": f"{request.method} not allowed on "
                                   f"{request.path}",
                    }})
            return self._error_response(
                404, {"error": {
                    "code": "not_found", "status": 404,
                    "message": f"no route for {request.path}",
                }})
        except Exception as exc:                      # noqa: BLE001
            return self._map_exception(exc)

    def _map_exception(self, exc: Exception) -> Response:
        status = getattr(exc, "http_status", None)
        to_wire = getattr(exc, "to_wire", None)
        if status is None or to_wire is None:
            body = {"error": {
                "code": "internal", "status": 500,
                "message": f"{type(exc).__name__}: {exc}",
            }}
            return self._error_response(500, body)
        headers = ()
        retry = getattr(exc, "retry_after_s", None)
        if retry is not None:
            # integral per RFC 9110; at least 1 so a client that honors
            # it literally cannot busy-loop
            headers = (("Retry-After", str(max(1, math.ceil(retry)))),)
        return self._error_response(int(status), error_body(exc), headers)

    @staticmethod
    def _error_response(status: int, body: dict, headers: tuple = ()):
        return Response(status=status, body=dump_json(body), headers=headers)

    # -- ASGI --------------------------------------------------------------

    async def __call__(self, scope, receive, send) -> None:
        if scope["type"] == "lifespan":              # accept, do nothing
            while True:
                msg = await receive()
                if msg["type"] == "lifespan.startup":
                    await send({"type": "lifespan.startup.complete"})
                elif msg["type"] == "lifespan.shutdown":
                    await send({"type": "lifespan.shutdown.complete"})
                    return
        if scope["type"] != "http":
            raise RuntimeError(f"unsupported ASGI scope {scope['type']!r}")
        headers = {
            k.decode("latin-1").lower(): v.decode("latin-1")
            for k, v in scope.get("headers", ())
        }
        body = bytearray()
        while True:
            msg = await receive()
            if msg["type"] != "http.request":
                break
            body.extend(msg.get("body", b""))
            if len(body) > MAX_BODY_BYTES:
                resp = self._map_exception(InvalidRequest(
                    f"body exceeds {MAX_BODY_BYTES} bytes"
                ))
                await self._send_response(send, resp)
                return
            if not msg.get("more_body"):
                break
        request = Request(
            method=scope["method"],
            path=scope["path"],
            headers=headers,
            body=bytes(body),
        )
        resp = await self.handle(request)
        await self._send_response(send, resp)

    @staticmethod
    async def _send_response(send, resp: Response) -> None:
        headers = [
            (b"content-type", resp.content_type.encode("latin-1")),
            (b"content-length", str(len(resp.body)).encode("latin-1")),
        ]
        for name, value in resp.headers:
            headers.append(
                (name.encode("latin-1").lower(), value.encode("latin-1"))
            )
        await send({"type": "http.response.start", "status": resp.status,
                    "headers": headers})
        await send({"type": "http.response.body", "body": resp.body})

    # -- lifetime ----------------------------------------------------------

    def close(self) -> None:
        if self._owns_runtime:
            self.runtime.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def create_app(
    runtime: Runtime | None = None,
    *,
    tenants=None,
    spool_dir: str | None = None,
    **runtime_kw,
) -> App:
    """Build the front door.

    ``runtime=None`` creates one (any ``runtime_kw`` — ``max_wait_us``,
    ``max_queue_rows``, ... — are forwarded) and ties its lifetime to
    the app; passing a runtime leaves its lifetime with the caller.
    ``tenants`` is an iterable of ``TenantConfig``; none ⇒ open server.
    ``spool_dir`` receives uploaded artifacts (default: a fresh temp
    directory).
    """
    owns = runtime is None
    if runtime is None:
        runtime = Runtime(**runtime_kw)
    elif runtime_kw:
        raise TypeError(
            f"runtime_kw {sorted(runtime_kw)} only apply when create_app "
            f"builds the runtime"
        )
    if spool_dir is None:
        spool_dir = tempfile.mkdtemp(prefix="repro-artifact-spool-")
    table = tenants if isinstance(tenants, TenantTable) \
        else TenantTable(tenants)
    return App(runtime, table, spool_dir, owns_runtime=owns)
