"""Per-tenant admission control for the HTTP front door.

The runtime's bounded queues protect the SERVER (total work is capped);
tenancy protects tenants from EACH OTHER: an API key resolves to a
``Tenant`` whose token buckets meter requests/s and rows/s before the
request ever reaches ``Runtime.submit``. The layering is deliberate —
a tenant-shed request costs one dict lookup and two float compares,
never an engine, a queue slot, or a numpy parse of a giant body.

Sheds here are still SHEDS in the one true accounting: the predict
route records a tenant-quota shed into the model's ``ModelTelemetry``
and emits a ``request.shed`` span, so ``Tracer.conservation`` holds
(submitted == admitted + shed) whether the shed came from a full queue,
a tripped breaker, or a tenant quota. ``TenantQuotaExceeded`` subclasses
``RuntimeOverloaded``: same HTTP 429, same ``Retry-After`` machinery,
distinct stable ``code`` so clients can tell "server is busy" from
"YOU are over quota".

Token buckets take an injectable ``clock`` so tests refill time
deterministically instead of sleeping.
"""

from __future__ import annotations

import dataclasses
import threading
import time

from repro.serve.runtime.errors import RuntimeOverloaded, ServingError
from repro.serve.server.wire import InvalidRequest

API_KEY_HEADER = "x-api-key"


class Unauthenticated(ServingError):
    """No/unknown API key on a server that has tenants configured."""

    code = "unauthenticated"
    http_status = 401


class TenantQuotaExceeded(RuntimeOverloaded):
    """Tenant-level token bucket empty; retry after ``retry_after_s``.

    A ``RuntimeOverloaded`` (same 429 + ``Retry-After`` path), with its
    own ``code`` and the offending quota named in ``quota``.
    """

    code = "tenant_quota"

    def __init__(self, message: str, retry_after_s: float = 0.0, *,
                 tenant: str = "", quota: str = ""):
        super().__init__(message, retry_after_s)
        self.tenant = tenant
        self.quota = quota

    def to_wire(self) -> dict:
        out = super().to_wire()
        out["tenant"] = self.tenant
        out["quota"] = self.quota
        return out


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, capacity ``burst``.

    ``take(n)`` either debits n tokens and returns 0.0, or debits
    nothing and returns the seconds until n tokens will exist — the
    caller's ``Retry-After``. A request for more than ``burst`` tokens
    can never succeed; ``take`` reports the refill time for the full
    burst so the caller still gets a finite hint.
    """

    def __init__(self, rate: float, burst: float, *, clock=time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate and burst must be > 0, got {rate}/{burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.clock = clock
        self._tokens = self.burst
        self._stamp = clock()
        self._lock = threading.Lock()

    def take(self, n: float = 1.0) -> float:
        with self._lock:
            now = self.clock()
            self._tokens = min(
                self.burst, self._tokens + (now - self._stamp) * self.rate
            )
            self._stamp = now
            if self._tokens >= n:
                self._tokens -= n
                return 0.0
            need = min(n, self.burst) - self._tokens
            return need / self.rate


@dataclasses.dataclass
class TenantConfig:
    """Declarative limits for one tenant (all Nones = unlimited)."""

    name: str
    api_key: str
    rate_rps: float | None = None        # request token bucket: rate
    burst: float | None = None           # ... capacity (default 2*rate)
    rows_per_s: float | None = None      # row token bucket: rate
    row_burst: float | None = None       # ... capacity (default 2*rate)
    max_rows: int | None = None          # hard per-request row cap (400)


class Tenant:
    """Live admission state for one configured tenant."""

    def __init__(self, cfg: TenantConfig, *, clock=time.monotonic):
        self.cfg = cfg
        self.name = cfg.name
        self.requests = TokenBucket(
            cfg.rate_rps, cfg.burst or 2 * cfg.rate_rps, clock=clock
        ) if cfg.rate_rps else None
        self.rows = TokenBucket(
            cfg.rows_per_s, cfg.row_burst or 2 * cfg.rows_per_s, clock=clock
        ) if cfg.rows_per_s else None
        self._lock = threading.Lock()
        self.admitted = 0
        self.shed = 0
        self.admitted_rows = 0
        self.shed_rows = 0

    def admit(self, n_rows: int) -> None:
        """Debit both buckets or raise ``TenantQuotaExceeded``.

        Request-then-rows order with a refund: if the request token is
        taken but the row bucket refuses, the request token is NOT
        returned (the tenant did make a request) — but the row bucket
        was never debited, so a smaller retry is not double-charged.
        """
        cfg = self.cfg
        if cfg.max_rows is not None and n_rows > cfg.max_rows:
            raise InvalidRequest(
                f"request of {n_rows} rows exceeds tenant {self.name!r} "
                f"per-request cap of {cfg.max_rows}"
            )
        retry = self.requests.take(1.0) if self.requests else 0.0
        quota = "rate_rps"
        if retry == 0.0 and self.rows is not None:
            retry = self.rows.take(float(n_rows))
            quota = "rows_per_s"
        if retry > 0.0:
            with self._lock:
                self.shed += 1
                self.shed_rows += n_rows
            raise TenantQuotaExceeded(
                f"tenant {self.name!r} over {quota} quota; "
                f"retry in {retry:.3f}s",
                retry, tenant=self.name, quota=quota,
            )
        with self._lock:
            self.admitted += 1
            self.admitted_rows += n_rows

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "name": self.name,
                "admitted": self.admitted,
                "shed": self.shed,
                "admitted_rows": self.admitted_rows,
                "shed_rows": self.shed_rows,
                "limits": {
                    "rate_rps": self.cfg.rate_rps,
                    "rows_per_s": self.cfg.rows_per_s,
                    "max_rows": self.cfg.max_rows,
                },
            }


class TenantTable:
    """API key → ``Tenant`` resolution.

    With no tenants configured the server is OPEN: every request maps
    to one implicit unlimited ``public`` tenant (the single-user dev
    loop should not need key management). With ANY tenant configured,
    authentication is mandatory — an unknown or missing key is 401,
    never a silent fall-through to public.
    """

    def __init__(self, tenants=None, *, clock=time.monotonic):
        self._by_key: dict[str, Tenant] = {}
        self._public = Tenant(TenantConfig(name="public", api_key=""),
                              clock=clock)
        for cfg in tenants or ():
            if cfg.api_key in self._by_key:
                raise ValueError(
                    f"duplicate api_key for tenant {cfg.name!r}"
                )
            self._by_key[cfg.api_key] = Tenant(cfg, clock=clock)

    @property
    def open(self) -> bool:
        return not self._by_key

    def resolve(self, api_key: str | None) -> Tenant:
        if self.open:
            return self._public
        if not api_key:
            raise Unauthenticated(
                f"missing {API_KEY_HEADER!r} header (server has tenants "
                f"configured)"
            )
        tenant = self._by_key.get(api_key)
        if tenant is None:
            raise Unauthenticated("unknown API key")
        return tenant

    def snapshot(self) -> dict:
        tenants = [self._public] if self.open else list(self._by_key.values())
        return {
            "open": self.open,
            "tenants": [t.snapshot() for t in tenants],
        }
