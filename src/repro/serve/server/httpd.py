"""Minimal asyncio HTTP/1.1 server for the ASGI app.

The container has no uvicorn/hypercorn, so this module adapts a TCP
byte stream onto the ASGI callable with the standard library only. It
is deliberately a SUBSET of HTTP/1.1 — exactly what the wire contract
needs and nothing speculative:

  * requests with ``Content-Length`` bodies (no chunked uploads; the
    JSON contract never needs them);
  * keep-alive with pipelined sequential requests per connection;
  * bounded header block (64 KiB) and body (``wire.MAX_BODY_BYTES``),
    closing the connection on violation — malformed framing gets a
    400 and a close, never a hang;
  * concurrency by asyncio task per connection; the app itself pushes
    blocking work to the executor, so one loop thread serves many
    in-flight requests (that overlap is what feeds the micro-batcher's
    coalescing window).

``serve(app)`` runs the loop in a daemon background thread and returns
a ``ServerHandle`` — tests and the example get a real localhost server
with two lines and no external process.
"""

from __future__ import annotations

import asyncio
import threading

from repro.serve.server.wire import MAX_BODY_BYTES

MAX_HEADER_BYTES = 64 << 10
_HTTP_STATUS_TEXT = {
    200: "OK", 201: "Created", 400: "Bad Request", 401: "Unauthorized",
    403: "Forbidden", 404: "Not Found", 405: "Method Not Allowed",
    413: "Payload Too Large", 429: "Too Many Requests",
    500: "Internal Server Error", 503: "Service Unavailable",
    504: "Gateway Timeout",
}


def _reason(status: int) -> str:
    return _HTTP_STATUS_TEXT.get(status, "Unknown")


async def _handle_connection(app, reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter) -> None:
    try:
        while True:
            try:
                head = await reader.readuntil(b"\r\n\r\n")
            except asyncio.IncompleteReadError:
                return                        # client closed between requests
            except asyncio.LimitOverrunError:
                writer.write(b"HTTP/1.1 400 Bad Request\r\n"
                             b"content-length: 0\r\nconnection: close\r\n\r\n")
                await writer.drain()
                return
            request_line, *header_lines = head.decode("latin-1").split("\r\n")
            try:
                method, target, _version = request_line.split(" ", 2)
            except ValueError:
                writer.write(b"HTTP/1.1 400 Bad Request\r\n"
                             b"content-length: 0\r\nconnection: close\r\n\r\n")
                await writer.drain()
                return
            headers = []
            for line in header_lines:
                if not line:
                    continue
                name, _, value = line.partition(":")
                headers.append((name.strip().lower().encode("latin-1"),
                                value.strip().encode("latin-1")))
            hmap = dict(headers)
            length = int(hmap.get(b"content-length", b"0") or 0)
            if length > MAX_BODY_BYTES:
                writer.write(b"HTTP/1.1 413 Payload Too Large\r\n"
                             b"content-length: 0\r\nconnection: close\r\n\r\n")
                await writer.drain()
                return
            body = await reader.readexactly(length) if length else b""
            path, _, query = target.partition("?")
            scope = {
                "type": "http",
                "asgi": {"version": "3.0", "spec_version": "2.3"},
                "http_version": "1.1",
                "method": method.upper(),
                "path": path,
                "raw_path": target.encode("latin-1"),
                "query_string": query.encode("latin-1"),
                "headers": headers,
            }
            messages = [
                {"type": "http.request", "body": body, "more_body": False}
            ]

            async def receive():
                if messages:
                    return messages.pop(0)
                return {"type": "http.disconnect"}

            state = {"status": 500, "headers": []}
            chunks: list[bytes] = []

            async def send(message):
                if message["type"] == "http.response.start":
                    state["status"] = message["status"]
                    state["headers"] = list(message.get("headers", ()))
                elif message["type"] == "http.response.body":
                    chunks.append(message.get("body", b""))

            await app(scope, receive, send)
            payload = b"".join(chunks)
            keep = hmap.get(b"connection", b"keep-alive").lower() != b"close"
            out = [f"HTTP/1.1 {state['status']} "
                   f"{_reason(state['status'])}\r\n".encode("latin-1")]
            has_length = False
            for name, value in state["headers"]:
                if name == b"content-length":
                    has_length = True
                out.append(name + b": " + value + b"\r\n")
            if not has_length:
                out.append(f"content-length: {len(payload)}\r\n"
                           .encode("latin-1"))
            out.append(b"connection: keep-alive\r\n" if keep
                       else b"connection: close\r\n")
            out.append(b"\r\n")
            out.append(payload)
            writer.write(b"".join(out))
            await writer.drain()
            if not keep:
                return
    except (ConnectionResetError, BrokenPipeError, asyncio.IncompleteReadError):
        return
    finally:
        try:
            writer.close()
        except Exception:                     # noqa: BLE001
            pass


class ServerHandle:
    """A running front door: ``host``/``port``/``url`` + ``close()``."""

    def __init__(self, host: str, port: int, loop, thread, server):
        self.host = host
        self.port = port
        self._loop = loop
        self._thread = thread
        self._server = server

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        if self._loop.is_closed():
            return

        async def _shutdown():
            self._server.close()
            await self._server.wait_closed()
            # idle keep-alive connections sit parked in readuntil();
            # cancel them so the loop stops clean instead of destroying
            # pending tasks
            me = asyncio.current_task()
            pending = [t for t in asyncio.all_tasks() if t is not me]
            for t in pending:
                t.cancel()
            await asyncio.gather(*pending, return_exceptions=True)

        asyncio.run_coroutine_threadsafe(_shutdown(), self._loop).result(10)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=10)
        self._loop.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def serve(app, host: str = "127.0.0.1", port: int = 0) -> ServerHandle:
    """Serve ``app`` on a background-thread event loop; returns a handle.

    ``port=0`` binds an ephemeral port (read it off the handle). The
    loop thread is a daemon: an un-closed handle never blocks process
    exit.
    """
    loop = asyncio.new_event_loop()
    started = threading.Event()
    box: dict = {}

    async def _start():
        server = await asyncio.start_server(
            lambda r, w: _handle_connection(app, r, w),
            host, port, limit=MAX_HEADER_BYTES,
        )
        box["server"] = server
        box["port"] = server.sockets[0].getsockname()[1]
        started.set()

    def _run():
        asyncio.set_event_loop(loop)
        loop.run_until_complete(_start())
        loop.run_forever()

    thread = threading.Thread(target=_run, name="repro-httpd", daemon=True)
    thread.start()
    if not started.wait(timeout=10):
        raise RuntimeError("HTTP server failed to start within 10s")
    return ServerHandle(host, box["port"], loop, thread, box["server"])
