"""Asyncio bridge over the runtime's future-based ``submit``.

``Runtime.submit`` returns a ``concurrent.futures.Future[SliceResult]``
and is itself mildly blocking (registry resolve, possibly a cold engine
build, queue admission under the batcher lock). The event loop must
block on none of that, and — the part that matters for throughput —
the deferred-sync contract must survive the hop: materializing
``.values`` triggers ONE device→host transfer shared by every request
coalesced into the same flush, so that sync has to happen off-loop too,
in a thread, where sibling requests amortize it.

The bridge is therefore three awaits, each with a reason:

  1. ``submit`` runs in the loop's default executor — admission sheds
     (``RuntimeOverloaded``) surface here, before anything is queued;
  2. the returned future is ``asyncio.wrap_future``-ed — zero threads
     parked while the micro-batcher waits for its flush window (a
     parked thread per in-flight request would cap coalescing at the
     executor's worker count);
  3. materialization runs back in the executor — the shared host sync
     never stalls the loop, and N coalesced requests pay for it once.
"""

from __future__ import annotations

import asyncio

import numpy as np


def _materialize(res) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(values, valid, labels) as host arrays — the one shared sync."""
    return (
        np.asarray(res.values),
        np.asarray(res.valid),
        np.asarray(res.labels),
    )


async def submit(runtime, model: str, Z, *, deadline_s: float | None = None):
    """Score ``Z`` on ``model`` without blocking the event loop.

    Returns ``(values, valid, labels)`` host arrays. Raises exactly
    what the runtime raises — ``RuntimeOverloaded`` at admission,
    ``DeadlineExceeded``/``BatcherClosed``/``ArtifactCorrupt`` out of
    the future — for the app's error mapper to translate.
    """
    loop = asyncio.get_running_loop()
    fut = await loop.run_in_executor(
        None, lambda: runtime.submit(model, Z, deadline_s=deadline_s)
    )
    res = await asyncio.wrap_future(fut)
    return await loop.run_in_executor(None, _materialize, res)
