"""HTTP front door over ``repro.serve.runtime.Runtime``.

Public surface: ``create_app`` builds the ASGI application,
``serve`` runs it on a background localhost server, ``TenantConfig``
declares per-tenant quotas. Everything else in this package is wiring.
"""

from repro.serve.server.app import App, create_app
from repro.serve.server.httpd import ServerHandle, serve
from repro.serve.server.tenancy import (
    TenantConfig,
    TenantQuotaExceeded,
    TenantTable,
    Unauthenticated,
)
from repro.serve.server.wire import InvalidRequest

__all__ = [
    "App",
    "InvalidRequest",
    "ServerHandle",
    "TenantConfig",
    "TenantQuotaExceeded",
    "TenantTable",
    "Unauthenticated",
    "create_app",
    "serve",
]
