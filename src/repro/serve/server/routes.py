"""Route handlers for the HTTP front door.

Every handler is ``async def handler(app, req, *path_params) ->
Response`` and raises ``ServingError`` subclasses for every refusal —
the app's single error mapper turns them into wire bodies, so no
handler ever builds an error response by hand.

Anything that takes a runtime lock or touches a device runs in the
loop's default executor via ``_off_loop``; the event loop only ever
shuffles parsed JSON.

The one subtle handler is ``predict``, whose ORDER of refusals is the
accounting contract:

  1. parse (400) — a malformed body is not a submitted request;
  2. authenticate (401) — an unknown key is nobody's traffic;
  3. resolve the ref (404) — sheds must attach to a real digest;
  4. tenant admission (429) — a quota shed is recorded into the
     digest's ``ModelTelemetry`` and traced as a ``request.shed`` span
     BEFORE the error propagates, so ``Tracer.conservation`` counts it
     exactly like a queue-full shed;
  5. ``bridge.submit`` — runtime refusals (429/503/504) flow through
     untouched; the batcher already accounted for them.
"""

from __future__ import annotations

import asyncio
import base64
import binascii
import hashlib
import os

from repro.serve.runtime.obs import trace
from repro.serve.runtime.publish import PublishSpec
from repro.serve.server import bridge, wire
from repro.serve.server.tenancy import TenantQuotaExceeded
from repro.serve.server.wire import InvalidRequest, Response


async def _off_loop(fn, *args):
    return await asyncio.get_running_loop().run_in_executor(
        None, lambda: fn(*args)
    )


def _json(obj, status: int = 200) -> Response:
    return Response(status=status, body=wire.dump_json(obj))


# ------------------------------------------------------------------ scoring

async def predict(app, req, ref: str) -> Response:
    data = wire.parse_json(req.body)
    Z, deadline_s = wire.parse_predict(data)
    n = int(Z.shape[0])
    tenant = app.tenants.resolve(req.headers.get("x-api-key"))
    digest = await _off_loop(app.runtime.registry.resolve, ref)
    try:
        tenant.admit(n)
    except TenantQuotaExceeded as e:
        await _off_loop(_account_tenant_shed, app.runtime, digest, n,
                        tenant.name, e.retry_after_s)
        raise
    values, valid, labels = await bridge.submit(
        app.runtime, digest, Z, deadline_s=deadline_s
    )
    entry = app.runtime.registry._entries.get(digest)
    engine = entry.engine if entry is not None else None
    return _json(wire.predict_response(
        digest, values, valid, labels,
        family=getattr(engine, "family", ""),
        dtype=getattr(engine, "dtype", ""),
    ))


def _account_tenant_shed(runtime, digest: str, rows: int, tenant: str,
                         retry_after_s: float) -> None:
    """A tenant-quota shed is a shed: same telemetry counter, same span
    name, same conservation identity as a queue-full shed."""
    runtime.telemetry(digest).record_shed(rows)
    if runtime.obs is not None:
        runtime.obs.tracer.span(
            digest[:12], trace.SHED,
            attrs={"rows": rows, "retry_after_s": retry_after_s,
                   "tenant": tenant, "reason": "tenant_quota"},
        )


# --------------------------------------------------------------- management

async def list_models(app, req) -> Response:
    models = await _off_loop(app.runtime.registry.list_models)
    return _json({"models": models})


async def publish(app, req) -> Response:
    """``POST /v1/models`` — publish an artifact, return its digest.

    Body: ``{"artifact_b64": <base64 npz bytes>, "spec": {...}}`` or
    ``{"path": <server-visible file>, "spec": {...}}``. Uploaded bytes
    are spooled to the app's spool directory and indexed via
    ``add_file`` so they get the same structural validation + content
    addressing as any on-disk artifact (a corrupt upload is rejected
    with 503 ``artifact_corrupt`` and never acquires an identity).
    """
    data = wire.parse_json(req.body)
    spec = PublishSpec.from_wire(data.get("spec") or {})
    if ("artifact_b64" in data) == ("path" in data):
        raise InvalidRequest(
            'expected exactly one of "artifact_b64" or "path"'
        )
    if "artifact_b64" in data:
        try:
            raw = base64.b64decode(data["artifact_b64"], validate=True)
        except (binascii.Error, TypeError) as e:
            raise InvalidRequest(f'"artifact_b64" is not base64: {e}') from e
        path = os.path.join(
            app.spool_dir, hashlib.sha256(raw).hexdigest() + ".npz"
        )
        if not os.path.exists(path):
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(raw)
            os.replace(tmp, path)            # atomic: no torn spool files
    else:
        path = str(data["path"])
        if not os.path.isfile(path):
            raise InvalidRequest(f"no such artifact file: {path}")
    digest = await _off_loop(app.runtime.registry.add_file, path, spec)
    return _json({"digest": digest, "spec": spec.to_wire()}, status=201)


async def set_alias(app, req, ref: str) -> Response:
    data = wire.parse_json(req.body)
    alias = data.get("alias")
    if not alias or not isinstance(alias, str):
        raise InvalidRequest('expected {"alias": "<name>"}')
    digest = await _off_loop(app.runtime.set_alias, alias, ref)
    return _json({"alias": alias, "digest": digest})


async def set_replicas(app, req, ref: str) -> Response:
    data = wire.parse_json(req.body)
    try:
        n = int(data["replicas"])
    except (KeyError, TypeError, ValueError) as e:
        raise InvalidRequest('expected {"replicas": <int >= 1>}') from e
    if n < 1:
        raise InvalidRequest(f"replicas must be >= 1, got {n}")
    digest = await _off_loop(app.runtime.registry.set_replicas, ref, n)
    return _json({"digest": digest, "replicas": n})


async def evict(app, req, ref: str) -> Response:
    digest = await _off_loop(app.runtime.registry.evict, ref)
    return _json({"digest": digest, "evicted": True})


# ------------------------------------------------------------ observability

async def stats(app, req, ref: str) -> Response:
    return _json(await _off_loop(app.runtime.stats, ref))

async def runtime_stats(app, req) -> Response:
    return _json(await _off_loop(app.runtime.stats))


async def metrics(app, req) -> Response:
    text = await _off_loop(app.runtime.render_prometheus)
    return Response(
        body=text.encode("utf-8"),
        content_type="text/plain; version=0.0.4; charset=utf-8",
    )


async def tenants(app, req) -> Response:
    return _json(app.tenants.snapshot())


async def healthz(app, req) -> Response:
    return _json({"ok": True})
