"""Pure-jnp oracle for Maclaurin (second-order) linear attention.

The paper's Eq 3.6 applied to attention: replace exp(u), u = q.k / sqrt(d),
by w(u) = 1 + u + u^2/2. w is positive (min 1/2 at u = -1), so the
normalizer is well-defined. Quadratic O(T^2) reference — the kernel must
match it exactly (it is the same math, chunked).
"""

from __future__ import annotations

import jax.numpy as jnp


def maclaurin_weights(u):
    """Second-order Maclaurin surrogate of exp(u) (Eq 3.6/A.1)."""
    return 1.0 + u + 0.5 * u * u


def maclaurin_attention_ref(q, k, v, scale=None):
    """Causal Maclaurin-attention. q,k: (..., T, d_k), v: (..., T, d_v)."""
    d_k = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d_k))
    T = q.shape[-2]
    u = jnp.einsum("...td,...sd->...ts", q, k) * scale
    w = maclaurin_weights(u)
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    w = jnp.where(causal, w, 0.0)
    num = jnp.einsum("...ts,...sv->...tv", w, v)
    den = jnp.sum(w, axis=-1)[..., None]
    return num / den


def softmax_attention_ref(q, k, v, scale=None):
    """Exact softmax attention — the 'exact model' the approximation targets."""
    d_k = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d_k))
    T = q.shape[-2]
    u = jnp.einsum("...td,...sd->...ts", q, k) * scale
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    u = jnp.where(causal, u, -jnp.inf)
    w = jnp.exp(u - jnp.max(u, axis=-1, keepdims=True))
    w = jnp.where(causal, w, 0.0)
    return jnp.einsum("...ts,...sv->...tv", w, v) / jnp.sum(w, axis=-1)[..., None]
