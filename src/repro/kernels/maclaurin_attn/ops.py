"""Jit'd public wrapper for chunked Maclaurin linear attention.

Accepts (batch, heads, T, d) layouts, flattens to (B*H, T, d) for the
kernel grid, and falls back to the quadratic jnp oracle when
``use_pallas=False``. Interpret mode on CPU. The chunk size travels as
``TileConfig.chunk`` (``None`` resolves the family default from the
tuning registry).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import TileConfig
from repro.kernels.maclaurin_attn.kernel import maclaurin_attention_pallas
from repro.kernels.maclaurin_attn.ref import maclaurin_attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("scale", "config", "use_pallas"))
def maclaurin_attention(
    q, k, v, scale: float | None = None,
    config: TileConfig | None = None, use_pallas: bool = True,
):
    """Causal Maclaurin attention. q,k: (B, H, T, d_k), v: (B, H, T, d_v)."""
    if not use_pallas:
        return maclaurin_attention_ref(q, k, v, scale=scale)
    b, h, t, d = q.shape
    dv = v.shape[-1]
    flat = lambda x: x.reshape(b * h, t, x.shape[-1])
    out = maclaurin_attention_pallas(
        flat(q), flat(k), flat(v), scale=scale, config=config, interpret=_on_cpu()
    )
    return out.reshape(b, h, t, dv).astype(v.dtype)
