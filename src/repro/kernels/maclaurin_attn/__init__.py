from repro.kernels.maclaurin_attn.ops import maclaurin_attention
from repro.kernels.maclaurin_attn.ref import (
    maclaurin_attention_ref,
    softmax_attention_ref,
    maclaurin_weights,
)

__all__ = [
    "maclaurin_attention",
    "maclaurin_attention_ref",
    "softmax_attention_ref",
    "maclaurin_weights",
]
