"""Pallas TPU kernel: chunked causal Maclaurin linear attention.

The paper's O(n_sv d) -> O(d^2) collapse (Eq 3.7) applied to decode-time
attention (DESIGN.md §4): with w(u) = 1 + u + u^2/2 and u = scale * q.k,

    sum_j w(u_tj) v_j = (sum v_j) + scale * q^T (sum k_j v_j^T)
                        + scale^2/2 * phi2(q)^T (sum phi2(k_j) v_j^T)

where phi2(x) = vec(x x^T) in R^{d^2}. The running sums are the paper's
(c, v, M) — order 0/1/2 moments of the stored set weighted by values.

Chunked schedule (Based-style, arXiv:2402.18668, re-derived for the TPU
memory hierarchy): grid = (batch*heads, T/Cs) with chunks innermost; the
inter-chunk moment state lives in VMEM scratch and persists across grid
steps (TPU grids execute sequentially per core). Each chunk does:

  intra: u = scale Q K^T (Cs x Cs MXU GEMM), causal-mask, accumulate
  inter: Q S1 and PHI2(Q) S2 GEMMs against the state
  state: S1 += K^T V; S2 += PHI2(K)^T V (MXU), plus the order-0/1/2 key sums

VMEM (f32, Cs=128, d=dv=128): S2 (d^2 x dv) 8 MB + PHI2 tile (Cs x d^2)
8 MB + S1/K/Q/V tiles < 1 MB -> ~17 MB peak; fits v5e VMEM. For d > 128,
tile S2 over a dv-grid axis (not needed for the assigned archs).

The chunk size comes from ``repro.kernels.common`` (``TileConfig.chunk``,
resolved by the tuning registry when the caller passes no config); the
sequence axis is padded with the shared ``tiles`` helpers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import TileConfig, tiles, tuning


def _phi2(x):
    """Row-wise vec(x x^T): (Cs, d) -> (Cs, d*d)."""
    cs, d = x.shape
    return (x[:, :, None] * x[:, None, :]).reshape(cs, d * d)


def _kernel(
    q_ref, k_ref, v_ref, o_ref,
    s1_ref, s2_ref, k1_ref, k2_ref, misc_ref,
    *, scale: float, chunk: int,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _reset():
        s1_ref[...] = jnp.zeros_like(s1_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)
        k1_ref[...] = jnp.zeros_like(k1_ref)
        k2_ref[...] = jnp.zeros_like(k2_ref)
        misc_ref[...] = jnp.zeros_like(misc_ref)

    q = q_ref[0]                       # (Cs, d)
    k = k_ref[0]                       # (Cs, d)
    v = v_ref[0]                       # (Cs, dv)
    cs = q.shape[0]

    # ---- intra-chunk (exact within the chunk) ----
    u = scale * jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (Cs, Cs)
    w = 1.0 + u + 0.5 * u * u
    rows = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    w = jnp.where(rows >= cols, w, 0.0)
    num = jax.lax.dot_general(
        w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (Cs, dv)
    den = jnp.sum(w, axis=-1)          # (Cs,)

    # ---- inter-chunk (paper's quadratic-form readout of the state) ----
    q2 = _phi2(q)                      # (Cs, d^2)
    n_prev = misc_ref[0, 0]            # count of previous tokens
    dv = o_ref.shape[-1]
    num = num + misc_ref[1:2, :dv]     # order-0 term: sum_prev v_j
    num = num + scale * jax.lax.dot_general(
        q, s1_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    num = num + (0.5 * scale * scale) * jax.lax.dot_general(
        q2, s2_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    den = den + n_prev
    den = den + scale * (q @ k1_ref[0, :])
    den = den + (0.5 * scale * scale) * (q2 @ k2_ref[0, :])

    o_ref[0] = num / den[:, None]

    # ---- state update (after readout: chunk c's keys are 'previous' for c+1) ----
    k2feat = _phi2(k)                  # (Cs, d^2)
    s1_ref[...] += jax.lax.dot_general(
        k, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # K^T V: (d, dv)
    s2_ref[...] += jax.lax.dot_general(
        k2feat, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (d^2, dv)
    k1_ref[0, :] += jnp.sum(k, axis=0)
    k2_ref[0, :] += jnp.sum(k2feat, axis=0)
    misc_ref[0, 0] += jnp.float32(cs)
    misc_ref[1:2, :v.shape[-1]] += jnp.sum(v, axis=0)[None, :]


def maclaurin_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    config: TileConfig | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q, k: (BH, T, d_k); v: (BH, T, d_v). Causal. Returns (BH, T, d_v)."""
    config = config or tuning.lookup("maclaurin_attn")
    bh, t, d = q.shape
    dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    chunk = min(config.chunk, t)
    t_pad = tiles.round_up(t, chunk)
    qp = tiles.pad_axis(q, 1, t_pad)
    kp = tiles.pad_axis(k, 1, t_pad)
    vp = tiles.pad_axis(v, 1, t_pad)
    n_chunks = t_pad // chunk
    misc_cols = max(dv, 2)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=float(scale), chunk=chunk),
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t_pad, dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((d, dv), jnp.float32),        # S1
            pltpu.VMEM((d * d, dv), jnp.float32),    # S2
            pltpu.VMEM((1, d), jnp.float32),         # sum k
            pltpu.VMEM((1, d * d), jnp.float32),     # sum phi2(k)
            pltpu.VMEM((2, misc_cols), jnp.float32), # [count | sum v]
        ],
        interpret=interpret,
    )(qp.astype(jnp.float32), kp.astype(jnp.float32), vp.astype(jnp.float32))
    return out[:, :t, :]
