"""Pure-jnp oracle for the fused random-Fourier-feature scoring kernel.

The fourier family serves

    f_k(z) = w_k . cos(W z + p) + b_k

where W (n_feat, d) are the sampled frequencies, p (n_feat,) the phases
and w_k the per-head weights with the 2 / n_feat feature scaling already
folded in at compile time (see ``repro.core.families.fourier``). The
oracle is the obviously-correct three-op formulation the fused kernel and
the XLA backend path are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp


def rff_score_ref(Z, W, phase, weights, bias):
    """Z: (n, d), W: (F, d), phase: (F,), weights: (K, F), bias: (K,).

    Returns per-head scores (n, K).
    """
    proj = Z @ W.T + phase[None, :]          # (n, F)
    phi = jnp.cos(proj)                      # feature scale folded into weights
    return phi @ weights.T + bias[None, :]   # (n, K)
