from repro.kernels.rff_score.kernel import rff_score_pallas
from repro.kernels.rff_score.ref import rff_score_ref

__all__ = ["rff_score_pallas", "rff_score_ref"]
