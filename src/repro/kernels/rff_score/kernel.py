"""Pallas TPU kernel: fused random-Fourier-feature scoring.

For the fourier approximation family (random Fourier features of the
Gaussian kernel), each serving step is

    scores[z, k] = sum_f weights[k, f] * cos(W[f, :] . z + phase[f]) + b[k]

i.e. one (BN, d) @ (d, F) MXU projection, a VPU cos, and one thin
(BN, F) @ (F, K) contraction against the per-head weights — fused per Z
tile so the (BN, F) feature block never leaves VMEM (the XLA formulation
materializes phi in HBM between the two GEMMs; see
``repro.core.backend.rff_score_xla``).

Schedule: grid = (n_tiles,) over Z tiles only. W, phase and weights are
resident in VMEM across the whole batch (one HBM read each): per-step
working set is F*(d + K + 1) + BN*(d + F + K) f32 — at F = 2048, d <= 896,
BN = 256, K <= 16 that is ~10 MB, inside a v5e core's VMEM. Models whose
F*d alone busts VMEM should lower ``TileConfig.block_n`` or serve the
XLA path; a feature-axis grid (accumulating over F blocks) is the
designated follow-up if such artifacts show up.

Padding contract (what makes the fused path exact): padded feature rows
have ZERO weight columns, so their cos(0 + 0) = 1 contribution is
multiplied away; padded d columns are zero in both Z and W (dots exact);
padded batch rows are sliced off; padded heads carry zero weights/bias
and are sliced off.

Block sizes come from ``repro.kernels.common`` (``TileConfig.block_n``),
resolved per shape bucket by the tuning registry under the ``rff_score``
kernel name.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import TileConfig, tiles, tuning


def _kernel(z_ref, w_ref, p_ref, wt_ref, b_ref, o_ref):
    z = z_ref[...]                           # (BN, d)
    w = w_ref[...]                           # (F, d) resident
    phase = p_ref[...]                       # (F,)
    wt = wt_ref[...]                         # (K, F) resident
    bias = b_ref[...]                        # (K,)
    proj = jax.lax.dot_general(
        z, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (BN, F) MXU
    phi = jnp.cos(proj + phase[None, :])     # VPU, never leaves VMEM
    scores = jax.lax.dot_general(
        phi, wt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (BN, K) MXU
    o_ref[...] = scores + bias[None, :]


def _kernel_q8(z_ref, w_ref, ws_ref, p_ref, wt_ref, wts_ref, b_ref, o_ref):
    """Int8-weights variant: the projection matrix and the per-head
    readout are int8; both quantized axes are OUTPUT axes of their GEMMs
    (feature rows for W, heads for the readout), so dequantization folds
    onto the small results — two VPU multiplies, no f32 weight copy."""
    z = z_ref[...]                           # (BN, d) f32
    w = w_ref[...]                           # (F, d) int8, resident
    w_scale = ws_ref[...]                    # (F,) per-feature-row scales
    phase = p_ref[...]                       # (F,)
    wt = wt_ref[...]                         # (K, F) int8, resident
    wt_scale = wts_ref[...]                  # (K,) per-head scales
    bias = b_ref[...]                        # (K,)
    proj = jax.lax.dot_general(
        z, w.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * w_scale[None, :]                     # fold row scales post-GEMM
    phi = jnp.cos(proj + phase[None, :])     # VPU, never leaves VMEM
    scores = jax.lax.dot_general(
        phi, wt.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * wt_scale[None, :]                    # fold head scales post-GEMM
    o_ref[...] = scores + bias[None, :]


def rff_score_q8_pallas(
    Z: jax.Array,
    W_q: jax.Array,
    w_scale: jax.Array,
    phase: jax.Array,
    weights_q: jax.Array,
    wt_scale: jax.Array,
    bias: jax.Array,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused RFF scores off int8 weights. Z: (n, d), W_q: (F, d) int8 with
    w_scale (F,), weights_q: (K, F) int8 with wt_scale (K,), phase (F,)
    and bias (K,) f32. Returns (n, K) — same contract as
    ``rff_score_pallas`` at a quarter of the resident-weight footprint.

    Padding keeps the f32 contract: padded feature rows are zero codes
    with zero weight columns (their cos(0)=1 is multiplied away); padded
    scales are zero, which only ever multiplies padded output."""
    config = config or tuning.lookup("rff_score_q8")
    n, d = Z.shape
    f, k = W_q.shape[0], weights_q.shape[0]
    config = config.clamp_block_n(n)
    block_n = config.block_n

    d_pad = tiles.lane_pad(d)
    f_pad = tiles.lane_pad(f)
    k_pad = max(tiles.SUBLANE, tiles.round_up(k, tiles.SUBLANE))
    n_pad = tiles.round_up(n, block_n)

    Zp = tiles.pad_tail(Z.astype(jnp.float32), n_pad, d_pad)
    Wp = tiles.pad_tail(W_q.astype(jnp.int8), f_pad, d_pad)
    wsp = tiles.pad_axis(w_scale.astype(jnp.float32), 0, f_pad)
    pp = tiles.pad_axis(phase.astype(jnp.float32), 0, f_pad)
    wtp = tiles.pad_tail(weights_q.astype(jnp.int8), k_pad, f_pad)
    wtsp = tiles.pad_axis(wt_scale.astype(jnp.float32), 0, k_pad)
    bp = tiles.pad_axis(bias.astype(jnp.float32), 0, k_pad)

    out = pl.pallas_call(
        _kernel_q8,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((f_pad, d_pad), lambda i: (0, 0)),   # resident
            pl.BlockSpec((f_pad,), lambda i: (0,)),
            pl.BlockSpec((f_pad,), lambda i: (0,)),
            pl.BlockSpec((k_pad, f_pad), lambda i: (0, 0)),   # resident
            pl.BlockSpec((k_pad,), lambda i: (0,)),
            pl.BlockSpec((k_pad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(Zp, Wp, wsp, pp, wtp, wtsp, bp)
    return out[:n, :k]


def rff_score_pallas(
    Z: jax.Array,
    W: jax.Array,
    phase: jax.Array,
    weights: jax.Array,
    bias: jax.Array,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused RFF scores. Z: (n, d), W: (F, d), phase: (F,), weights: (K, F),
    bias: (K,). Returns (n, K) per-head scores."""
    config = config or tuning.lookup("rff_score")
    n, d = Z.shape
    f, k = W.shape[0], weights.shape[0]
    config = config.clamp_block_n(n)
    block_n = config.block_n

    d_pad = tiles.lane_pad(d)
    f_pad = tiles.lane_pad(f)
    k_pad = max(tiles.SUBLANE, tiles.round_up(k, tiles.SUBLANE))
    n_pad = tiles.round_up(n, block_n)

    Zp = tiles.pad_tail(Z.astype(jnp.float32), n_pad, d_pad)
    Wp = tiles.pad_tail(W.astype(jnp.float32), f_pad, d_pad)
    pp = tiles.pad_axis(phase.astype(jnp.float32), 0, f_pad)
    wtp = tiles.pad_tail(weights.astype(jnp.float32), k_pad, f_pad)
    bp = tiles.pad_axis(bias.astype(jnp.float32), 0, k_pad)

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((f_pad, d_pad), lambda i: (0, 0)),   # resident
            pl.BlockSpec((f_pad,), lambda i: (0,)),
            pl.BlockSpec((k_pad, f_pad), lambda i: (0, 0)),   # resident
            pl.BlockSpec((k_pad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(Zp, Wp, pp, wtp, bp)
    return out[:n, :k]
