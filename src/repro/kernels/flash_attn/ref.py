"""Pure-jnp oracle for the fused softmax-attention kernel."""

from repro.kernels.maclaurin_attn.ref import softmax_attention_ref

__all__ = ["softmax_attention_ref"]
