from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import softmax_attention_ref

__all__ = ["flash_attention", "softmax_attention_ref"]
