"""Pallas TPU kernel: fused causal softmax attention (flash / online-softmax).

This is the fusion the roofline analysis calls for (EXPERIMENTS.md §Perf):
the unfused blockwise attention's (chunk x S) score slabs account for most
of the memory term on every train/prefill cell; keeping score tiles in VMEM
removes that HBM traffic entirely.

Algorithm (FlashAttention, re-tiled for the TPU memory hierarchy):
grid = (batch*heads, q_blocks, kv_blocks), kv innermost. Running
(m, l, acc) online-softmax state lives in VMEM scratch and persists across
kv steps; each step is one (bq x d)x(d x bk) MXU GEMM + VPU epilogue:

    s    = q k^T * scale                (MXU)
    m'   = max(m, rowmax(s))
    p    = exp(s - m')                  l' = l e^{m-m'} + rowsum(p)
    acc  = acc e^{m-m'} + p v           (MXU)
    out  = acc / l  at the last kv step

VMEM per step (f32): q/k/v tiles (bq+2bk) x d + acc bq x d + s bq x bk —
with bq=bk=256, d<=128: ~0.8 MB. Causally-skipped kv blocks are masked
(grid still visits them; a production variant would prune the grid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, bq: int, bk: int, kv_blocks: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]                                   # (bk, dv)
    s = scale * jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (bq, bk)
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    scale: float | None = None, causal: bool = True,
    block_q: int = 256, block_k: int = 256, interpret: bool = False,
) -> jax.Array:
    """q,k: (BH, T, d); v: (BH, T, dv). Returns (BH, T, dv)."""
    bh, t, d = q.shape
    dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    bq, bk = min(block_q, t), min(block_k, t)
    t_pad = -(-t // bq) * bq
    s_pad = -(-t // bk) * bk
    pad_t, pad_s = t_pad - t, s_pad - t
    qp = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0)))
    # padded KEY positions must never win the softmax: they sit at
    # cols > any real row, so the causal mask removes them for real rows.
    assert causal or pad_s == 0, "non-causal padding needs an explicit mask"
    q_blocks, kv_blocks = t_pad // bq, s_pad // bk

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=float(scale), bq=bq, bk=bk,
            kv_blocks=kv_blocks, causal=causal,
        ),
        grid=(bh, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t_pad, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :t, :]
