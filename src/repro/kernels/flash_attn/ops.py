"""Jit'd public wrapper for fused flash attention (GQA layout aware)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import softmax_attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("scale", "causal", "block_q", "block_k", "use_pallas"))
def flash_attention(
    q, k, v, scale: float | None = None, causal: bool = True,
    block_q: int = 256, block_k: int = 256, use_pallas: bool = True,
):
    """Causal fused attention. q,k: (B, H, T, d); v: (B, H, T, dv).

    GQA callers repeat kv heads to q heads before the call (cheap: the
    repeat is a broadcast, never materialized by XLA)."""
    if not use_pallas:
        return softmax_attention_ref(q, k, v, scale=scale)
    b, h, t, d = q.shape
    dv = v.shape[-1]
    flat = lambda x: x.reshape(b * h, t, x.shape[-1])
    out = flash_attention_pallas(
        flat(q), flat(k), flat(v), scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, interpret=_on_cpu(),
    )
    return out.reshape(b, h, t, dv)
