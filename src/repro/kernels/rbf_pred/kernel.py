"""Pallas TPU kernel: exact RBF-expansion prediction, streaming over SVs
with DOUBLE-BUFFERED support-vector tiles.

Computes f(Z) = sum_i a_i exp(-gamma ||x_i - z||^2) + b without ever
materializing the (n x n_sv) kernel matrix in HBM (flash-attention-style
online accumulation). The pairwise distance is produced by one MXU GEMM
per (z-tile, sv-tile):

    d2 = ||z||^2 + ||x||^2 - 2 Z X^T

Schedule: grid = (n_tiles,) over Z tiles only. The SV matrix and its
coefficients stay in HBM (``memory_space=ANY``) and are streamed through
a 2-slot VMEM scratch by explicit async copies — while tile j is in the
MXU, tile j+1 is already in flight (the double-buffer pattern from the
Pallas guide), so the SV stream hides its own HBM latency instead of
serializing DMA-then-compute per tile. The per-Z-tile accumulator is a
fori_loop carry in registers; the output block is written once.

VMEM working set per step (f32): BN*d (Z tile) + 2*BM*d (X slots) +
2*BM (alpha slots) + BN*BM (scores) — with BN=BM=256, d<=2048: ~6.5 MB,
comfortably within a v5e core's VMEM.

Block sizes come from ``repro.kernels.common`` (``TileConfig.block_n`` /
``block_m``), resolved per shape bucket by the tuning registry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import TileConfig, tiles, tuning


def _kernel(x_hbm, a_hbm, z_ref, p_ref, o_ref, x_slots, a_slots, sem_x, sem_a,
            *, m_tiles: int, block_m: int):
    z = z_ref[...]                      # (BN, d) resident for this grid step
    p = p_ref[...]                      # (2,): gamma, bias — traced operands,
    gamma, bias = p[0], p[1]            # not baked Python floats (jit-able)
    z_sq = jnp.sum(z * z, axis=-1)      # (BN,)

    def copy_x(slot, j):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(j * block_m, block_m)], x_slots.at[slot], sem_x.at[slot]
        )

    def copy_a(slot, j):
        return pltpu.make_async_copy(
            a_hbm.at[pl.ds(j * block_m, block_m)], a_slots.at[slot], sem_a.at[slot]
        )

    copy_x(0, 0).start()                # warm up: first SV tile in flight
    copy_a(0, 0).start()

    def body(j, acc):
        slot = j % 2
        nxt = (j + 1) % 2

        @pl.when(j + 1 < m_tiles)
        def _prefetch():                # overlap: next tile DMAs during compute
            copy_x(nxt, j + 1).start()
            copy_a(nxt, j + 1).start()

        copy_x(slot, j).wait()
        copy_a(slot, j).wait()
        x = x_slots[slot]               # (BM, d)
        a = a_slots[slot]               # (BM,)
        x_sq = jnp.sum(x * x, axis=-1)
        # MXU GEMM + VPU epilogue, all in VMEM.
        dots = jax.lax.dot_general(
            z, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                               # (BN, BM)
        d2 = jnp.maximum(z_sq[:, None] + x_sq[None, :] - 2.0 * dots, 0.0)
        return acc + jnp.exp(-gamma * d2) @ a

    acc = jax.lax.fori_loop(0, m_tiles, body, jnp.zeros_like(o_ref))
    o_ref[...] = acc + bias


def rbf_predict_pallas(
    Z: jax.Array,
    X: jax.Array,
    alpha_y: jax.Array,
    gamma: float,
    b: float,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Padded + tiled pallas_call wrapper. Z: (n, d), X: (m, d), a: (m,)."""
    config = config or tuning.lookup("rbf_pred")
    n, d = Z.shape
    m = X.shape[0]
    config = config.clamp_block_n(n)
    block_n, block_m = config.block_n, config.block_m

    # Pad: d to lane multiple (zeros preserve norms/dots), m to block
    # (alpha=0 rows contribute exactly 0), n to block (rows sliced off).
    d_pad = tiles.lane_pad(d)
    n_pad = tiles.round_up(n, block_n)
    m_pad = tiles.round_up(m, block_m)
    Zp = tiles.pad_tail(Z, n_pad, d_pad)
    Xp = tiles.pad_tail(X, m_pad, d_pad)
    ap = tiles.pad_axis(alpha_y, 0, m_pad)
    params = jnp.stack(
        [jnp.asarray(gamma, jnp.float32), jnp.asarray(b, jnp.float32)]
    )                                                       # (2,)

    m_tiles = m_pad // block_m
    out = pl.pallas_call(
        functools.partial(_kernel, m_tiles=m_tiles, block_m=block_m),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),           # X stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),           # alpha stays in HBM
            pl.BlockSpec((block_n, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, block_m, d_pad), jnp.float32),   # X double buffer
            pltpu.VMEM((2, block_m), jnp.float32),          # alpha double buffer
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(Xp.astype(jnp.float32), ap.astype(jnp.float32), Zp.astype(jnp.float32), params)
    return out[:n]
