"""Pallas TPU kernel: exact RBF-expansion prediction, streaming over SVs.

Computes f(Z) = sum_i a_i exp(-gamma ||x_i - z||^2) + b without ever
materializing the (n x n_sv) kernel matrix in HBM (flash-attention-style
online accumulation). The pairwise distance is produced by one MXU GEMM per
(z-tile, sv-tile):

    d2 = ||z||^2 + ||x||^2 - 2 Z X^T

Grid: (n_tiles, m_tiles), SV dimension innermost so each z-tile's
accumulator lives in the revisited output block.

VMEM working set per step (f32): BN*d (Z tile) + BM*d (X tile) + BN*BM
(scores) + BN (acc) — with BN=BM=256, d<=2048: ~4.5 MB, comfortably within
a v5e core's VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, x_ref, a_ref, p_ref, o_ref, *, m_tiles: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    z = z_ref[...]                      # (BN, d)
    x = x_ref[...]                      # (BM, d)
    a = a_ref[...]                      # (BM,)
    p = p_ref[...]                      # (2,): gamma, bias — traced operands,
    gamma, bias = p[0], p[1]            # not baked Python floats (jit-able)
    z_sq = jnp.sum(z * z, axis=-1)      # (BN,)
    x_sq = jnp.sum(x * x, axis=-1)      # (BM,)
    # MXU GEMM + VPU epilogue, all in VMEM.
    dots = jax.lax.dot_general(
        z, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                   # (BN, BM)
    d2 = jnp.maximum(z_sq[:, None] + x_sq[None, :] - 2.0 * dots, 0.0)
    contrib = jnp.exp(-gamma * d2) @ a  # (BN,)
    o_ref[...] += contrib

    @pl.when(j == m_tiles - 1)
    def _finalize():
        o_ref[...] += bias


def rbf_predict_pallas(
    Z: jax.Array,
    X: jax.Array,
    alpha_y: jax.Array,
    gamma: float,
    b: float,
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Padded + tiled pallas_call wrapper. Z: (n, d), X: (m, d), a: (m,)."""
    n, d = Z.shape
    m = X.shape[0]

    # Pad: d to lane multiple (zeros preserve norms/dots), m to block
    # (alpha=0 rows contribute exactly 0), n to block (rows sliced off).
    d_pad = max(128, -(-d // 128) * 128)
    n_pad = -(-n // block_n) * block_n
    m_pad = -(-m // block_m) * block_m
    Zp = jnp.pad(Z, ((0, n_pad - n), (0, d_pad - d)))
    Xp = jnp.pad(X, ((0, m_pad - m), (0, d_pad - d)))
    ap = jnp.pad(alpha_y, (0, m_pad - m))
    params = jnp.stack(
        [jnp.asarray(gamma, jnp.float32), jnp.asarray(b, jnp.float32)]
    )                                                       # (2,)

    n_tiles, m_tiles = n_pad // block_n, m_pad // block_m
    out = pl.pallas_call(
        functools.partial(_kernel, m_tiles=m_tiles),
        grid=(n_tiles, m_tiles),
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(Zp.astype(jnp.float32), Xp.astype(jnp.float32), ap.astype(jnp.float32), params)
    return out[:n]
