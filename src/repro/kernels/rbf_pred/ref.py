"""Pure-jnp oracle for the exact RBF prediction kernel."""

from __future__ import annotations

import jax.numpy as jnp


def rbf_predict_ref(Z, X, alpha_y, gamma, b):
    """f(Z) = sum_i alpha_y_i exp(-gamma ||x_i - z||^2) + b.

    Z: (n, d), X: (m, d), alpha_y: (m,), gamma/b scalars. Returns (n,).
    """
    z_sq = jnp.sum(Z * Z, axis=-1)[:, None]
    x_sq = jnp.sum(X * X, axis=-1)[None, :]
    d2 = jnp.maximum(z_sq + x_sq - 2.0 * (Z @ X.T), 0.0)
    return jnp.exp(-gamma * d2) @ alpha_y + b
