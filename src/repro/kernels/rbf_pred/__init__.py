from repro.kernels.rbf_pred.ops import rbf_predict
from repro.kernels.rbf_pred.ref import rbf_predict_ref

__all__ = ["rbf_predict", "rbf_predict_ref"]
