"""Jit'd public wrapper (shim) for the exact-RBF prediction kernel.

On CPU (this container) the Pallas body runs in interpret mode; on TPU the
same BlockSpecs compile natively. ``use_pallas=False`` falls back to the
jnp oracle (what XLA fuses on its own) — the Table-2 benchmark compares
both.  Process-level Pallas-vs-XLA routing for the serving path lives in
``repro.core.backend``; this shim pins the path explicitly for A/B runs.

Block sizes travel as a ``TileConfig`` (``None`` resolves the rbf_pred
default from the tuning registry). ``gamma`` and ``b`` are TRACED
arguments (array operands of the kernel), so this composes with outer
jits over SVMModel pytrees without retracing.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.common import TileConfig
from repro.kernels.rbf_pred.kernel import rbf_predict_pallas
from repro.kernels.rbf_pred.ref import rbf_predict_ref


def _off_tpu() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("use_pallas", "config"))
def rbf_predict(
    Z,
    X,
    alpha_y,
    gamma,
    b,
    use_pallas: bool = True,
    config: TileConfig | None = None,
):
    if use_pallas:
        return rbf_predict_pallas(
            Z, X, alpha_y, gamma, b, config=config, interpret=_off_tpu()
        )
    return rbf_predict_ref(Z, X, alpha_y, gamma, b)
