"""Reference (XLA) formulation of the fused Fastfood scoring path.

The Fastfood construction (Le et al. 2013) replaces the dense RFF
projection W (F, d) with ``stacks`` structured operators

    V_s = S_s H G_s Pi_s H B_s        (each d' = 2^ceil(log2 d) wide)

where B (signs), G (Gaussian) and S (chi row-norm correction) are
diagonal, Pi is a permutation and H is the (unnormalized) Hadamard
matrix applied via the Walsh-Hadamard transform — O(d' log d') adds per
row instead of O(d'^2) multiplies. These functions are the algebraic
ground truth the Pallas kernel in ``kernel.py`` must match: the backend
dispatches to them on CPU/GPU (``repro.core.backend.fastfood_score*``)
and the tests assert Pallas-vs-XLA agreement through them.

One transform, two schedules: ``fwht`` is the radix-2 butterfly the
Pallas kernel unrolls on VMEM-resident tiles (VPU adds); ``fwht_xla`` is
the same H x through Sylvester's Kronecker factorization as two small
dense GEMMs, which XLA's CPU/GPU matmul paths run ~2x faster than the
concat-per-stage butterfly (each butterfly stage materializes the full
(n, d') array). ``fastfood_project`` — the XLA dispatch target and the
oracle the Pallas parity tests compare against — uses ``fwht_xla``; the
tests pin both formulations to the explicit Hadamard matrix.
"""

from __future__ import annotations

from functools import lru_cache

import numpy as np

import jax
import jax.numpy as jnp


def fwht(x):
    """Unnormalized Walsh-Hadamard transform over the last axis (a power
    of two): H x with H entries +-1, H^T H = d I. O(d log d) adds.

    The loop is the classic radix-2 butterfly vectorized as a
    reshape/concat per stage: at half-size h the vector splits into
    (d // 2h) blocks of [lo | hi] pairs that recombine as
    [lo + hi | lo - hi]. ``d`` is static, so the log2(d) stages unroll
    at trace time — inside a Pallas kernel each stage is VPU adds on a
    resident tile.
    """
    d = x.shape[-1]
    shape = x.shape
    y = x.reshape(-1, d)
    h = 1
    while h < d:
        y = y.reshape(-1, d // (2 * h), 2, h)
        y = jnp.concatenate([y[:, :, 0] + y[:, :, 1], y[:, :, 0] - y[:, :, 1]],
                            axis=-1)
        y = y.reshape(-1, d)
        h *= 2
    return y.reshape(shape)


@lru_cache(maxsize=None)
def _hadamard(m: int) -> np.ndarray:
    """Sylvester Hadamard matrix H_m (m a power of two), +-1 entries."""
    H = np.array([[1.0]], dtype=np.float32)
    while H.shape[0] < m:
        H = np.block([[H, H], [H, -H]])
    return H


def fwht_xla(x):
    """The same H x as ``fwht`` on an XLA-friendly schedule.

    Sylvester's construction gives H_{2^k} = H_{2^a} (x) H_{2^b} for any
    a + b = k, so with the last axis reshaped to (2^a, 2^b) the transform
    is Ha @ X @ Hb — two dense GEMMs against tiny +-1 matrices (balanced
    split: 32x32 at d' = 1024). O(d' (2^a + 2^b)) multiply-adds per row
    instead of the butterfly's O(d' log d') adds, but it runs through the
    optimized matmul path with no per-stage materialization, which is the
    faster trade everywhere except inside the Pallas kernel.
    """
    d = x.shape[-1]
    k = max(0, d.bit_length() - 1)
    da = 1 << (k - k // 2)
    db = d // da
    Ha = jnp.asarray(_hadamard(da))
    Hb = jnp.asarray(_hadamard(db))
    y = x.reshape(-1, da, db)
    y = jnp.einsum("ab,nbc,cd->nad", Ha, y, Hb)
    return y.reshape(x.shape)


def fastfood_project(Z, B, G, perm, scale):
    """Z (n, d) -> (n, F) via the per-stack structured transform (no W).

    B/G/scale: (stacks, d') diagonals; perm: (stacks, d') int. Z is
    zero-padded to d' (exact: the B sign flip of a zero column is zero).
    """
    dd = B.shape[-1]
    n = Z.shape[0]
    Zp = jnp.pad(Z, ((0, 0), (0, dd - Z.shape[1])))

    def one_stack(b, g, p, s):
        t = fwht_xla(Zp * b[None, :])
        t = jnp.take(t, p, axis=1)
        t = fwht_xla(t * g[None, :])
        return t * s[None, :]

    proj = jax.vmap(one_stack, in_axes=(0, 0, 0, 0), out_axes=1)(B, G, perm, scale)
    return proj.reshape(n, -1)                                 # (n, stacks*dd)


def fastfood_score_ref(Z, B, G, perm, scale, phase, weights, bias):
    """Structured-projection RFF scores: (n, K) = cos(proj + phase) @ W^T + b.

    The f32 oracle for both backend paths: ``fastfood_project`` then the
    thin per-head readout, with the 2/F feature scaling already folded
    into ``weights`` at compile time.
    """
    proj = fastfood_project(jnp.asarray(Z, jnp.float32), B, G, perm, scale)
    phi = jnp.cos(proj + phase[None, :])
    return phi @ weights.T + bias[None, :]


def fastfood_score_q8_ref(
    Z, b_q, g_q, perm, s_q, stack_scale, phase, weights_q, wt_scale, bias
):
    """Int8-operator oracle: dequantize everything to f32, then score.

    ``stack_scale`` is the per-stack product of the G and S row scales —
    both diagonals multiply elementwise on the SAME output columns
    (fwht(t * g_q * gs) * s_q * ss == (fwht(t * g_q) * s_q) * (gs * ss)),
    so one fold per stack on the transform output reconstructs both.
    """
    B = b_q.astype(jnp.float32)                                # signs, exact
    G = g_q.astype(jnp.float32)
    S = s_q.astype(jnp.float32) * stack_scale[:, None]
    proj = fastfood_project(
        jnp.asarray(Z, jnp.float32), B, G, perm.astype(jnp.int32), S
    )
    phi = jnp.cos(proj + phase.astype(jnp.float32)[None, :])
    scores = (phi @ weights_q.astype(jnp.float32).T) * wt_scale[None, :]
    return scores + bias[None, :]
