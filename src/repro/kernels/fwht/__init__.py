from repro.kernels.fwht.kernel import (
    fastfood_score_pallas,
    fastfood_score_q8_pallas,
)
from repro.kernels.fwht.ref import (
    fastfood_project,
    fastfood_score_q8_ref,
    fastfood_score_ref,
    fwht,
    fwht_xla,
)

__all__ = [
    "fastfood_project",
    "fastfood_score_pallas",
    "fastfood_score_q8_pallas",
    "fastfood_score_q8_ref",
    "fastfood_score_ref",
    "fwht",
    "fwht_xla",
]
