"""Pallas TPU kernel: fused Fastfood (structured RFF) scoring.

For the fourier family's ``structured=True`` artifacts each serving step
is, per stack s of d' = 2^ceil(log2 d) features,

    proj_s = fwht(fwht(z * B_s)[Pi_s] * G_s) * S_s          (VPU butterflies)
    scores = cos(concat_s proj_s + phase) @ weights.T + b   (one thin MXU GEMM)

fused per Z tile so neither the (BN, d') transform intermediates nor the
(BN, F) feature block ever leave VMEM. The Walsh-Hadamard transform is
log2(d') statically-unrolled butterfly stages of adds/subtracts on the
resident tile — exactly the shifts-and-adds workload the VPU exists for;
the only MXU work left is the (BN, F) @ (F, K) readout.

Schedule: grid = (n_tiles,) over Z tiles only, like ``rff_score``. The
diagonal operators are O(F) and stay resident in VMEM across the whole
batch together with phase and the (K, F) readout: per-step working set is
F*(4 + K) + BN*(2 d' + F + K) f32-equivalents — at F = 2048, d' = 1024,
BN = 256, K = 16 that is ~4 MB, far inside a v5e core's VMEM (the dense
``rff_score`` needs F*d more for W; the structured path's whole point is
that it does not).

Algebraic identity: the stage arithmetic is ``ref.fwht``; the XLA
backend formulation computes the same H x through ``ref.fwht_xla``
(Kronecker-factored GEMMs — the faster schedule outside Pallas), and the
parity tests pin both to the explicit Sylvester Hadamard matrix.

Padding contract: Z's feature columns zero-pad to d' (a sign flip of
zero is zero, and H @ [x; 0] columns contribute nothing to the dots);
batch rows pad to a block multiple and are sliced off; heads pad to a
sublane multiple with zero weights/bias and are sliced off. F = stacks*d'
needs no padding by construction. d' < 128 lanes (models with d <= 64)
compiles but underfills the lane tile — small-d models should prefer the
dense path anyway (d^2 is tiny there).

The permutation is applied with ``jnp.take`` along the lane axis against
the resident int32 index rows — supported natively in interpret mode and
by Mosaic's dynamic-gather lowering on current TPU toolchains.

Block sizes come from ``TileConfig.block_n``, resolved per shape bucket
by the tuning registry under the ``fwht`` / ``fwht_q8`` kernel names.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import TileConfig, tiles, tuning
from repro.kernels.fwht.ref import fwht


def _transform(z, B, G, P, S):
    """The per-stack structured transform on a resident (BN, d') tile.

    Static Python loop over stacks — each iteration is 2 log2(d')
    butterfly stages + 3 diagonal multiplies + 1 lane gather, all VPU
    work on VMEM-resident data. Returns the concatenated (BN, F) block
    in the same stack-major feature order as ``ref.fastfood_project``.
    """
    projs = []
    for s in range(B.shape[0]):
        t = fwht(z * B[s][None, :])
        t = jnp.take(t, P[s], axis=1)
        t = fwht(t * G[s][None, :])
        projs.append(t * S[s][None, :])
    return jnp.concatenate(projs, axis=-1)


def _kernel(z_ref, b_ref, g_ref, p_ref, s_ref, ph_ref, wt_ref, bias_ref, o_ref):
    z = z_ref[...]                           # (BN, d') f32
    proj = _transform(
        z, b_ref[...], g_ref[...], p_ref[...], s_ref[...]
    )                                        # (BN, F), never leaves VMEM
    phi = jnp.cos(proj + ph_ref[...][None, :])
    scores = jax.lax.dot_general(
        phi, wt_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (BN, K) MXU
    o_ref[...] = scores + bias_ref[...][None, :]


def _kernel_q8(z_ref, b_ref, g_ref, p_ref, s_ref, ss_ref, ph_ref,
               wt_ref, wts_ref, bias_ref, o_ref):
    """Int8-operator variant: B (exact signs), G and S are int8 codes; the
    per-stack product of the G and S row scales folds once onto each
    stack's transform output (both diagonals multiply the same columns),
    and the readout's per-head scales fold post-GEMM — same epilogue
    shape as ``rff_score_q8``."""
    z = z_ref[...]                           # (BN, d') f32
    B = b_ref[...].astype(jnp.float32)       # +-1, lossless upcast
    G = g_ref[...].astype(jnp.float32)
    ss = ss_ref[...]                         # (stacks,) combined G*S scales
    S = s_ref[...].astype(jnp.float32) * ss[:, None]
    proj = _transform(z, B, G, p_ref[...], S)
    phi = jnp.cos(proj + ph_ref[...][None, :])
    scores = jax.lax.dot_general(
        phi, wt_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * wts_ref[...][None, :]                # fold head scales post-GEMM
    o_ref[...] = scores + bias_ref[...][None, :]


def fastfood_score_pallas(
    Z: jax.Array,
    B: jax.Array,
    G: jax.Array,
    perm: jax.Array,
    scale: jax.Array,
    phase: jax.Array,
    weights: jax.Array,
    bias: jax.Array,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused Fastfood scores. Z: (n, d); B/G/scale: (stacks, d') f32
    diagonals; perm: (stacks, d') int; phase: (F,); weights: (K, F) with
    the 2/F scaling folded at compile time; bias: (K,). Returns (n, K) —
    the same contract as ``rff_score_pallas`` without ever materializing
    the implicit (F, d) projection matrix."""
    config = config or tuning.lookup("fwht")
    n, d = Z.shape
    stacks, dd = B.shape
    f, k = stacks * dd, weights.shape[0]
    config = config.clamp_block_n(n)
    block_n = config.block_n

    k_pad = max(tiles.SUBLANE, tiles.round_up(k, tiles.SUBLANE))
    n_pad = tiles.round_up(n, block_n)

    Zp = tiles.pad_tail(Z.astype(jnp.float32), n_pad, dd)
    wtp = tiles.pad_axis(weights.astype(jnp.float32), 0, k_pad)
    bp = tiles.pad_axis(bias.astype(jnp.float32), 0, k_pad)

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, dd), lambda i: (i, 0)),
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((k_pad, f), lambda i: (0, 0)),       # resident
            pl.BlockSpec((k_pad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(
        Zp, B.astype(jnp.float32), G.astype(jnp.float32),
        perm.astype(jnp.int32), scale.astype(jnp.float32),
        phase.astype(jnp.float32), wtp, bp,
    )
    return out[:n, :k]


def fastfood_score_q8_pallas(
    Z: jax.Array,
    b_q: jax.Array,
    g_q: jax.Array,
    perm: jax.Array,
    s_q: jax.Array,
    stack_scale: jax.Array,
    phase: jax.Array,
    weights_q: jax.Array,
    wt_scale: jax.Array,
    bias: jax.Array,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused Fastfood scores off int8 operators. b_q/g_q/s_q: (stacks, d')
    int8 (b_q is exact +-1 signs); stack_scale: (stacks,) f32 combined
    G*S row scales; weights_q: (K, F) int8 with per-head wt_scale (K,);
    phase and bias f32. Same contract as ``fastfood_score_pallas``.

    Padding keeps the f32 contract: padded heads carry zero codes, zero
    scales and zero bias, and are sliced off."""
    config = config or tuning.lookup("fwht_q8")
    n, d = Z.shape
    stacks, dd = b_q.shape
    f, k = stacks * dd, weights_q.shape[0]
    config = config.clamp_block_n(n)
    block_n = config.block_n

    k_pad = max(tiles.SUBLANE, tiles.round_up(k, tiles.SUBLANE))
    n_pad = tiles.round_up(n, block_n)

    Zp = tiles.pad_tail(Z.astype(jnp.float32), n_pad, dd)
    wtp = tiles.pad_axis(weights_q.astype(jnp.int8), 0, k_pad)
    wtsp = tiles.pad_axis(wt_scale.astype(jnp.float32), 0, k_pad)
    bp = tiles.pad_axis(bias.astype(jnp.float32), 0, k_pad)

    out = pl.pallas_call(
        _kernel_q8,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, dd), lambda i: (i, 0)),
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((stacks, dd), lambda i: (0, 0)),     # resident
            pl.BlockSpec((stacks,), lambda i: (0,)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((k_pad, f), lambda i: (0, 0)),       # resident
            pl.BlockSpec((k_pad,), lambda i: (0,)),
            pl.BlockSpec((k_pad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(
        Zp, b_q.astype(jnp.int8), g_q.astype(jnp.int8),
        perm.astype(jnp.int32), s_q.astype(jnp.int8),
        stack_scale.astype(jnp.float32), phase.astype(jnp.float32),
        wtp, wtsp, bp,
    )
    return out[:n, :k]
