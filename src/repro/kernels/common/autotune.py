"""Measure-don't-guess block-size selection.

``sweep`` times one kernel family over a list of candidate
``TileConfig``s on the live device and returns every measurement;
``autotune`` additionally records the winner into the tuning registry so
subsequent ``tuning.lookup`` calls (and therefore the serving engine)
pick it up. The candidate list should always INCLUDE the current default
— then the tuned pick is never slower than the default by construction
(argmin over a set containing it).

The timing loop is best-of-N wall clock with warmup, same discipline as
``benchmarks/common.timeit`` (kept separate: ``benchmarks`` sits outside
``src`` and the kernel layer must not import upward).
"""

from __future__ import annotations

import time
from typing import Callable, Iterable

import jax

from repro.kernels.common import tuning
from repro.kernels.common.config import TileConfig


def measure(fn: Callable[[], object], *, repeats: int = 5, warmup: int = 2) -> float:
    """Best-of-N wall-clock seconds of a nullary callable; blocks on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(
    build: Callable[[TileConfig], Callable[[], object]],
    candidates: Iterable[TileConfig],
    *,
    repeats: int = 5,
    warmup: int = 2,
) -> list[dict]:
    """Time ``build(config)()`` for every candidate.

    ``build`` returns a nullary callable closing over pre-staged operands
    (so compile time and host->device transfer stay out of the timing).
    Returns one row per candidate: {"config": TileConfig, "ms": float}.
    """
    rows = []
    for cfg in candidates:
        fn = build(cfg)
        rows.append({"config": cfg, "ms": 1e3 * measure(fn, repeats=repeats, warmup=warmup)})
    return rows


def prune_candidates(
    candidates: list[TileConfig],
    default: TileConfig,
    prior: Callable[[TileConfig], float],
    keep: int,
) -> list[TileConfig]:
    """The ``keep`` cheapest-predicted candidates, default ALWAYS kept.

    ``prior`` maps a config to a predicted cost (e.g. the analytic
    roofline terms in ``repro.launch.roofline`` — ``quadform_tile_seconds``
    and friends). Pruning only decides what gets MEASURED; keeping the
    default in the measured set preserves autotune's never-worse-than-
    default guarantee even under a badly mis-calibrated prior.
    """
    ranked = sorted(candidates, key=prior)
    kept = set(ranked[: max(1, int(keep))])
    kept.add(default)
    return [c for c in candidates if c in kept]


def autotune(
    kernel: str,
    key: str,
    build: Callable[[TileConfig], Callable[[], object]],
    candidates: Iterable[TileConfig],
    *,
    repeats: int = 5,
    warmup: int = 2,
    source: str | None = None,
    prior: Callable[[TileConfig], float] | None = None,
    prior_keep: int | None = None,
) -> tuple[TileConfig, list[dict]]:
    """Sweep, pick the fastest, record it for (kernel, platform(), key).

    Returns (winner, all sweep rows). The default config for ``kernel``
    is appended to the candidates if absent, so the recorded winner can
    only tie or beat it. With ``prior`` + ``prior_keep``, only the
    ``prior_keep`` candidates with the cheapest predicted cost are
    measured (``prune_candidates``) — rank-and-prune, never
    pick-by-prediction: the winner is still chosen by measurement over a
    set that includes the default.
    """
    cands = list(candidates)
    default = tuning.lookup(kernel)
    if default not in cands:
        cands.append(default)
    if prior is not None and prior_keep is not None:
        cands = prune_candidates(cands, default, prior, prior_keep)
    rows = sweep(build, cands, repeats=repeats, warmup=warmup)
    winner = min(rows, key=lambda r: r["ms"])
    default_ms = next(r["ms"] for r in rows if r["config"] == default)
    tuning.record(
        kernel,
        key,
        winner["config"],
        measured_ms=winner["ms"],
        default_ms=default_ms,
        source=source,
    )
    return winner["config"], rows
