"""Shared tiled-kernel infrastructure for ``repro.kernels.*``.

One layer, three jobs, used by all three kernel families (quadform,
rbf_pred, maclaurin_attn):

  * ``tiles``    — lane/block padding arithmetic (the ``-(-n//b)*b`` that
    used to be hand-rolled per kernel);
  * ``config``   — the frozen, hashable ``TileConfig`` every pallas_call
    receives (jit-static);
  * ``tuning``   — measured-or-default ``TileConfig`` resolution per
    (kernel, platform, shape bucket), backed by the checked-in
    ``tuning_table.json``;
  * ``autotune`` — the sweep harness that produces those measurements
    (driven by ``benchmarks/serving_latency.py``).

Typical kernel-side use::

    from repro.kernels.common import TileConfig, tiles, tuning

    def my_kernel_wrapper(x, *, config: TileConfig | None = None, interpret=False):
        config = config or tuning.lookup("my_kernel")
        n_pad = tiles.round_up(x.shape[0], config.block_n)
        ...
"""

from repro.kernels.common.config import TileConfig
from repro.kernels.common import autotune, tiles, tuning

__all__ = ["TileConfig", "autotune", "tiles", "tuning"]
