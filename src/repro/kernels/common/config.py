"""``TileConfig`` — the one value that travels from the tuning registry
through ``repro.core.backend`` down into a ``pallas_call``.

A single frozen (hashable — it is a jit static argument) dataclass covers
all three kernel families; fields a family does not use are simply
ignored by it:

  ==============  ==========================================================
  field           used by
  ==============  ==========================================================
  ``block_n``     quadform (Z rows/tile), rbf_pred (Z rows/tile)
  ``block_m``     rbf_pred (SV rows per double-buffered stream tile)
  ``block_k``     quadform (heads per stacked-Hessian grid block;
                  ``None`` = as many as ``vmem_limit_mb`` allows)
  ``chunk``       maclaurin_attn (sequence positions per grid step)
  ``vmem_limit_mb``  quadform ``block_k`` auto-resolution budget for the
                  resident (d_pad, block_k*d_pad) Hessian slice
  ==============  ==========================================================

Instances come from ``repro.kernels.common.tuning`` (measured table or
per-kernel default) — construct one directly only in tests/benchmarks.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TileConfig:
    block_n: int = 512
    block_m: int = 256
    block_k: int | None = None
    chunk: int = 128
    vmem_limit_mb: int = 8

    def __post_init__(self):
        for name in ("block_n", "block_m", "chunk", "vmem_limit_mb"):
            v = getattr(self, name)
            if not (isinstance(v, int) and v > 0):
                raise ValueError(f"TileConfig.{name} must be a positive int, got {v!r}")
        if self.block_k is not None and not (
            isinstance(self.block_k, int) and self.block_k > 0
        ):
            raise ValueError(f"TileConfig.block_k must be None or a positive int")

    def with_(self, **updates) -> "TileConfig":
        """Functional update (``dataclasses.replace`` spelled tersely)."""
        return dataclasses.replace(self, **updates)

    def clamp_block_n(self, n: int) -> "TileConfig":
        """Shrink block_n to the (padded) batch so tiny buckets do not pad
        up to a full default tile."""
        from repro.kernels.common.tiles import SUBLANE, round_up

        target = min(self.block_n, max(SUBLANE, round_up(n, SUBLANE)))
        return self if target == self.block_n else self.with_(block_n=target)

    def resolve_block_k(self, k: int, d_pad: int) -> int:
        """Heads per quadform grid block.

        Explicit ``block_k`` wins (capped at k); otherwise the largest
        count whose (d_pad, block_k*d_pad) f32 Hessian slice fits the
        ``vmem_limit_mb`` budget, floored at one head (a single head over
        budget must still run — it is the smallest possible tile).
        """
        if self.block_k is not None:
            return max(1, min(self.block_k, k))
        budget = self.vmem_limit_mb << 20
        fit = budget // (4 * d_pad * d_pad)
        return max(1, min(k, int(fit)))

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TileConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})
