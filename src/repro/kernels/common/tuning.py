"""Per-(kernel, platform, shape-bucket) tile tuning registry.

The registry answers one question on the serving hot path: *which
``TileConfig`` should this kernel use for this shape on this hardware?*
Resolution order:

  1. in-process overrides (``record(...)`` — what the autotuner and tests
     write);
  2. the checked-in measured table ``tuning_table.json`` next to this
     module (written back by ``benchmarks/serving_latency.py``'s block
     sweep, keyed by platform so CPU numbers never leak onto TPU);
  3. the per-kernel default (the pre-tuning fixed block sizes).

Keys are canonical strings from ``shape_key(d=.., k=.., n=..)`` —
dimension names sorted, so every caller produces the same key for the
same bucket. Lookup never fails: an unknown kernel/key quietly falls back
to ``DEFAULTS``; ``lookup(..., strict=True)`` raises instead (tests).

To add a measured entry by hand, append under
``entries.<platform>.<kernel>.<key>`` in the JSON (see the benchmark for
the canonical writer) — or call ``record(...)`` + ``save_table()``.
"""

from __future__ import annotations

import json
import os
import re
import threading
import warnings

import jax

from repro.kernels.common.config import TileConfig

TABLE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "tuning_table.json")

DEFAULTS: dict[str, TileConfig] = {
    "quadform": TileConfig(block_n=512),
    "rbf_pred": TileConfig(block_n=256, block_m=256),
    "rff_score": TileConfig(block_n=256),
    "maclaurin_attn": TileConfig(chunk=128),
    # int8-weight variants are separate tuning families: the quantized
    # operand streams at a quarter of the f32 HBM bandwidth, so the
    # optimal tilings diverge from the f32 kernels' on real hardware.
    "quadform_q8": TileConfig(block_n=512),
    "rff_score_q8": TileConfig(block_n=256),
    # Structured (Fastfood) scoring: VPU butterfly stages dominate, so the
    # Z-tile block is the only knob; the readout GEMM is thin. Separate
    # family for the int8-operator variant (same rationale as above).
    "fwht": TileConfig(block_n=256),
    "fwht_q8": TileConfig(block_n=256),
}

# Canonical shape_key grammar: underscore-joined <dims><int> groups, e.g.
# "d64_k10_n1024" (whatever shape_key() can emit).
_KEY_RE = re.compile(r"^[a-z]+\d+(?:_[a-z]+\d+)*$")

_lock = threading.Lock()
_overrides: dict[tuple[str, str, str], dict] = {}
_table_cache: dict | None = None


def platform() -> str:
    """Hardware key the registry partitions on (cpu / tpu / gpu)."""
    return jax.default_backend()


def shape_key(**dims) -> str:
    """Canonical bucket key: ``shape_key(d=64, k=10, n=1024) -> 'd64_k10_n1024'``.

    Dimension names are sorted so call-site order never matters. Batch-like
    dimensions should be passed through ``bucket()`` first so every caller
    lands on the keys the benchmark sweep records.
    """
    return "_".join(f"{name}{int(dims[name])}" for name in sorted(dims))


def bucket(n: int, lo: int = 32, hi: int = 8192) -> int:
    """Canonical batch bucket: next power of two, floored at lo, capped at hi.

    THE bucketing policy — the serving engine's shape buckets, the sweep's
    recorded keys and the dispatch-level lookups all share it, so a batch
    of 1000 resolves the entry measured for the 1024 bucket instead of
    missing the table on a raw-n key.
    """
    if n <= lo:
        return lo
    return min(hi, 1 << (int(n) - 1).bit_length())


def _read_table(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {"version": 1, "entries": {}}


def validate_table(table: dict, *, origin: str = "tuning table") -> dict:
    """Drop malformed entries, warning once per problem instead of letting a
    corrupt checked-in table surface later as a KeyError / TypeError deep in
    a trace. Checks, per ``entries.<platform>.<kernel>.<key>``:

      * the kernel is a known family (has a ``DEFAULTS`` entry);
      * the key matches the ``shape_key`` grammar;
      * the entry carries a ``config`` dict that ``TileConfig`` accepts.

    Returns a NEW table containing only the surviving entries (input is
    not mutated); table-level shape problems reset to an empty table.
    """
    if not isinstance(table, dict) or not isinstance(table.get("entries", {}), dict):
        warnings.warn(f"{origin}: top-level structure malformed; ignoring table")
        return {"version": 1, "entries": {}}
    clean: dict = {"version": table.get("version", 1), "entries": {}}
    for plat, kernels in table.get("entries", {}).items():
        if not isinstance(kernels, dict):
            warnings.warn(f"{origin}: platform {plat!r} entries malformed; dropped")
            continue
        for kernel, keys in kernels.items():
            if kernel not in DEFAULTS:
                warnings.warn(
                    f"{origin}: unknown kernel {kernel!r} under {plat!r} "
                    f"(known: {sorted(DEFAULTS)}); dropped"
                )
                continue
            if not isinstance(keys, dict):
                warnings.warn(f"{origin}: {plat}/{kernel} entries malformed; dropped")
                continue
            for key, entry in keys.items():
                if not _KEY_RE.match(key):
                    warnings.warn(
                        f"{origin}: malformed shape_key {key!r} under "
                        f"{plat}/{kernel}; dropped"
                    )
                    continue
                cfg = entry.get("config") if isinstance(entry, dict) else None
                if not isinstance(cfg, dict):
                    warnings.warn(
                        f"{origin}: entry {plat}/{kernel}/{key} has no "
                        f"config dict; dropped"
                    )
                    continue
                try:
                    TileConfig.from_json(cfg)
                except (TypeError, ValueError) as e:
                    warnings.warn(
                        f"{origin}: bad config for {plat}/{kernel}/{key} "
                        f"({e}); dropped"
                    )
                    continue
                clean["entries"].setdefault(plat, {}).setdefault(kernel, {})[key] = entry
    return clean


def load_table(path: str = TABLE_PATH) -> dict:
    """Read + validate a tuning table file (malformed entries are dropped
    with a warning; a missing/unreadable file is an empty table)."""
    return validate_table(_read_table(path), origin=path)


def _load_table() -> dict:
    """The checked-in default table, read once per process (lookup tier 2)."""
    global _table_cache
    if _table_cache is None:
        _table_cache = load_table(TABLE_PATH)
    return _table_cache


def lookup(
    kernel: str,
    key: str | None = None,
    *,
    platform_name: str | None = None,
    strict: bool = False,
) -> TileConfig:
    """Resolve the ``TileConfig`` for one (kernel, platform, bucket).

    ``key=None`` skips the measured tiers and returns the kernel default
    (what a caller with no shape information gets).
    """
    plat = platform_name or platform()
    if key is not None:
        with _lock:
            hit = _overrides.get((plat, kernel, key))
        if hit is not None:
            return TileConfig.from_json(hit)
        entry = _load_table().get("entries", {}).get(plat, {}).get(kernel, {}).get(key)
        if entry is not None:
            return TileConfig.from_json(entry["config"])
    if strict:
        raise KeyError(f"no measured tuning for ({plat}, {kernel}, {key})")
    if kernel not in DEFAULTS:
        raise KeyError(f"unknown kernel family {kernel!r}; known: {sorted(DEFAULTS)}")
    return DEFAULTS[kernel]


def record(
    kernel: str,
    key: str,
    config: TileConfig,
    *,
    platform_name: str | None = None,
    measured_ms: float | None = None,
    default_ms: float | None = None,
    source: str | None = None,
) -> None:
    """Write one measured entry into the in-process override tier."""
    entry = {**config.to_json()}
    meta = {
        k: v
        for k, v in (
            ("measured_ms", measured_ms),
            ("default_ms", default_ms),
            ("source", source),
        )
        if v is not None
    }
    with _lock:
        _overrides[(platform_name or platform(), kernel, key)] = entry
        _overrides_meta[(platform_name or platform(), kernel, key)] = meta


_overrides_meta: dict[tuple[str, str, str], dict] = {}


def clear_overrides() -> None:
    """Drop every in-process override (test isolation)."""
    with _lock:
        _overrides.clear()
        _overrides_meta.clear()


def save_table(path: str = TABLE_PATH) -> str:
    """Merge the in-process overrides into the table at ``path`` and write it.

    The benchmark sweep calls this after recording its winners, producing
    the checked-in ``tuning_table.json`` the next process reads back. The
    TARGET file is re-read and merged (never the in-process cache, which
    may belong to a different path); the cached default table is refreshed
    only when writing to the default location.
    """
    global _table_cache
    table = _read_table(path)
    entries = table.setdefault("entries", {})
    with _lock:
        for (plat, kernel, key), cfg in _overrides.items():
            slot = entries.setdefault(plat, {}).setdefault(kernel, {})
            slot[key] = {"config": cfg, **_overrides_meta.get((plat, kernel, key), {})}
    table["version"] = 1
    with open(path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    if path == TABLE_PATH:
        _table_cache = table
    return path


def reload_table() -> None:
    """Forget the cached table so the next lookup re-reads the file."""
    global _table_cache
    _table_cache = None
