"""Tile-shape and padding helpers shared by every Pallas kernel family.

TPU tiles are (sublane, lane) = (8, 128) for f32; every kernel in
``repro.kernels`` pads its operands the same three ways:

  * the feature/contraction axis to a lane multiple (zeros are exact for
    norms, dots and RBF distances);
  * the streamed row axis (batch rows, SV rows, sequence chunks) to a
    block multiple so the grid divides evenly (padded rows are either
    sliced off the output or carry zero weight);
  * a head/stack axis to a block multiple (padded heads score zero and
    are sliced off).

Before this module each kernel hand-rolled the ``-(-n // b) * b``
arithmetic; keep all of it here so a tiling change is one edit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128      # last-dim tile width, all dtypes
SUBLANE = 8     # second-to-last tile width, f32


def round_up(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= n."""
    return -(-n // multiple) * multiple


def lane_pad(d: int) -> int:
    """Feature-axis padding target: next lane multiple, floored at one lane."""
    return max(LANE, round_up(d, LANE))


def pad_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad one axis of ``x`` up to ``target`` (no-op if already there)."""
    cur = x.shape[axis]
    if cur == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths)


def pad_tail(x: jax.Array, *targets: int) -> jax.Array:
    """Zero-pad the trailing ``len(targets)`` axes of ``x`` to ``targets``.

    ``pad_tail(Z, n_pad, d_pad)`` pads a (n, d) operand to (n_pad, d_pad).
    """
    for axis, target in zip(range(x.ndim - len(targets), x.ndim), targets):
        x = pad_axis(x, axis, target)
    return x


def grid_blocks(n: int, block: int) -> int:
    """Number of grid steps covering ``n`` rows at ``block`` rows per step."""
    return round_up(n, block) // block
