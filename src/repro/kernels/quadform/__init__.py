from repro.kernels.quadform.ops import quadform_predict, quadform_predict_heads
from repro.kernels.quadform.ref import quadform_heads_ref, quadform_predict_ref

__all__ = [
    "quadform_predict",
    "quadform_predict_heads",
    "quadform_predict_ref",
    "quadform_heads_ref",
]
