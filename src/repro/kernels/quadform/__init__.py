from repro.kernels.quadform.ops import quadform_predict
from repro.kernels.quadform.ref import quadform_predict_ref

__all__ = ["quadform_predict", "quadform_predict_ref"]
