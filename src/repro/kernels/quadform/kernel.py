"""Pallas TPU kernel: batched quadratic-form prediction (Eq 3.8).

    f_hat(z) = exp(-gamma ||z||^2)(c + v^T z + z^T M z) + b

The d x d Hessian M stays RESIDENT in VMEM across the whole batch (it is
read once from HBM, not once per tile) and each grid step streams one Z tile
through two MXU contractions (Z M, then row-dot with Z) plus a VPU epilogue.
This is the TPU analogue of the paper's AVX z^T M z loop.

VMEM: M is f32 (d<=2048 -> 16 MB at d=2000; the epsilon data set fits, and
that is the paper's own largest case). Larger d would tile M over a second
grid axis; not needed for the paper's regime d << n_sv.

Outputs both f_hat and ||z||^2 so the Eq 3.11 validity check is free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, m_ref, v_ref, o_ref, zsq_ref, *, c: float, b: float, gamma: float):
    z = z_ref[...]                            # (BN, d)
    M = m_ref[...]                            # (d, d)
    v = v_ref[...]                            # (d,)
    z_sq = jnp.sum(z * z, axis=-1)            # (BN,)
    zm = jax.lax.dot_general(
        z, M, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (BN, d) -- MXU
    quad = jnp.sum(zm * z, axis=-1)           # (BN,)   -- VPU row-dot
    lin = z @ v                               # (BN,)
    g_hat = c + lin + quad
    o_ref[...] = jnp.exp(-gamma * z_sq) * g_hat + b
    zsq_ref[...] = z_sq


def quadform_predict_pallas(
    Z: jax.Array,
    M: jax.Array,
    v: jax.Array,
    c: float,
    b: float,
    gamma: float,
    *,
    block_n: int = 512,
    interpret: bool = False,
):
    n, d = Z.shape
    d_pad = max(128, -(-d // 128) * 128)
    n_pad = -(-n // block_n) * block_n
    Zp = jnp.pad(Z, ((0, n_pad - n), (0, d_pad - d)))
    Mp = jnp.pad(M, ((0, d_pad - d), (0, d_pad - d)))
    vp = jnp.pad(v, (0, d_pad - d))

    out, z_sq = pl.pallas_call(
        functools.partial(_kernel, c=float(c), b=float(b), gamma=float(gamma)),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, d_pad), lambda i: (0, 0)),   # M resident
            pl.BlockSpec((d_pad,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(Zp.astype(jnp.float32), Mp.astype(jnp.float32), vp.astype(jnp.float32))
    return out[:n], z_sq[:n]
