"""Pallas TPU kernel: fused multi-head quadratic-form prediction (Eq 3.8).

For K collapsed heads (c_k, v_k, M_k) sharing one input batch Z,

    f_k(z) = exp(-gamma_k ||z||^2) (c_k + v_k^T z + z^T M_k z) + b_k

The K Hessians are laid out as ONE stacked (d, K*d) operand and TILED
over a second grid axis in head-blocks of ``block_k`` heads, so K*d^2 no
longer has to fit VMEM at once (mnist K=10 at d=784 is ~31 MB stacked —
over a single core's budget; each (d, block_k*d) slice stays under the
``TileConfig.vmem_limit_mb`` budget). Grid = (head_blocks, n_tiles) with
Z tiles innermost: each Hessian slice is read from HBM exactly ONCE and
stays resident while every Z tile streams through back-to-back per-head
MXU dots

    Z @ M_k -> (BN, d)   --row-dot Z-->   (BN,)      for each head in block

plus the thin per-head linear term and a fused exp/bias/validity
epilogue (the per-head dots have the same FLOPs as one wide
(BN, d) @ (d, BK*d) contraction, but their shapes are independent of the
tiling, which keeps the fp32 accumulation order fixed). Head-blocks are
independent — every (i, j) grid step writes its own (BN, BK) score tile,
no cross-step accumulation — so the tiled kernel is bit-for-bit identical
to the untiled one for any block_k. block_k = K recovers the PR-1
fully-resident kernel; K = 1 recovers the original single-head kernel
exactly.

Scalar head parameters arrive as a (4, K) f32 operand (rows: c, b, gamma,
||x_M||^2) instead of baked-in Python floats, so the kernel can be traced
with model parameters as jit ARGUMENTS — the core API jits over the model
pytree; only the serving engine closes over a fixed model.

Outputs per batch row: (BN, K) scores, ||z||^2 (shared across heads), and
the per-head Eq 3.11 validity mask — the accuracy-contract check is free
because ||z||^2 already feeds the exp envelope.

Block sizes come from ``repro.kernels.common``: pass a ``TileConfig``
(the backend/tuning layer resolves one per shape bucket) or get the
kernel-family default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import TileConfig, tiles, tuning
from repro.kernels.quadform.ref import eq311_valid


def _heads_kernel(z_ref, m_ref, v_ref, p_ref, o_ref, zsq_ref, valid_ref,
                  *, block_k: int, d_pad: int):
    z = z_ref[...]                            # (BN, d)
    v = v_ref[...]                            # (BK, d)
    p = p_ref[...]                            # (4, BK): c, b, gamma, ||x_M||^2
    c, bias, gamma, msq = p[0], p[1], p[2], p[3]

    z_sq = jnp.sum(z * z, axis=-1)            # (BN,)
    # Per-head (BN, d) @ (d, d) MXU dots against the resident slice, then a
    # VPU row-dot. The unrolled loop is static (block_k is a trace-time
    # constant) and every dot has the SAME shape for ANY block_k, so the
    # fp32 accumulation order per head never depends on the tiling — tiled
    # and untiled kernels are bit-for-bit identical (a wide fused
    # (BN, d) @ (d, BK*d) contraction has the same FLOPs but lets the GEMM
    # reorder its accumulation with the block width).
    quad_h, lin_h = [], []
    for h in range(block_k):
        zm = jax.lax.dot_general(
            z, m_ref[:, h * d_pad:(h + 1) * d_pad],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )                                     # (BN, d)
        quad_h.append(jnp.sum(zm * z, axis=-1))            # (BN,)
        lin_h.append(jnp.sum(z * v[h][None, :], axis=-1))  # (BN,)
    quad = jnp.stack(quad_h, axis=-1)         # (BN, BK)
    lin = jnp.stack(lin_h, axis=-1)           # (BN, BK)
    g_hat = c[None, :] + lin + quad
    env = jnp.exp(-z_sq[:, None] * gamma[None, :])
    o_ref[...] = env * g_hat + bias[None, :]
    zsq_ref[...] = z_sq                       # same value for every head-block
    valid_ref[...] = eq311_valid(z_sq, gamma, msq).astype(jnp.float32)


def quadform_heads_pallas(
    Z: jax.Array,
    M_all: jax.Array,
    V: jax.Array,
    c: jax.Array,
    b: jax.Array,
    gamma: jax.Array,
    msq: jax.Array,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
):
    """Fused K-head scores, head-block tiled. Z: (n, d), M_all: (K, d, d),
    V: (K, d); c/b/gamma/msq: (K,). Returns (scores (n, K), z_sq (n,),
    valid (n, K))."""
    config = config or tuning.lookup("quadform")
    n, d = Z.shape
    k = M_all.shape[0]
    d_pad = tiles.lane_pad(d)
    config = config.clamp_block_n(n)
    block_n = config.block_n
    block_k = config.resolve_block_k(k, d_pad)
    n_pad = tiles.round_up(n, block_n)
    k_pad = tiles.round_up(k, block_k)

    Zp = tiles.pad_tail(Z.astype(jnp.float32), n_pad, d_pad)
    Mp = tiles.pad_tail(M_all.astype(jnp.float32), d_pad, d_pad)
    Mp = tiles.pad_axis(Mp, 0, k_pad)         # zero Hessians for padded heads
    # (K, d, d) -> (d, K*d) with m[:, k*d:(k+1)*d] = M_k, so the reshape of
    # Z @ m back to (BN, K, d) groups columns per head.
    m_kd = jnp.transpose(Mp, (1, 0, 2)).reshape(d_pad, k_pad * d_pad)
    Vp = tiles.pad_tail(V.astype(jnp.float32), k_pad, d_pad)
    params = jnp.stack(
        [jnp.ravel(c), jnp.ravel(b), jnp.ravel(gamma), jnp.ravel(msq)]
    ).astype(jnp.float32)                                  # (4, K)
    params = tiles.pad_axis(params, 1, k_pad)

    # Head-blocks OUTER, Z tiles inner: each (d, BK*d) Hessian slice is
    # fetched once and reused across the whole batch.
    scores, z_sq, valid = pl.pallas_call(
        functools.partial(_heads_kernel, block_k=block_k, d_pad=d_pad),
        grid=(k_pad // block_k, n_pad // block_n),
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda j, i: (i, 0)),
            pl.BlockSpec((d_pad, block_k * d_pad), lambda j, i: (0, j)),
            pl.BlockSpec((block_k, d_pad), lambda j, i: (j, 0)),
            pl.BlockSpec((4, block_k), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_k), lambda j, i: (i, j)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n, block_k), lambda j, i: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        ],
        interpret=interpret,
    )(Zp, m_kd, Vp, params)
    return scores[:n, :k], z_sq[:n], valid[:n, :k] > 0.0


def _heads_kernel_q8(z_ref, m_ref, s_ref, v_ref, p_ref, o_ref, zsq_ref,
                     valid_ref, *, block_k: int, d_pad: int):
    """Int8-Hessian variant: ``m_ref`` is the stacked int8 operand,
    ``s_ref`` the per-(head, column) f32 scales. The dequantization is
    FUSED: each head's int8 slice feeds the MXU dot directly (upcast in
    registers, never written back) and the scale folds onto the (BN, d)
    GEMM result — one VPU multiply per head, no f32 copy of the Hessian
    ever exists in VMEM."""
    z = z_ref[...]                            # (BN, d) f32
    v = v_ref[...]                            # (BK, d) f32 (dequantized)
    s = s_ref[...]                            # (BK, d) per-column scales
    p = p_ref[...]                            # (4, BK): c, b, gamma, ||x_M||^2
    c, bias, gamma, msq = p[0], p[1], p[2], p[3]

    z_sq = jnp.sum(z * z, axis=-1)            # (BN,)
    quad_h, lin_h = [], []
    for h in range(block_k):
        zm = jax.lax.dot_general(
            z, m_ref[:, h * d_pad:(h + 1) * d_pad].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )                                     # (BN, d)
        zm = zm * s[h][None, :]               # fold the column scales here
        quad_h.append(jnp.sum(zm * z, axis=-1))            # (BN,)
        lin_h.append(jnp.sum(z * v[h][None, :], axis=-1))  # (BN,)
    quad = jnp.stack(quad_h, axis=-1)         # (BN, BK)
    lin = jnp.stack(lin_h, axis=-1)           # (BN, BK)
    g_hat = c[None, :] + lin + quad
    env = jnp.exp(-z_sq[:, None] * gamma[None, :])
    o_ref[...] = env * g_hat + bias[None, :]
    zsq_ref[...] = z_sq
    valid_ref[...] = eq311_valid(z_sq, gamma, msq).astype(jnp.float32)


def quadform_heads_q8_pallas(
    Z: jax.Array,
    M_q: jax.Array,
    col_scale: jax.Array,
    V: jax.Array,
    c: jax.Array,
    b: jax.Array,
    gamma: jax.Array,
    msq: jax.Array,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
):
    """Fused K-head scores off an int8 stacked Hessian. Z: (n, d),
    M_q: (K, d, d) int8, col_scale: (K, d) f32 (per-column dequant
    scales, already expanded from the stored per-group form), V: (K, d)
    f32; c/b/gamma/msq: (K,). Returns (scores (n, K), z_sq (n,),
    valid (n, K)) — same contract as ``quadform_heads_pallas``, the int8
    slice streams from HBM at a quarter of the f32 bandwidth."""
    config = config or tuning.lookup("quadform_q8")
    n, d = Z.shape
    k = M_q.shape[0]
    d_pad = tiles.lane_pad(d)
    config = config.clamp_block_n(n)
    block_n = config.block_n
    block_k = config.resolve_block_k(k, d_pad)
    n_pad = tiles.round_up(n, block_n)
    k_pad = tiles.round_up(k, block_k)

    Zp = tiles.pad_tail(Z.astype(jnp.float32), n_pad, d_pad)
    Mp = tiles.pad_tail(M_q.astype(jnp.int8), d_pad, d_pad)
    Mp = tiles.pad_axis(Mp, 0, k_pad)         # zero Hessians for padded heads
    m_kd = jnp.transpose(Mp, (1, 0, 2)).reshape(d_pad, k_pad * d_pad)
    Sp = tiles.pad_tail(col_scale.astype(jnp.float32), k_pad, d_pad)
    Vp = tiles.pad_tail(V.astype(jnp.float32), k_pad, d_pad)
    params = jnp.stack(
        [jnp.ravel(c), jnp.ravel(b), jnp.ravel(gamma), jnp.ravel(msq)]
    ).astype(jnp.float32)                                  # (4, K)
    params = tiles.pad_axis(params, 1, k_pad)

    scores, z_sq, valid = pl.pallas_call(
        functools.partial(_heads_kernel_q8, block_k=block_k, d_pad=d_pad),
        grid=(k_pad // block_k, n_pad // block_n),
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda j, i: (i, 0)),
            pl.BlockSpec((d_pad, block_k * d_pad), lambda j, i: (0, j)),
            pl.BlockSpec((block_k, d_pad), lambda j, i: (j, 0)),
            pl.BlockSpec((block_k, d_pad), lambda j, i: (j, 0)),
            pl.BlockSpec((4, block_k), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_k), lambda j, i: (i, j)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n, block_k), lambda j, i: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        ],
        interpret=interpret,
    )(Zp, m_kd, Sp, Vp, params)
    return scores[:n, :k], z_sq[:n], valid[:n, :k] > 0.0


def quadform_predict_pallas(
    Z: jax.Array,
    M: jax.Array,
    v: jax.Array,
    c,
    b,
    gamma,
    *,
    config: TileConfig | None = None,
    interpret: bool = False,
):
    """Single-head wrapper (the original kernel API): K = 1 of the fused path.

    Returns (f_hat (n,), z_sq (n,)).  c/b/gamma may be Python floats or
    traced scalars.
    """
    one = lambda x: jnp.reshape(jnp.asarray(x, jnp.float32), (1,))
    scores, z_sq, _ = quadform_heads_pallas(
        Z, M[None], v[None], one(c), one(b), one(gamma), one(0.0),
        config=config, interpret=interpret,
    )
    return scores[:, 0], z_sq
