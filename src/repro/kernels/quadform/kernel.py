"""Pallas TPU kernel: fused multi-head quadratic-form prediction (Eq 3.8).

For K collapsed heads (c_k, v_k, M_k) sharing one input batch Z,

    f_k(z) = exp(-gamma_k ||z||^2) (c_k + v_k^T z + z^T M_k z) + b_k

All K Hessians stay RESIDENT in VMEM as ONE (d, K*d) operand (read once
from HBM, not once per tile and never once per head).  Each grid step
streams one Z tile through a single MXU contraction

    Z @ M_all -> (BN, K*d)   --reshape-->   (BN, K, d)

followed by a VPU row-dot with Z -> (BN, K) quadratic terms, the thin
linear GEMM Z @ V^T -> (BN, K), and a fused exp/bias/validity epilogue.
One pallas_call scores ALL heads: OvR multiclass no longer pays K passes
over Z nor K separate reads of each d x d Hessian.  K = 1 recovers the
original single-head kernel exactly.

Scalar head parameters arrive as a (4, K) f32 operand (rows: c, b, gamma,
||x_M||^2) instead of baked-in Python floats, so the kernel can be traced
with model parameters as jit ARGUMENTS — the core API jits over the model
pytree; only the serving engine closes over a fixed model.

Outputs per batch row: (BN, K) scores, ||z||^2 (shared across heads), and
the per-head Eq 3.11 validity mask — the accuracy-contract check is free
because ||z||^2 already feeds the exp envelope.

VMEM: the resident operand is K*d^2 f32 — 16 MB at (K=1, d=2000), the
paper's largest case.  Large K*d^2 (e.g. K=10 at mnist's d=784) exceeds a
single core's VMEM on real hardware; tiling M_all over a second grid axis
is the designated follow-up once a TPU host is in the loop (see
ROADMAP.md "Serving architecture").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quadform.ref import eq311_valid


def _heads_kernel(z_ref, m_ref, v_ref, p_ref, o_ref, zsq_ref, valid_ref,
                  *, num_heads: int, d_pad: int):
    z = z_ref[...]                            # (BN, d)
    m = m_ref[...]                            # (d, K*d)  resident
    v = v_ref[...]                            # (K, d)
    p = p_ref[...]                            # (4, K): c, b, gamma, ||x_M||^2
    c, bias, gamma, msq = p[0], p[1], p[2], p[3]

    z_sq = jnp.sum(z * z, axis=-1)            # (BN,)
    zm = jax.lax.dot_general(
        z, m, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (BN, K*d) -- ONE MXU contraction
    zm = zm.reshape(z.shape[0], num_heads, d_pad)
    quad = jnp.sum(zm * z[:, None, :], axis=-1)            # (BN, K) row-dot
    lin = jax.lax.dot_general(
        z, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (BN, K)
    g_hat = c[None, :] + lin + quad
    env = jnp.exp(-z_sq[:, None] * gamma[None, :])
    o_ref[...] = env * g_hat + bias[None, :]
    zsq_ref[...] = z_sq
    valid_ref[...] = eq311_valid(z_sq, gamma, msq).astype(jnp.float32)


def quadform_heads_pallas(
    Z: jax.Array,
    M_all: jax.Array,
    V: jax.Array,
    c: jax.Array,
    b: jax.Array,
    gamma: jax.Array,
    msq: jax.Array,
    *,
    block_n: int = 512,
    interpret: bool = False,
):
    """Fused K-head scores. Z: (n, d), M_all: (K, d, d), V: (K, d);
    c/b/gamma/msq: (K,). Returns (scores (n, K), z_sq (n,), valid (n, K))."""
    n, d = Z.shape
    k = M_all.shape[0]
    d_pad = max(128, -(-d // 128) * 128)
    n_pad = -(-n // block_n) * block_n
    Zp = jnp.pad(Z.astype(jnp.float32), ((0, n_pad - n), (0, d_pad - d)))
    Mp = jnp.pad(M_all.astype(jnp.float32), ((0, 0), (0, d_pad - d), (0, d_pad - d)))
    # (K, d, d) -> (d, K*d) with m[:, k*d:(k+1)*d] = M_k, so the reshape of
    # Z @ m back to (BN, K, d) groups columns per head.
    m_kd = jnp.transpose(Mp, (1, 0, 2)).reshape(d_pad, k * d_pad)
    Vp = jnp.pad(V.astype(jnp.float32), ((0, 0), (0, d_pad - d)))
    params = jnp.stack(
        [jnp.ravel(c), jnp.ravel(b), jnp.ravel(gamma), jnp.ravel(msq)]
    ).astype(jnp.float32)                                  # (4, K)

    scores, z_sq, valid = pl.pallas_call(
        functools.partial(_heads_kernel, num_heads=k, d_pad=d_pad),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, k * d_pad), lambda i: (0, 0)),   # M_all resident
            pl.BlockSpec((k, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((4, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, k), jnp.float32),
        ],
        interpret=interpret,
    )(Zp, m_kd, Vp, params)
    return scores[:n], z_sq[:n], valid[:n] > 0.0


def quadform_predict_pallas(
    Z: jax.Array,
    M: jax.Array,
    v: jax.Array,
    c,
    b,
    gamma,
    *,
    block_n: int = 512,
    interpret: bool = False,
):
    """Single-head wrapper (the original kernel API): K = 1 of the fused path.

    Returns (f_hat (n,), z_sq (n,)).  c/b/gamma may be Python floats or
    traced scalars.
    """
    one = lambda x: jnp.reshape(jnp.asarray(x, jnp.float32), (1,))
    scores, z_sq, _ = quadform_heads_pallas(
        Z, M[None], v[None], one(c), one(b), one(gamma), one(0.0),
        block_n=block_n, interpret=interpret,
    )
    return scores[:, 0], z_sq
