"""Jit'd public wrapper for the quadratic-form prediction kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.quadform.kernel import quadform_predict_pallas
from repro.kernels.quadform.ref import quadform_predict_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("c", "b", "gamma", "use_pallas", "block_n"))
def quadform_predict(
    Z, M, v, c: float, b: float, gamma: float,
    use_pallas: bool = True, block_n: int = 512,
):
    """Returns (f_hat, z_sq). See kernel.py for the TPU mapping."""
    if use_pallas:
        return quadform_predict_pallas(
            Z, M, v, c, b, gamma, block_n=block_n, interpret=_on_cpu()
        )
    return quadform_predict_ref(Z, M, v, c, b, gamma)
