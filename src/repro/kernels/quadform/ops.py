"""Jit'd public wrappers (shims) for the quadratic-form prediction kernel.

These are thin: the actual Pallas-vs-XLA routing lives in
``repro.core.backend`` so core, the serving engine and the benchmarks all
share one implementation of the math.  ``use_pallas`` is kept for explicit
A/B benchmarking (Table-2 style comparisons) and pins the path regardless
of the process-level backend choice.

Block sizes travel as a ``TileConfig`` (hashable, jit-static; ``None``
resolves the kernel-family default from ``repro.kernels.common.tuning``).
Model scalars (c, b, gamma) are TRACED arguments, not static — the
kernels take them as array operands, so these wrappers compose with outer
jits over model pytrees without retracing per value.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.common import TileConfig
from repro.kernels.quadform.kernel import (
    quadform_heads_pallas,
    quadform_predict_pallas,
)
from repro.kernels.quadform.ref import quadform_heads_ref, quadform_predict_ref


def _off_tpu() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("use_pallas", "config"))
def quadform_predict(
    Z, M, v, c, b, gamma,
    use_pallas: bool = True, config: TileConfig | None = None,
):
    """Single-head (f_hat, z_sq). K=1 slice of the fused multi-head kernel."""
    if use_pallas:
        return quadform_predict_pallas(
            Z, M, v, c, b, gamma, config=config, interpret=_off_tpu()
        )
    return quadform_predict_ref(Z, M, v, c, b, gamma)


@partial(jax.jit, static_argnames=("use_pallas", "config"))
def quadform_predict_heads(
    Z, M_all, V, c, b, gamma, msq,
    use_pallas: bool = True, config: TileConfig | None = None,
):
    """Fused K-head (scores (n, K), z_sq (n,), valid (n, K)).

    ``use_pallas=False`` runs the unfused per-head vmap oracle — the
    baseline the fused path is benchmarked against.
    """
    if use_pallas:
        return quadform_heads_pallas(
            Z, M_all, V, c, b, gamma, msq, config=config, interpret=_off_tpu()
        )
    return quadform_heads_ref(Z, M_all, V, c, b, gamma, msq)
