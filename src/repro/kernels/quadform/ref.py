"""Pure-jnp oracles for the approximated-model prediction kernel (Eq 3.8).

``quadform_predict_ref`` is the single-head oracle; ``quadform_heads_ref``
is the DELIBERATELY-UNFUSED multi-head oracle (a vmap of K independent
single-head evaluations — K separate reads of each Hessian).  Both exist
so the fused implementations (Pallas kernel and the backend's single-GEMM
XLA path) have something slow-but-obviously-correct to be tested against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def eq311_valid(z_sq, gamma, msq):
    """Per-head Eq 3.11 mask (n, K): valid iff ||x_M||^2 ||z||^2 < 1/(16 g^2).

    z_sq: (n,), gamma/msq: (K,). The single definition shared by the Pallas
    kernel, the XLA backend path and the vmap oracle (plain jnp so it can
    run inside a kernel body). The max() guards gamma == 0 (degenerate
    head) without producing inf.
    """
    rhs = 0.0625 / jnp.maximum(gamma * gamma, 1e-30)
    return msq[None, :] * z_sq[:, None] < rhs[None, :]


def quadform_predict_ref(Z, M, v, c, b, gamma):
    """f_hat(Z) = exp(-gamma ||z||^2)(c + v^T z + z^T M z) + b.

    Z: (n, d), M: (d, d), v: (d,). Returns (f_hat (n,), z_sq (n,)).
    z_sq is exposed so callers can check the Eq 3.11 bound for free.
    """
    z_sq = jnp.sum(Z * Z, axis=-1)
    g_hat = c + Z @ v + jnp.sum((Z @ M) * Z, axis=-1)
    return jnp.exp(-gamma * z_sq) * g_hat + b, z_sq


def quadform_heads_ref(Z, M_all, V, c, b, gamma, msq):
    """Per-head vmap oracle for the fused multi-head path.

    M_all: (K, d, d), V: (K, d), c/b/gamma/msq: (K,).
    Returns (scores (n, K), z_sq (n,), valid (n, K)) exactly like the fused
    implementations, but evaluates each head independently.
    """
    scores, z_sqs = jax.vmap(
        lambda Mk, vk, ck, bk, gk: quadform_predict_ref(Z, Mk, vk, ck, bk, gk)
    )(M_all, V, c, b, gamma)                               # (K, n), (K, n)
    z_sq = z_sqs[0]
    return scores.T, z_sq, eq311_valid(z_sq, gamma, msq)
