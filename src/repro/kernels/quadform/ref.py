"""Pure-jnp oracle for the approximated-model prediction kernel (Eq 3.8)."""

from __future__ import annotations

import jax.numpy as jnp


def quadform_predict_ref(Z, M, v, c, b, gamma):
    """f_hat(Z) = exp(-gamma ||z||^2)(c + v^T z + z^T M z) + b.

    Z: (n, d), M: (d, d), v: (d,). Returns (f_hat (n,), z_sq (n,)).
    z_sq is exposed so callers can check the Eq 3.11 bound for free.
    """
    z_sq = jnp.sum(Z * Z, axis=-1)
    g_hat = c + Z @ v + jnp.sum((Z @ M) * Z, axis=-1)
    return jnp.exp(-gamma * z_sq) * g_hat + b, z_sq
