from repro.data.synthetic import DATASETS, DatasetSpec, make_dataset
from repro.data.loader import ShardedLoader, lm_token_batches

__all__ = ["DATASETS", "DatasetSpec", "make_dataset", "ShardedLoader", "lm_token_batches"]
