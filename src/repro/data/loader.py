"""Sharded, step-resumable data pipeline.

Design goals for the 1000+-node posture (DESIGN.md §6):

  * **Stateless indexing** — batch t is a pure function of (seed, step), so a
    restarted job resumes mid-epoch from the checkpointed step with zero
    pipeline state to save.
  * **Shard-aware** — each data-parallel host slices its rows from the global
    batch by its mesh coordinates; no host ever materializes the global batch.
  * **Prefetch** — a one-deep software pipeline (next batch is generated while
    the current step runs) mirrors real input pipelines; on this 1-core
    container it is a correctness structure more than a throughput one.
"""

from __future__ import annotations

import threading
import queue
from typing import Callable, Iterator

import numpy as np

Array = np.ndarray


class ShardedLoader:
    """Deterministic per-step batch sampler over an in-memory array store."""

    def __init__(
        self,
        X: Array,
        y: Array,
        global_batch: int,
        seed: int = 0,
        shard_index: int = 0,
        num_shards: int = 1,
    ):
        assert global_batch % num_shards == 0, "global batch must split evenly"
        self.X, self.y = X, y
        self.global_batch = global_batch
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards

    def batch_at(self, step: int) -> tuple[Array, Array]:
        """Pure function of step — the resumability contract."""
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self.X.shape[0], size=self.global_batch)
        lo = self.shard_index * self.local_batch
        sel = idx[lo : lo + self.local_batch]
        return self.X[sel], self.y[sel]

    def iter_from(self, step: int) -> Iterator[tuple[Array, Array]]:
        while True:
            yield self.batch_at(step)
            step += 1


def prefetched(make_batch: Callable[[int], object], start_step: int, depth: int = 1):
    """Background-thread prefetch of ``make_batch(step)`` for step >= start."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put(make_batch(step))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()


def lm_token_batches(
    vocab_size: int, batch: int, seq_len: int, seed: int = 0
) -> Callable[[int], dict[str, Array]]:
    """Synthetic-corpus LM batches: a fixed random "document" pool with
    Zipfian unigram statistics plus a copy-structure (spans repeat) so a
    transformer can actually reduce loss below unigram entropy.
    """
    rng = np.random.default_rng(seed)
    # Zipf-ish unigram distribution over the vocab.
    ranks = np.arange(1, vocab_size + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    pool = rng.choice(vocab_size, size=(256, seq_len + 1), p=probs).astype(np.int32)
    # Inject copy structure: second half of each doc repeats its first half.
    half = (seq_len + 1) // 2
    pool[:, half : 2 * half] = pool[:, :half]

    def make(step: int) -> dict[str, Array]:
        r = np.random.default_rng((seed, step))
        rows = r.integers(0, pool.shape[0], size=batch)
        docs = pool[rows]
        return {"tokens": docs[:, :-1], "labels": docs[:, 1:]}

    return make
