"""Synthetic stand-ins for the paper's five LIBSVM data sets.

The container is offline, so a9a/mnist/ijcnn1/sensit/epsilon cannot be
downloaded. We generate classification problems with the SAME dimensionality
and feature character (binary dummies for a9a, pixel-like sparse positives
for mnist, dense standardized for epsilon, ...), so every Table-1/2/3
experiment runs at the paper's shapes. DESIGN.md §9 records this honestly.

Generator: a two-class mixture with a nonlinear (quadratic) ground-truth
boundary — rich enough that an RBF SVM beats a linear one, so approximation
quality is tested on a genuinely nonlinear decision function.

Scale: `scale` < 1 shrinks n_train/n_test (NOT d — dimensionality is what
the technique's complexity depends on) so tests/benchmarks stay CPU-feasible.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    d: int
    n_train: int
    n_test: int
    feature_kind: str        # "binary" | "pixels" | "dense" | "standardized"
    paper_gamma: float       # the gamma the paper used (first row per set)
    paper_gamma_max: float   # the paper's reported gamma_max


# The five paper data sets (Table 1), full shapes.
DATASETS: dict[str, DatasetSpec] = {
    "a9a": DatasetSpec("a9a", 123, 32561, 16281, "binary", 0.01, 0.018),
    "mnist": DatasetSpec("mnist", 780, 60000, 10000, "pixels", 1e-4, 1e-3),
    "ijcnn1": DatasetSpec("ijcnn1", 22, 49990, 91701, "dense", 0.05, 0.064),
    "sensit": DatasetSpec("sensit", 100, 78823, 19705, "dense", 0.003, 0.0025),
    "epsilon": DatasetSpec("epsilon", 2000, 400000, 100000, "standardized", 0.35, 0.25),
}


def _features(rng: np.random.Generator, n: int, d: int, kind: str) -> Array:
    if kind == "binary":
        # a9a-like: mostly 0/1 dummies, sparse-ish.
        return (rng.random((n, d)) < 0.12).astype(np.float32)
    if kind == "pixels":
        # mnist-like: [0,1] values, ~80% zeros.
        x = rng.random((n, d)).astype(np.float32)
        mask = rng.random((n, d)) < 0.19
        return np.where(mask, x, 0.0).astype(np.float32)
    if kind == "dense":
        # ijcnn1/sensit-like: bounded dense features in [-1, 1].
        return (rng.random((n, d)).astype(np.float32) * 2.0 - 1.0) * 0.8
    if kind == "standardized":
        # epsilon-like: unit-variance gaussian, then row-normalized to unit
        # L2 norm (epsilon is distributed pre-normalized).
        x = rng.standard_normal((n, d)).astype(np.float32)
        return x / (np.linalg.norm(x, axis=1, keepdims=True) + 1e-12)
    raise ValueError(f"unknown feature kind {kind!r}")


def _quadratic_boundary(rng: np.random.Generator, d: int) -> Callable[[Array], Array]:
    """Random ground truth f*(x) = x^T A x + w^T x + c with low-rank A."""
    r = max(2, d // 16)
    U = rng.standard_normal((d, r)).astype(np.float32) / np.sqrt(d)
    s = rng.standard_normal(r).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32) / np.sqrt(d)

    def f(X: Array) -> Array:
        proj = X @ U
        return (proj * proj) @ s + X @ w

    return f


def make_dataset(
    name: str, scale: float = 1.0, seed: int = 0, label_noise: float = 0.03
) -> tuple[Array, Array, Array, Array, DatasetSpec]:
    """Returns (X_train, y_train, X_test, y_test, spec); labels in {-1,+1}."""
    spec = DATASETS[name]
    rng = np.random.default_rng(seed + hash(name) % 2**31)
    n_tr = max(64, int(spec.n_train * scale))
    n_te = max(64, int(spec.n_test * scale))
    X = _features(rng, n_tr + n_te, spec.d, spec.feature_kind)
    f = _quadratic_boundary(rng, spec.d)
    scores = f(X)
    y = np.where(scores > np.median(scores), 1.0, -1.0).astype(np.float32)
    flip = rng.random(y.shape) < label_noise
    y = np.where(flip, -y, y)
    return X[:n_tr], y[:n_tr], X[n_tr:], y[n_tr:], spec


def make_blobs(
    n: int, d: int, seed: int = 0, separation: float = 2.0
) -> tuple[Array, Array]:
    """Tiny two-blob task for unit tests."""
    rng = np.random.default_rng(seed)
    half = n // 2
    mu = rng.standard_normal(d).astype(np.float32)
    mu = mu / np.linalg.norm(mu) * separation / 2
    Xp = rng.standard_normal((half, d)).astype(np.float32) + mu
    Xn = rng.standard_normal((n - half, d)).astype(np.float32) - mu
    X = np.concatenate([Xp, Xn], 0)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    perm = rng.permutation(n)
    return X[perm], y[perm]
