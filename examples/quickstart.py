"""Quickstart: the paper in 60 seconds.

Train an LS-SVM with an RBF kernel, collapse it to the (c, v, M) quadratic
form (2nd-order Maclaurin, paper §3), check the validity bound (Eq 3.11),
and compare accuracy + size + speed.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    approximate,
    approx_decision_function_checked,
    decision_function,
    gamma_max,
)
from repro.core.maclaurin import approx_model_bytes
from repro.core.rbf import model_bytes
from repro.data.synthetic import make_blobs
from repro.svm import train_lssvm


def main():
    X, y = make_blobs(800, 24, seed=0, separation=2.5)
    Xtr, ytr, Xte, yte = X[:600], y[:600], X[600:], y[600:]

    gm = float(gamma_max(jnp.asarray(X)))
    gamma = 0.8 * gm
    print(f"data: d=24 n_train=600; gamma_MAX={gm:.4f} (Eq 3.11); using gamma={gamma:.4f}")

    model = train_lssvm(jnp.asarray(Xtr), jnp.asarray(ytr), jnp.float32(gamma), jnp.float32(10.0))
    print(f"exact model: n_sv={model.n_sv} (LS-SVM: every point is a SV), "
          f"{model_bytes(model)/1024:.0f} KiB")

    approx = approximate(model)
    print(f"approx model: c + v^T z + z^T M z with M {approx.M.shape}, "
          f"{approx_model_bytes(approx)/1024:.1f} KiB "
          f"({model_bytes(model)/approx_model_bytes(approx):.0f}x smaller)")

    Z = jnp.asarray(Xte)
    f_exact = np.asarray(decision_function(model, Z))
    f_hat, valid = approx_decision_function_checked(approx, Z)
    f_hat = np.asarray(f_hat)
    print(f"bound holds for {100*np.asarray(valid).mean():.1f}% of test points")
    print(f"exact accuracy:  {(np.sign(f_exact) == yte).mean():.3f}")
    print(f"approx accuracy: {(np.sign(f_hat) == yte).mean():.3f}")
    print(f"label diff:      {(np.sign(f_hat) != np.sign(f_exact)).mean()*100:.2f}% "
          f"(paper: <1% under the bound)")

    exact_fn = jax.jit(decision_function)
    from repro.core.maclaurin import approx_decision_function
    fast_fn = jax.jit(approx_decision_function)
    jax.block_until_ready(exact_fn(model, Z)); jax.block_until_ready(fast_fn(approx, Z))
    t0 = time.perf_counter(); jax.block_until_ready(exact_fn(model, Z)); t_e = time.perf_counter() - t0
    t0 = time.perf_counter(); jax.block_until_ready(fast_fn(approx, Z)); t_a = time.perf_counter() - t0
    print(f"prediction time: exact {1e3*t_e:.2f} ms vs approx {1e3*t_a:.2f} ms "
          f"-> {t_e/max(t_a,1e-9):.1f}x faster")


if __name__ == "__main__":
    main()
