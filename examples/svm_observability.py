"""Observability demo: trace a burst, expose metrics, profile a step.

The serving runtime answers "what is happening in production" on three
layers, all shown here end to end:

1. **Tracing** — every submitted request gets a deterministic trace id
   (``{seed:04x}-{ordinal:012x}``, a seeded counter — replayable, never
   wall-clock); its lifecycle lands as linked spans (admission, queue
   wait, coalesced engine step with bucket/TileConfig/recompile flag,
   scatter, sync, verdict) in a bounded per-model ring, exportable as
   JSONL. Monotone span counts survive ring eviction, so the
   conservation identity (served + failed + expired + closed ==
   admitted) is checkable forever.

2. **Metrics** — the same record sites feed a typed counter/gauge/
   histogram registry dimensioned by (model_digest, alias, family,
   dtype, replica, bucket), rendered in the Prometheus text format:
   point a scraper at ``render_prometheus()`` and the §4 validity
   fraction, fallback rate, queue depth, per-replica breaker state and
   EWMA step time are first-class series.

3. **Profiling** — ``Runtime.profile(model, Z, path)`` wraps one
   coalesced step in ``jax.profiler.trace`` with named annotations
   around the engine step and the backend kernel-dispatch seam, for
   TensorBoard / Perfetto inspection.

    PYTHONPATH=src python examples/svm_observability.py
"""

import tempfile
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.core import gamma_max
from repro.core.families import maclaurin
from repro.core.rbf import SVMModel
from repro.serve import PublishSpec, Runtime
from repro.serve.runtime import MetricsRegistry, Observability

DIM = 16
REQ_ROWS = 4
BURST = 32


def make_model(seed=0, d=DIM, n_sv=64):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * 0.5
    gamma = 0.8 * float(gamma_max(jnp.asarray(X)))
    ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
    return SVMModel(
        X=jnp.asarray(X),
        alpha_y=jnp.asarray(ay),
        b=jnp.float32(0.1),
        gamma=jnp.float32(gamma),
    )


def main():
    model = make_model()
    # a private Observability isolates this demo's registry and seeds the
    # tracer; the default (obs=None) shares one process-wide registry so
    # every runtime's series land in a single exposition
    obs = Observability(seed=7, registry=MetricsRegistry())
    out_dir = Path(tempfile.mkdtemp(prefix="svm_obs_"))

    with Runtime(engine_opts=dict(min_bucket=8, max_batch=64), obs=obs) as rt:
        digest = rt.publish(
            "detector", maclaurin.compile(model), PublishSpec(exact=model)
        )
        key = digest[:12]
        rng = np.random.default_rng(1)

        # -- 1. trace a burst of coalesced traffic -----------------------
        futs = [
            rt.submit(
                "detector",
                0.3 * rng.standard_normal((REQ_ROWS, DIM)).astype(np.float32),
            )
            for _ in range(BURST)
        ]
        for f in futs:
            f.result(timeout=30.0).values

        cons = obs.tracer.conservation(key)
        print(f"[obs] conservation for {key}: {cons}")
        assert cons["unaccounted"] == 0
        step = obs.tracer.spans(key, "engine.step")[-1]
        print(
            f"[obs] last engine step: trace={step['trace_id']} "
            f"bucket={step['attrs']['bucket']} "
            f"recompiled={step['attrs']['recompiled']} "
            f"tile={step['attrs']['tile_config']}"
        )

        # -- 2. Prometheus exposition ------------------------------------
        text = rt.render_prometheus()
        wanted = (
            "repro_serve_validity_fraction",
            "repro_serve_fallback_rate",
            "repro_serve_queue_rows",
            "repro_serve_breaker_state",
            "repro_serve_step_time_ewma_seconds",
        )
        picked = [
            line
            for line in text.splitlines()
            if line.startswith(wanted) or line.startswith("repro_serve_requests_total")
        ]
        print(f"[obs] prometheus exposition ({len(text.splitlines())} lines), e.g.:")
        for line in picked:
            print(f"  {line}")

        # -- 3. JSONL span export + one profiler capture -----------------
        jsonl = out_dir / "spans.jsonl"
        n = obs.tracer.export_jsonl(jsonl, key)
        print(f"[obs] exported {n} ring-resident spans to {jsonl}")

        trace_dir = out_dir / "profile"
        probe = 0.3 * rng.standard_normal((8, DIM)).astype(np.float32)
        rt.profile("detector", probe, trace_dir)
        produced = sorted(
            p.relative_to(trace_dir) for p in trace_dir.rglob("*") if p.is_file()
        )
        print(f"[obs] jax.profiler trace under {trace_dir}:")
        for p in produced:
            print(f"  {p}")


if __name__ == "__main__":
    main()
