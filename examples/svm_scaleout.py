"""Multi-device scale-out demo: replicated dispatch + head sharding.

Two independent axes of scale, composed on whatever devices the host
exposes (this demo forces 8 virtual CPU devices so it runs anywhere —
on a real TPU/GPU host, drop the env var and the same code spreads over
the physical devices):

1. **Replicated engine dispatch** — ``publish(..., replicas=N)`` builds
   N engines from ONE content-addressed artifact (same digest, same
   compiled step — consistency is free) and the micro-batcher routes
   each flush to the least-loaded replica. Every replica carries its
   own circuit breaker: the demo trips ONE replica with a scripted
   fault and shows its siblings serving the fast path, undisturbed,
   while per-replica telemetry names the culprit.

2. **Head-sharded extreme multiclass** — a K=4096 one-vs-rest model's
   stacked Hessians (K, d, d) dwarf one device's comfortable footprint;
   ``head_mesh=`` partitions heads across the mesh via ``shard_map``,
   pads K to the shard count with argmax-neutral heads, and slices the
   pad columns back off before anyone sees them. Scores match the
   unsharded engine bit-for-bit at small K (shown), and 4096 heads
   serve within a single submit at large K.

    PYTHONPATH=src python examples/svm_scaleout.py
"""

import os

# must land before jax initializes its backends
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import threading  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import gamma_max  # noqa: E402
from repro.core.families import maclaurin  # noqa: E402
from repro.core.rbf import SVMModel  # noqa: E402
from repro.serve import FaultInjector, PublishSpec, Runtime  # noqa: E402
from repro.serve.runtime import ENGINE_STEP  # noqa: E402
from repro.serve.svm_engine import SVMEngine  # noqa: E402

DIM = 16
REQ_ROWS = 64
CLIENTS = 8
REQS = 20
# emulated per-flush service time: on this demo's single physical CPU,
# real steps are too fast to show dispatch concurrency, so the fault
# injector pins each flush at 10 ms (a GIL-releasing sleep) — replicas
# then overlap honestly, exactly like N devices would
STEP_S = 0.010


def make_model(seed, k=1, d=DIM, n_sv=64):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * 0.5
    gamma = 0.8 * float(gamma_max(jnp.asarray(X)))
    ay = rng.standard_normal((k, n_sv)).astype(np.float32) * 0.5
    b = (rng.standard_normal(k) * 0.1).astype(np.float32)
    if k == 1:
        return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay[0]),
                        b=jnp.float32(b[0]), gamma=jnp.float32(gamma))
    return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                    b=jnp.asarray(b), gamma=jnp.float32(gamma))


def drive(rt, alias, seed):
    """CLIENTS open-loop threads, REQS requests each; returns rows/s."""
    def client(tid, out):
        # 0.3x scale keeps rows inside the §4 envelope: the point here is
        # dispatch concurrency, not fallback traffic
        rng = np.random.default_rng((seed, tid))
        futs = [rt.submit(alias, 0.3 * rng.standard_normal(
            (REQ_ROWS, DIM)).astype(np.float32)) for _ in range(REQS)]
        out.extend(f.result(timeout=60.0) for f in futs)

    outs = [[] for _ in range(CLIENTS)]
    threads = [threading.Thread(target=client, args=(t, o))
               for t, o in enumerate(outs)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for o in outs:
        for r in o:
            r.values  # materialize
    rows = CLIENTS * REQS * REQ_ROWS
    return rows / (time.perf_counter() - t0)


def main():
    ndev = len(jax.local_devices())
    print(f"local devices: {ndev} ({jax.local_devices()[0].platform})")

    # ---- act 1: throughput scales with replica count
    model = make_model(3)
    art = maclaurin.compile(model)
    print(f"\n[replicas] {CLIENTS} clients x {REQS} reqs x {REQ_ROWS} rows, "
          f"per-flush service time pinned at {STEP_S * 1e3:.0f} ms:")
    for n in (1, 2, min(4, ndev), min(8, ndev)):
        fi = FaultInjector(seed=0, slow_step_rate=1.0, slow_step_s=STEP_S)
        with Runtime(max_wait_us=500.0, flush_rows=REQ_ROWS,
                     engine_opts=dict(min_bucket=REQ_ROWS,
                                      max_batch=REQ_ROWS),
                     fault_injector=fi) as rt:
            rt.publish("m", art, PublishSpec(exact=model, replicas=n))
            rt.predict("m", np.zeros((2, DIM), np.float32))  # warm
            rate = drive(rt, "m", seed=n)
            per = rt.stats("m")["replicas"]
            spread = [per[i]["flushes"] for i in sorted(per)]
            print(f"  replicas={n}: {rate:9.0f} rows/s  "
                  f"(flushes per replica: {spread})")

    # ---- act 2: one faulting replica degrades only itself
    fi = FaultInjector(seed=0)
    with Runtime(max_wait_us=500.0,
                 breaker=dict(fail_threshold=1, reset_after_s=60.0),
                 engine_opts=dict(min_bucket=8, max_batch=64),
                 fault_injector=fi) as rt:
        rt.publish("m", art, PublishSpec(exact=model, replicas=3))
        rng = np.random.default_rng(0)
        rt.predict("m", 0.3 * rng.standard_normal((2, DIM)).astype(np.float32))
        fi.fail_next(FaultInjector.replica_site(ENGINE_STEP, 1), 1)
        failed = 0
        for _ in range(8):
            try:
                _, valid = rt.predict(
                    "m",
                    0.3 * rng.standard_normal((4, DIM)).astype(np.float32))
                assert valid.all()          # siblings keep the FAST path
            except Exception:
                failed += 1
        per = rt.stats("m")["replicas"]
        states = {i: per[i]["breaker_state"] for i in sorted(per)}
        print(f"\n[isolation] scripted fault on replica 1: {failed} request "
              f"failed, breakers now {states} — healthy replicas never "
              f"degraded to the exact path")

    # ---- act 3: head-sharded extreme multiclass
    mesh = Mesh(np.array(jax.local_devices()), ("heads",))
    small = make_model(5, k=10)
    small_art = maclaurin.compile(small)
    ref = SVMEngine(small_art, min_bucket=64, max_batch=256)
    shd = SVMEngine(small_art, head_mesh=mesh, min_bucket=64, max_batch=256)
    Z = np.random.default_rng(1).standard_normal((64, DIM)).astype(np.float32)
    r_ref, r_shd = ref.submit(Z), shd.submit(Z)
    agree = float(np.mean(np.asarray(r_ref.labels) == np.asarray(r_shd.labels)))
    pad = shd._serve_artifact.meta.get("padded_heads", 10)
    print(f"\n[sharding] K=10 over {ndev} shards (padded to {pad} heads): "
          f"argmax parity vs unsharded = {agree:.3f}")

    big = make_model(7, k=4096, d=32)
    big_art = maclaurin.compile(big)
    eng = SVMEngine(big_art, head_mesh=mesh, min_bucket=256, max_batch=256)
    Zb = np.random.default_rng(2).standard_normal((256, 32)).astype(np.float32)
    eng.submit(Zb).block_until_ready()          # compile outside the timing
    t0 = time.perf_counter()
    res = eng.submit(Zb)
    res.values
    dt = time.perf_counter() - t0
    print(f"  K=4096 d=32: 256 rows scored in {dt * 1e3:.1f} ms "
          f"({res.values.shape[1]} score columns, heads sharded {ndev}-way)")


if __name__ == "__main__":
    main()
