"""HTTP front door demo: the runtime as a network service.

Everything here talks to the server the way a real client would —
``http.client`` over a localhost socket, JSON bodies, an ``x-api-key``
header — with zero ``repro`` imports on the client side of the wire.
Four acts:

1. **Publish over the wire** — POST base64 artifact bytes to
   ``/v1/models``; the server spools, validates, content-addresses and
   aliases them exactly like a local ``add_file``.

2. **Coalesced predictions** — a burst of concurrent HTTP clients
   shares ``MicroBatcher`` flushes (the async bridge preserves
   deferred sync), and every response row carries the paper's §4
   validity verdict plus the serving digest.

3. **Typed refusals** — on a tenanted, deliberately-slow server:
   missing key ⇒ 401 ``unauthenticated``; a tenant over its bucket ⇒
   429 ``tenant_quota`` with a parseable ``Retry-After``; a full
   runtime queue ⇒ 429 ``overloaded``. Every shed — tenant or queue —
   lands in the SAME conservation identity, checkable over HTTP.

4. **Metrics scrape** — ``GET /metrics`` serves the runtime's
   Prometheus exposition verbatim.

    PYTHONPATH=src python examples/svm_http.py
"""

import base64
import concurrent.futures
import http.client
import json
from urllib.parse import urlparse

import numpy as np
import jax.numpy as jnp

from repro.core import gamma_max
from repro.core.families import maclaurin
from repro.core.rbf import SVMModel
from repro.serve import FaultInjector, Runtime
from repro.serve.server import TenantConfig, create_app, serve

DIM = 16
BURST_CLIENTS = 8
BURST_REQS = 6
REQ_ROWS = 4


def make_model(seed=0, d=DIM, n_sv=64):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * 0.5
    gamma = 0.8 * float(gamma_max(jnp.asarray(X)))
    ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
    return SVMModel(
        X=jnp.asarray(X),
        alpha_y=jnp.asarray(ay),
        b=jnp.float32(0.1),
        gamma=jnp.float32(gamma),
    )


class Client:
    """A thin JSON-over-HTTP client — stdlib only, no repro imports."""

    def __init__(self, url):
        u = urlparse(url)
        self.conn = http.client.HTTPConnection(u.hostname, u.port, timeout=30)

    def request(self, method, path, body=None, key=None):
        headers = {"content-type": "application/json"}
        if key:
            headers["x-api-key"] = key
        payload = json.dumps(body).encode() if body is not None else None
        self.conn.request(method, path, body=payload, headers=headers)
        resp = self.conn.getresponse()
        raw = resp.read()
        hdrs = {k.lower(): v for k, v in resp.getheaders()}
        ctype = hdrs.get("content-type", "")
        data = json.loads(raw) if ctype.startswith("application/json") else raw
        return resp.status, hdrs, data


def act_1_and_2_and_4(art):
    app = create_app(
        max_wait_us=100_000.0,  # generous window: let the burst coalesce
        engine_opts=dict(min_bucket=8, max_batch=64),
        warmup_on_load=False,
    )
    handle = serve(app)
    c = Client(handle.url)
    try:
        # ---- act 1: publish over the wire --------------------------------
        payload = base64.b64encode(art.to_bytes()).decode()
        status, _, out = c.request(
            "POST", "/v1/models",
            {"artifact_b64": payload, "spec": {"alias": "det"}},
        )
        digest = out["digest"]
        print(f"[publish] POST /v1/models -> {status}, digest {digest[:12]} "
              f"(content-addressed: digest == sha256 of the bytes)")
        assert digest == art.digest()
        _, _, listing = c.request("GET", "/v1/models")
        row = listing["models"][0]
        print(f"[publish] GET /v1/models -> aliases={row['aliases']} "
              f"loaded={row['loaded']} nbytes={row['nbytes']}")

        # ---- act 2: a coalesced burst with §4 verdicts -------------------
        before = app.runtime.stats("det")

        def burst(i):
            cc = Client(handle.url)
            got = []
            r = np.random.default_rng(100 + i)
            for _ in range(BURST_REQS):
                rows = (0.3 * r.standard_normal((REQ_ROWS, DIM))).tolist()
                s, _, o = cc.request(
                    "POST", "/v1/models/det:predict", {"rows": rows}
                )
                assert s == 200, o
                got.append(o)
            return got

        with concurrent.futures.ThreadPoolExecutor(BURST_CLIENTS) as pool:
            outs = [o for f in [pool.submit(burst, i)
                                for i in range(BURST_CLIENTS)]
                    for o in f.result()]
        n_rows = sum(o["n"] for o in outs)
        n_valid = sum(sum(o["valid"]) for o in outs)
        after = app.runtime.stats("det")
        flushes = after["flushes"] - before["flushes"]
        print(f"[predict] {len(outs)} HTTP requests ({n_rows} rows) from "
              f"{BURST_CLIENTS} clients -> {flushes} engine flushes "
              f"(coalescing {len(outs) / max(1, flushes):.1f}x)")
        print(f"[predict] §4 validity over the wire: {n_valid}/{n_rows} rows "
              f"fast-path valid; every response pinned digest "
              f"{outs[0]['digest'][:12]}")

        # ---- act 4: Prometheus scrape ------------------------------------
        status, hdrs, text = c.request("GET", "/metrics")
        lines = text.decode().splitlines()
        picked = [ln for ln in lines
                  if ln.startswith(("repro_serve_requests_total",
                                    "repro_serve_validity_fraction"))]
        print(f"[metrics] GET /metrics -> {status} "
              f"({hdrs['content-type'].split(';')[0]}, {len(lines)} lines):")
        for ln in picked[:4]:
            print(f"  {ln}")
    finally:
        handle.close()
        app.close()


def act_3_typed_refusals(art):
    # a deliberately slow engine (every flush pinned at 50 ms) behind a
    # small admission bound, plus one tenant whose request bucket holds
    # exactly 3 tokens and refills ~never
    fi = FaultInjector(seed=0, slow_step_rate=1.0, slow_step_s=0.05)
    app = create_app(
        max_wait_us=100.0,
        max_queue_rows=16,
        engine_opts=dict(min_bucket=8, max_batch=64),
        warmup_on_load=False,
        fault_injector=fi,
        tenants=[
            TenantConfig("acme", api_key="acme-key",
                         rate_rps=1e-6, burst=3),
            TenantConfig("umbrella", api_key="umbrella-key"),
        ],
    )
    handle = serve(app)
    try:
        c = Client(handle.url)
        payload = base64.b64encode(art.to_bytes()).decode()
        _, _, out = c.request(
            "POST", "/v1/models",
            {"artifact_b64": payload, "spec": {"alias": "det"}},
        )
        digest = out["digest"]
        rows = [[0.0] * DIM]

        status, _, body = c.request("POST", "/v1/models/det:predict",
                                    {"rows": rows})
        print(f"[refusals] no api key        -> {status} "
              f"{body['error']['code']}")

        verdicts = []
        for _ in range(6):
            status, hdrs, body = c.request(
                "POST", "/v1/models/det:predict", {"rows": rows},
                key="acme-key",
            )
            verdicts.append(
                (status, body.get("error", {}).get("code"),
                 hdrs.get("retry-after"))
            )
        ok = sum(1 for s, _, _ in verdicts if s == 200)
        s, code, retry = verdicts[-1]
        print(f"[refusals] tenant 'acme' (burst=3): {ok} admitted, then "
              f"{s} {code} with Retry-After: {retry}s")

        def flood(i):
            cc = Client(handle.url)
            r = np.random.default_rng(i)
            hits = []
            for _ in range(BURST_REQS):
                rw = (0.3 * r.standard_normal((REQ_ROWS, DIM))).tolist()
                s, h, o = cc.request(
                    "POST", "/v1/models/det:predict", {"rows": rw},
                    key="umbrella-key",
                )
                hits.append((s, o.get("error", {}).get("code"),
                             h.get("retry-after")))
            return hits

        with concurrent.futures.ThreadPoolExecutor(BURST_CLIENTS) as pool:
            hits = [h for f in [pool.submit(flood, i)
                                for i in range(BURST_CLIENTS)]
                    for h in f.result()]
        served = sum(1 for s, _, _ in hits if s == 200)
        shed = [h for h in hits if h[0] == 429]
        print(f"[refusals] unlimited tenant vs 50 ms flushes + "
              f"max_queue_rows=16: {served} served, {len(shed)} shed "
              f"{shed[0][1]} (Retry-After: {shed[0][2]}s)" if shed else
              f"[refusals] {served} served, no sheds (machine too fast)")

        # conservation holds ACROSS the network hop: the client's own 2xx/
        # 429 tally, the runtime's telemetry, and the span counters agree
        st = app.runtime.stats(digest)
        tenant_shed = sum(1 for s, code, _ in hits + verdicts
                          if s == 429 and code == "tenant_quota")
        _, _, tsnap = c.request("GET", "/v1/tenants")
        acme = next(t for t in tsnap["tenants"] if t["name"] == "acme")
        cons = (app.runtime.obs.tracer.conservation(digest[:12])
                if app.runtime.obs is not None else {})
        print(f"[conserve] client saw {served + ok} ok / "
              f"{len(shed) + (6 - ok)} shed; telemetry "
              f"served={st['served_requests']} shed={st['shed_requests']}; "
              f"spans unaccounted={cons.get('unaccounted')}")
        print(f"[conserve] GET /v1/tenants: acme admitted={acme['admitted']} "
              f"shed={acme['shed']} (tenant sheds: {tenant_shed})")
        assert cons.get("unaccounted", 0) == 0
        assert st["shed_requests"] == len(shed) + (6 - ok)
    finally:
        handle.close()
        app.close()


def main():
    art = maclaurin.compile(make_model())
    act_1_and_2_and_4(art)
    act_3_typed_refusals(art)


if __name__ == "__main__":
    main()
