"""LM serving demo: batched greedy decode with (a) the exact KV cache and
(b) the paper-technique Maclaurin state — same model weights, same API.

Prints the per-sequence cache footprint of both backends: the state is
O(d^2) per head, independent of context length (the paper's n_sv -> d^2
collapse with KV entries as support vectors).

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import init_cache, init_params
from repro.serve.decode_step import greedy_generate


def cache_bytes(cache) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def main():
    cfg = get_config("smollm-135m").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 4096
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)

    for backend in ("softmax", "maclaurin"):
        c = cfg.with_backend(backend)
        cache = init_cache(c, B, S, params=params, dtype=jnp.float32)
        toks, cache = greedy_generate(c, params, prompt, cache, steps=16, start_pos=0)
        per_seq = cache_bytes(cache) / B
        print(f"{backend:10s} backend: generated {toks.shape[1]} tokens/seq; "
              f"cache {per_seq/1024:.1f} KiB/seq at S={S} "
              f"({'grows with S' if backend == 'softmax' else 'independent of S'})")
        print(f"{'':10s} sample tokens: {toks[0, :8].tolist()}")


if __name__ == "__main__":
    main()
