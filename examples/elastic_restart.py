"""Fault-tolerance drill: train, die uncleanly mid-run, restart, resume.

Demonstrates the checkpoint/restart contract end-to-end by actually
spawning the launcher as a subprocess, killing it via --simulate-failure,
and restarting it. The restarted run resumes from the last committed async
checkpoint and replays the data stream (step-pure loader), so the loss
curve continues rather than restarting.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_elastic_ckpt"


def run(extra):
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-135m", "--reduced",
        "--steps", "90", "--batch", "4", "--seq", "64",
        "--ckpt-dir", CKPT, "--ckpt-every", "20", "--log-every", "10",
    ] + extra
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(cmd, env=env, capture_output=True, text=True)
    print(p.stdout, end="")
    return p.returncode


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    print("=== run 1: will lose a node at step 47 ===")
    rc = run(["--simulate-failure", "47"])
    assert rc == 42, f"expected simulated-failure exit 42, got {rc}"
    print("\n=== run 2: restart with identical flags — resumes from step 41 ===")
    rc = run([])
    assert rc == 0, rc
    print("\nelastic restart drill passed: loss continued from the restored step")


if __name__ == "__main__":
    main()
