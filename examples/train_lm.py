"""End-to-end training driver (deliverable b): train an LM from the
assigned-arch family zoo on the synthetic copy-structure corpus, with
checkpointing and crash-resume.

Default: a ~15M-param smollm-shape model, a few hundred steps on CPU.
The full 135M config trains with exactly the same code path on TPU
(PYTHONPATH=src python -m repro.launch.train --arch smollm-135m ... without
--reduced).

    PYTHONPATH=src python examples/train_lm.py            # ~10 min on 1 core
    PYTHONPATH=src python examples/train_lm.py --quick    # 60 steps
"""

import argparse

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()
    steps = "60" if args.quick else "300"
    train_main([
        "--arch", args.arch, "--reduced",
        "--steps", steps, "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "50",
        "--log-every", "10",
    ])
