"""Multi-tenant serving demo: one ``Runtime``, many models, many callers.

Builds on ``examples/svm_serving.py`` (train -> compile -> artifact file):
here TWO models are compiled, published into the content-addressed
registry under aliases, and served concurrently through the async
micro-batching scheduler. The walk-through shows the runtime's four
headline behaviors:

1. **Content addressing + dedupe** — artifacts are keyed on the SHA-256
   of their deterministic bytes; registering the same compile twice
   lands on one entry.
2. **Coalescing** — 8 client threads firing single-row requests are
   merged into bucket-sized engine steps (watch the coalescing factor
   and the zero-recompile guarantee).
3. **Accuracy contract under coalescing** — out-of-envelope rows inside
   a coalesced flush still fall back to the exact expansion, and each
   request gets its own rows back in order.
4. **Alias hot-swap** — ``publish`` atomically re-points ``detector``
   at a retrained model while traffic is in flight; in-flight requests
   finish on the old engine.

    PYTHONPATH=src python examples/svm_runtime.py
"""

import threading

import numpy as np
import jax.numpy as jnp

from repro.core import Budget, compile_model, gamma_max
from repro.data.synthetic import make_blobs
from repro.serve import Runtime
from repro.svm import train_lssvm


def train(seed, sep):
    X, y = make_blobs(400, 16, seed=seed, separation=sep)
    gamma = 0.8 * float(gamma_max(jnp.asarray(X)))
    return train_lssvm(jnp.asarray(X), jnp.asarray(y),
                       jnp.float32(gamma), jnp.float32(10.0))


def main():
    # compile two tenants (the §4 verification picks each one's family)
    budget = Budget(max_err=0.05, metric="mean_abs")
    det_model = train(3, 2.5)
    cls_model = train(7, 2.0)
    det_art = compile_model(det_model, budget, families=("maclaurin", "poly2"))
    cls_art = compile_model(cls_model, budget)

    rt = Runtime(
        max_wait_us=500.0,              # lone requests wait at most 0.5 ms
        flush_rows=64,                  # ... or flush as soon as a bucket fills
        engine_opts=dict(min_bucket=32, max_batch=256),
    )
    d1 = rt.publish("detector", det_art, exact=det_model)
    d2 = rt.publish("classifier", cls_art, exact=cls_model)
    assert rt.publish("detector", det_art, exact=det_model) == d1  # dedupe
    print(f"published detector   -> {d1[:12]} ({det_art.family})")
    print(f"published classifier -> {d2[:12]} ({cls_art.family})")

    # 8 concurrent clients, single-row requests, mixed tenants
    rng = np.random.default_rng(0)
    work = [
        [("detector" if rng.random() < 0.6 else "classifier",
          rng.standard_normal((1, 16)).astype(np.float32))
         for _ in range(40)]
        for _ in range(8)
    ]
    # a few out-of-envelope rows: served in the SAME coalesced flushes,
    # patched through the exact fallback without touching their neighbors
    for Z in (work[0][5][1], work[3][20][1]):
        Z *= 25.0

    def client(items, out):
        futs = [(name, rt.submit(name, Z)) for name, Z in items]  # open loop
        out.extend((name, f.result()) for name, f in futs)

    outs = [[] for _ in work]
    threads = [threading.Thread(target=client, args=(w, o))
               for w, o in zip(work, outs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fellback = sum((~r.valid).sum() for o in outs for _, r in o)
    print(f"\nserved {sum(len(o) for o in outs)} requests from 8 clients; "
          f"{fellback} rows fell back to the exact path inside coalesced flushes")
    for alias in ("detector", "classifier"):
        s = rt.stats(alias)
        print(f"  {alias:10s}: {s['requests']} reqs in {s['flushes']} engine "
              f"steps (coalescing x{s['coalescing_factor']}), "
              f"p99 {s['latency']['p99_ms']} ms, "
              f"fallback rate {100 * s['fallback_rate']:.1f}%, "
              f"{s['compiled_steps']} compiled variants (all from warmup)")

    # hot-swap the detector under live traffic
    stop = threading.Event()

    def background_traffic():
        Z = rng.standard_normal((2, 16)).astype(np.float32)
        while not stop.is_set():
            rt.predict("detector", Z)

    bg = threading.Thread(target=background_traffic)
    bg.start()
    new_model = train(13, 3.0)
    new_art = compile_model(new_model, budget, families=("maclaurin", "poly2"))
    d3 = rt.publish("detector", new_art, exact=new_model)   # atomic re-point
    stop.set()
    bg.join()
    print(f"\nhot-swapped detector -> {d3[:12]} while traffic was in flight")
    print(f"registry: {rt.stats()['registry']}")
    rt.close()


if __name__ == "__main__":
    main()
