"""Robust multi-tenant serving demo: one ``Runtime``, many models, many
callers — and everything that can go wrong, handled on stage.

Builds on ``examples/svm_serving.py`` (train -> compile -> artifact
file). Two models are compiled, published under aliases, and served
concurrently through the async micro-batching scheduler; then the demo
walks the runtime's robustness layer end to end:

1. **Coalescing + content addressing** — 8 client threads firing
   single-row requests merge into bucket-sized engine steps; artifacts
   are keyed on the SHA-256 of their deterministic bytes, so the same
   compile registers once. Out-of-envelope rows inside a coalesced
   flush fall back to the exact expansion without touching neighbors.
2. **Overload shedding** — the queue is BOUNDED (``max_queue_rows``).
   When a burst outruns capacity (the demo pins capacity with the
   fault injector's slow-step hook), admission control sheds the
   excess with typed ``RuntimeOverloaded`` carrying a ``retry_after_s``
   hint — bounded queue, bounded latency for everything admitted.
3. **Fault isolation + graceful degradation** — scripted engine faults
   fail exactly the batch they hit (the worker survives); three in a
   row trip the per-model circuit breaker, and while it holds the fast
   path open, traffic is served by the exact streaming ``rbf_pred``
   path (every row correct, ``valid`` all-False, and none of it
   pollutes the drift signal: an engine fault is not input drift).
   After ``reset_after_s`` a half-open probe closes the breaker again.
4. **Drift-triggered self-healing** — traffic drifts out of the
   compiled artifact's §4 validity envelope, so the windowed fallback
   rate climbs: correct, but slow forever. The ``DriftGuard`` notices,
   recompiles the family x dtype search against a reservoir sample of
   the LIVE traffic, canaries the candidate against the exact RBF
   judge, and atomically flips the alias — after which the same
   drifted traffic fast-paths again.

    PYTHONPATH=src python examples/svm_runtime.py
"""

import threading
import time

import numpy as np
import jax.numpy as jnp

from repro.core import Budget, compile_model, gamma_max
from repro.data.synthetic import make_blobs
from repro.serve import (
    DriftGuard,
    FaultInjector,
    PublishSpec,
    Runtime,
    RuntimeOverloaded,
)
from repro.serve.runtime import ENGINE_STEP
from repro.svm import train_lssvm

DIM = 16


def train(seed, sep):
    X, y = make_blobs(400, DIM, seed=seed, separation=sep)
    # moderate gamma: aggressive kernels shrink every family's envelope
    # so far that no recompile can cover drifted traffic (the heal in
    # act 4 needs at least one family whose envelope CAN fit it)
    gamma = 0.4 * float(gamma_max(jnp.asarray(X)))
    return train_lssvm(jnp.asarray(X), jnp.asarray(y),
                       jnp.float32(gamma), jnp.float32(10.0))


def main():
    budget = Budget(max_err=0.05, metric="mean_abs")
    det_model = train(3, 2.5)
    cls_model = train(7, 2.0)
    # the detector compiles to a quadform family on purpose: those carry
    # the PER-ROW §4 validity check the drift act needs to trip
    det_art = compile_model(det_model, budget, families=("maclaurin", "poly2"))
    cls_art = compile_model(cls_model, budget)

    faults = FaultInjector(seed=0, slow_step_s=0.02)
    rt = Runtime(
        max_wait_us=500.0,              # lone requests wait at most 0.5 ms
        flush_rows=64,                  # ... or flush as soon as a bucket fills
        max_queue_rows=256,             # admission bound: beyond this, shed
        breaker=dict(fail_threshold=3, reset_after_s=0.3),
        fault_injector=faults,
        engine_opts=dict(min_bucket=32, max_batch=256),
    )
    d1 = rt.publish("detector", det_art, PublishSpec(exact=det_model))
    d2 = rt.publish("classifier", cls_art, PublishSpec(exact=cls_model))
    assert rt.publish("detector", det_art, PublishSpec(exact=det_model)) == d1  # dedupe
    print(f"published detector   -> {d1[:12]} ({det_art.family})")
    print(f"published classifier -> {d2[:12]} ({cls_art.family})")

    # ---- act 1: coalescing under 8 concurrent clients, mixed tenants
    rng = np.random.default_rng(0)
    work = [
        [("detector" if rng.random() < 0.6 else "classifier",
          rng.standard_normal((1, DIM)).astype(np.float32))
         for _ in range(40)]
        for _ in range(8)
    ]
    for Z in (work[0][5][1], work[3][20][1]):   # out-of-envelope rows:
        Z *= 25.0                               # exact-fallback in place

    def client(items, out):
        futs = [(name, rt.submit(name, Z)) for name, Z in items]  # open loop
        out.extend((name, f.result()) for name, f in futs)

    outs = [[] for _ in work]
    threads = [threading.Thread(target=client, args=(w, o))
               for w, o in zip(work, outs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fellback = sum((~r.valid).sum() for o in outs for _, r in o)
    print(f"\n[coalescing] served {sum(len(o) for o in outs)} requests from "
          f"8 clients; {fellback} rows fell back inside coalesced flushes")
    for alias in ("detector", "classifier"):
        s = rt.stats(alias)
        print(f"  {alias:10s}: {s['requests']} reqs in {s['flushes']} engine "
              f"steps (coalescing x{s['coalescing_factor']}), "
              f"p99 {s['latency']['p99_ms']} ms")

    # ---- act 2: a burst past capacity is SHED, not queued unboundedly
    faults.slow_next(ENGINE_STEP, 1000)         # pin per-flush service time
    shed, admitted = [], []
    lock = threading.Lock()

    def bursty(batches):
        for Z in batches:
            try:
                f = rt.submit("classifier", Z)
            except RuntimeOverloaded as e:
                with lock:
                    shed.append(e.retry_after_s)
            else:
                with lock:
                    admitted.append(f)

    burst = [
        [rng.standard_normal((8, DIM)).astype(np.float32)
         for _ in range(40)]
        for _ in range(4)
    ]
    threads = [threading.Thread(target=bursty, args=(w,)) for w in burst]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for f in admitted:
        f.result().values                       # every admitted future resolves
    faults.clear_scripts(ENGINE_STEP)           # cancel the leftover slowness
    st = rt.stats("classifier")
    print(f"\n[overload] burst of {len(shed) + len(admitted)} requests against "
          f"a {rt.max_queue_rows}-row queue: {len(admitted)} admitted "
          f"(all served), {len(shed)} shed with "
          f"retry_after ~{(np.mean(shed) * 1e3 if shed else 0):.0f} ms hints "
          f"(telemetry agrees: {st['shed_requests']} sheds, "
          f"queue drained to {st['queue_rows']} rows)")

    # ---- act 3: engine faults trip the breaker; serving degrades, not dies
    Zb = rng.standard_normal((8, DIM)).astype(np.float32)
    faults.fail_next(ENGINE_STEP, 3)
    failures = 0
    for _ in range(3):
        try:
            rt.predict("classifier", Zb)
        except Exception:
            failures += 1                       # only ITS batch failed
    _, valid = rt.predict("classifier", Zb)         # breaker now open:
    st = rt.stats("classifier")                     # exact-served, not shed
    print(f"\n[breaker] {failures} injected engine faults failed only their "
          f"own batches, then tripped the breaker "
          f"(state={st['breaker']['state']}, trips={st['breaker']['trips']})")
    print(f"  degraded serving: {st['breaker']['degraded_requests']} request(s) "
          f"answered by the exact streaming path "
          f"(valid all-False: {not valid.any()})")
    time.sleep(0.35)                            # let reset_after_s elapse
    rt.predict("classifier", Zb)                # half-open probe, succeeds
    st = rt.stats("classifier")
    print(f"  after reset_after_s, one probe closed it again "
          f"(state={st['breaker']['state']}, probes={st['breaker']['probes']})")

    # ---- act 4: input drift -> red fallback window -> recompile/canary/flip
    # The heal budget is RELATIVE and looser than the publish budget: the
    # quadform families hit their §4 validity wall on the drifted regime
    # no matter how they recompile, so covering it means switching to the
    # globally-valid fourier family — which costs some error headroom
    # (a bigger basis buys it back; 4096 features here).
    guard = DriftGuard(
        rt, "detector", exact=det_model,
        budget=Budget(max_err=0.2, metric="mean_abs", relative=True),
        threshold=0.25, min_rows=64, min_agreement=0.9, seed=0,
        compile_opts=dict(family_opts={"fourier": {"num_features": 4096}}),
    ).attach()

    X_in, _ = make_blobs(400, DIM, seed=21, separation=2.5)
    X_in = np.asarray(X_in, np.float32)[:256]
    for i in range(0, 256, 8):                  # in-distribution traffic
        rt.predict("detector", X_in[i:i + 8])
    print(f"\n[drift] in-distribution window: "
          f"{guard.fallback_rate()} -> triggered={guard.check()['triggered']}")

    X_drift = X_in * 4.0                        # ||z||^2 leaves the envelope
    for _ in range(2):                          # drift PERSISTS — that is
        for i in range(0, 256, 8):              # what makes it drift, not
            rt.predict("detector", X_drift[i:i + 8])    # a one-off outlier
    print(f"  drifted window:         {guard.fallback_rate()}")
    verdict = guard.check()                     # recompile -> canary -> flip
    d3 = rt.registry.resolve("detector")
    print(f"  heal verdict: healed={verdict['healed']} "
          f"family={verdict.get('family')}[{verdict.get('dtype')}] "
          f"canary agreement {verdict.get('agreement', 0):.3f} "
          f"on {verdict.get('canary_rows')} reservoir rows")
    print(f"  alias flipped {verdict.get('old_digest', '?')[:12]} -> {d3[:12]}")
    for i in range(0, 256, 8):                  # same drifted traffic, again
        rt.predict("detector", X_drift[i:i + 8])
    print(f"  post-flip window:       {guard.fallback_rate()} "
          f"(the drifted traffic fast-paths on the healed artifact)")

    print(f"\nregistry: {rt.stats()['registry']}")
    rt.close()


if __name__ == "__main__":
    main()
