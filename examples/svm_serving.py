"""Serving demo (paper §4-§5): train -> compile -> serve.

The three stages are deliberately separable:

1. TRAIN an exact RBF model (training side, heavyweight).
2. COMPILE it with ``compile_model(svm, budget)`` — the paper's §4
   verification run across every approximation family (maclaurin
   quadratic form, §3.2 poly-2 expansion, random Fourier features) at
   every storage dtype (f32 and int8-quantized): each candidate is
   measured for error vs the exact expansion and serving latency on
   this host, and the cheapest artifact within the accuracy budget
   wins. The artifact is saved to an ``.npz`` file.
3. SERVE the artifact file in an ``SVMEngine`` — the engine never sees a
   training-side object; a real deployment would run this stage in a
   different process (the load below goes through the same bytes).

The engine pads every batch into a power-of-two shape bucket so repeated
traffic never recompiles, scores all heads through the family's fused
backend path, and enforces the family's accuracy contract at run time
(Eq 3.11 per-row envelope for the quadratic forms; the compile-time
held-out estimate for fourier), re-scoring violating rows exactly.

    PYTHONPATH=src python examples/svm_serving.py

This demo serves ONE model to ONE caller; for the multi-tenant layer —
content-addressed registry, alias hot-swap, async micro-batching across
concurrent clients — see ``examples/svm_runtime.py``.
"""

import os
import tempfile

import numpy as np
import jax.numpy as jnp

from repro.core import Budget, CompiledArtifact, compile_model, gamma_max
from repro.core import families
from repro.data.synthetic import make_blobs
from repro.serve.svm_engine import SVMEngine
from repro.svm import train_lssvm


def main():
    # 1. train (exact model, O(n_sv d) per prediction)
    X, y = make_blobs(600, 16, seed=3, separation=2.5)
    gamma = 0.8 * float(gamma_max(jnp.asarray(X)))
    model = train_lssvm(jnp.asarray(X), jnp.asarray(y), jnp.float32(gamma), jnp.float32(10.0))

    # 2. compile: measure every family against the budget, keep the cheapest
    artifact = compile_model(model, Budget(max_err=0.05, metric="mean_abs"))
    if artifact.meta.get("validity") != "per-row":
        # the out-of-envelope demo below exercises the PER-ROW fallback;
        # if this host's latency measurements crowned fourier (per-artifact
        # validity), pin the compilation to the quadform families instead
        artifact = compile_model(model, Budget(max_err=0.05, metric="mean_abs"),
                                 families=("maclaurin", "poly2"))
    report = artifact.meta["compile_report"]
    print(f"compiled families (budget mean_abs <= {report['limit']:.3g}):")
    for row in report["families"]:
        chosen = (row["family"] == report["chosen"]
                  and row.get("dtype") == report["chosen_dtype"])
        marker = "->" if chosen else "  "
        tag = f"{row['family']}[{row.get('dtype', '?')}]"
        if "skipped" in row:
            print(f"  {marker} {tag:18s} skipped: {row['skipped']}")
            continue
        print(f"  {marker} {tag:18s} err={row['mean_abs']:.4g} "
              f"latency={row['latency_ms']:.3f}ms bytes={row['artifact_bytes']}"
              f"{'' if row['meets_budget'] else '  (over budget)'}")

    path = os.path.join(tempfile.gettempdir(), "svm_artifact.npz")
    artifact.save(path)
    print(f"artifact -> {path} ({os.path.getsize(path)} bytes on disk)\n")

    # int8 variant of the same model: ~4x smaller serialized artifact, a
    # distinct content digest (the registry can hold both), and its own
    # measured quantization error in the meta.
    # recompile a CLEAN f32 parent rather than reusing the winner: the
    # winner's meta embeds the measured-latency compile_report, so its
    # digest is not the stable registry identity of the f32 variant
    fam = families.get_family(artifact.family)
    f32_art = fam.compile(model)
    q8_art = fam.compile(model, dtype="int8")
    print(f"int8 variant of {artifact.family!r}: "
          f"weight arrays {f32_art.nbytes()} -> {q8_art.nbytes()} bytes "
          f"({f32_art.nbytes() / q8_art.nbytes():.2f}x smaller; this demo "
          f"model is tiny, so the ~2 KB npz header hides most of it on "
          f"disk — see the model_size benchmark for real footprints), "
          f"quant err mean={q8_art.meta['quant_mean_abs_err']:.2e} "
          f"max={q8_art.meta['quant_max_abs_err']:.2e}, "
          f"digest {f32_art.digest()[:12]} vs {q8_art.digest()[:12]}\n")

    # 3. serve: reload from bytes (no training objects needed) and stream
    served = CompiledArtifact.load(path)
    engine = SVMEngine(served, model)      # exact model only for the fallback

    rng = np.random.default_rng(0)
    print("serving 20 batches; batch 9 and 14 contain out-of-envelope rows")
    for b in range(20):
        Z = rng.standard_normal((64, 16)).astype(np.float32)
        if b in (9, 14):
            Z[:5] *= 25.0  # rows violating the accuracy contract
        f, valid = engine.predict(jnp.asarray(Z))
        flag = "" if valid.all() else f"  <- {int((~valid).sum())} rows fell back to exact"
        print(f"batch {b:2d}: mean|f|={np.abs(f).mean():.3f}{flag}")

    s = engine.stats
    print(f"\nstats: {s.instances} instances in {s.batches} batches "
          f"served by the {engine.family!r} family; "
          f"fallback rate {100*s.fallback_rate:.2f}% "
          f"(accuracy contract held with the fast path for the rest)")
    print(f"shape buckets hit: {dict(sorted(s.bucket_hits.items()))}; "
          f"compiled step variants: {engine.jit_cache_size()} "
          f"(zero steady-state recompiles); "
          f"padding overhead {100*s.padding_overhead:.1f}%")


if __name__ == "__main__":
    main()
