"""Serving demo (paper §5): high-throughput SVM prediction with the
approximated model, run-time bound checking, and exact-model fallback.

The engine pads every batch into a power-of-two shape bucket so repeated
traffic never recompiles, scores all heads through the fused quadratic-form
backend, and defers host synchronization until results are read.

    PYTHONPATH=src python examples/svm_serving.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import approximate, gamma_max
from repro.data.synthetic import make_blobs
from repro.serve.svm_engine import SVMEngine
from repro.svm import train_lssvm


def main():
    X, y = make_blobs(600, 16, seed=3, separation=2.5)
    gamma = 0.8 * float(gamma_max(jnp.asarray(X)))
    model = train_lssvm(jnp.asarray(X), jnp.asarray(y), jnp.float32(gamma), jnp.float32(10.0))
    engine = SVMEngine(approximate(model), model)

    rng = np.random.default_rng(0)
    print("serving 20 batches; batch 9 and 14 contain out-of-envelope rows")
    for b in range(20):
        Z = rng.standard_normal((64, 16)).astype(np.float32)
        if b in (9, 14):
            Z[:5] *= 25.0  # rows violating the Eq 3.11 envelope
        f, valid = engine.predict(jnp.asarray(Z))
        flag = "" if valid.all() else f"  <- {int((~valid).sum())} rows fell back to exact"
        print(f"batch {b:2d}: mean|f|={np.abs(f).mean():.3f}{flag}")

    s = engine.stats
    print(f"\nstats: {s.instances} instances in {s.batches} batches; "
          f"fallback rate {100*s.fallback_rate:.2f}% "
          f"(accuracy contract held with the approx fast path for the rest)")
    print(f"shape buckets hit: {dict(sorted(s.bucket_hits.items()))}; "
          f"compiled step variants: {engine.jit_cache_size()} "
          f"(zero steady-state recompiles); "
          f"padding overhead {100*s.padding_overhead:.1f}%")


if __name__ == "__main__":
    main()
