"""HTTP front door integration tests — a real localhost server, real
``http.client`` requests with NO repro imports on the client side of
the wire, driving the real coalescing/admission/observability stack.

The load-bearing assertions:

  * concurrent HTTP clients coalesce into SHARED flushes (the §3
    micro-batching win survives the network hop);
  * a 429 carries a parseable integer ``Retry-After`` and the taxonomy
    body (``code: overloaded``);
  * tenant-quota sheds CONSERVE: client-observed 429s == telemetry
    ``shed_requests`` == ``request.shed`` spans, and
    ``Tracer.conservation`` stays balanced;
  * alias hot-swap mid-traffic routes new requests to the new digest
    with zero failed requests;
  * ``/metrics`` parses as Prometheus text exposition.
"""

import base64
import http.client
import json
import re
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gamma_max
from repro.core.rbf import SVMModel
from repro.core.families import fourier, maclaurin
from repro.serve import PublishSpec, create_app
from repro.serve.runtime import FaultInjector, Runtime
from repro.serve.server import TenantConfig, serve

ENGINE_OPTS = dict(min_bucket=8, max_batch=64)


def _svm(seed=0, d=8, n_sv=40, bias=0.1):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * 0.6
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
    return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                    b=jnp.float32(bias), gamma=jnp.float32(gamma))


def _rows(rng, n, d=8):
    return (rng.standard_normal((n, d)) * 0.3).tolist()


class _Client:
    """Tiny JSON-over-HTTP client: stdlib only, one connection, no
    repro imports — the acceptance criterion's 'external client'."""

    def __init__(self, host, port):
        self.conn = http.client.HTTPConnection(host, port, timeout=60)

    def request(self, method, path, body=None, headers=None):
        hdrs = dict(headers or {})
        data = None
        if body is not None:
            data = json.dumps(body).encode()
            hdrs["Content-Type"] = "application/json"
        self.conn.request(method, path, body=data, headers=hdrs)
        resp = self.conn.getresponse()
        raw = resp.read()
        parsed = json.loads(raw) if raw and resp.headers.get(
            "content-type", "").startswith("application/json") else raw
        return resp.status, parsed, {
            k.lower(): v for k, v in resp.headers.items()
        }

    def close(self):
        self.conn.close()


def _app_and_server(runtime=None, tenants=None, **runtime_kw):
    runtime_kw.setdefault("engine_opts", ENGINE_OPTS)
    runtime_kw.setdefault("warmup_on_load", False)
    app = create_app(runtime, tenants=tenants,
                     **(runtime_kw if runtime is None else {}))
    handle = serve(app)
    return app, handle


def _publish(app, model, alias, family=maclaurin, **spec_kw):
    art = family.compile(model)
    return app.runtime.publish(
        alias, art, PublishSpec(exact=model, **spec_kw)
    )


# ------------------------------------------------------------ basic contract


def test_predict_returns_scores_validity_and_digest():
    app, h = _app_and_server()
    with app, h:
        m = _svm(0)
        digest = _publish(app, m, "det")
        c = _Client(h.host, h.port)
        status, body, _ = c.request(
            "POST", "/v1/models/det:predict",
            {"rows": _rows(np.random.default_rng(0), 5)})
        assert status == 200
        assert body["digest"] == digest
        assert body["n"] == 5
        assert len(body["scores"]) == 5 and len(body["labels"]) == 5
        assert body["valid"] == [True] * 5          # in-envelope traffic
        assert body["family"] == "maclaurin"
        # digest-addressed and prefix-addressed refs serve identically
        status2, body2, _ = c.request(
            "POST", f"/v1/models/{digest[:12]}:predict",
            {"rows": _rows(np.random.default_rng(0), 5)})
        assert status2 == 200 and body2["scores"] == body["scores"]
        c.close()


def test_error_taxonomy_maps_onto_http():
    app, h = _app_and_server()
    with app, h:
        _publish(app, _svm(0), "det")
        c = _Client(h.host, h.port)
        cases = [
            ("POST", "/v1/models/nope:predict", {"rows": [[0.0] * 8]},
             404, "model_not_found"),
            ("POST", "/v1/models/det:predict", {"rowz": []},
             400, "invalid_request"),
            ("POST", "/v1/models/det:predict", None,
             400, "invalid_request"),          # empty body
            ("GET", "/v1/nowhere", None, 404, "not_found"),
            ("DELETE", "/v1/models", None, 405, "method_not_allowed"),
        ]
        for method, path, body, want_status, want_code in cases:
            status, parsed, _ = c.request(method, path, body)
            assert status == want_status, (path, status, parsed)
            assert parsed["error"]["code"] == want_code
            assert parsed["error"]["status"] == want_status
        c.close()


def test_http_publish_then_predict_no_repro_client_imports():
    """The acceptance path: artifact bytes over the wire, digest back,
    predictions against the digest — client knows nothing of repro."""
    app, h = _app_and_server()
    with app, h:
        art = maclaurin.compile(_svm(4))
        payload = base64.b64encode(art.to_bytes()).decode()
        c = _Client(h.host, h.port)
        status, body, _ = c.request(
            "POST", "/v1/models",
            {"artifact_b64": payload, "spec": {"alias": "uploaded"}})
        assert status == 201
        digest = body["digest"]
        assert digest == art.digest()      # content addressing end to end
        status, listing, _ = c.request("GET", "/v1/models")
        assert status == 200
        assert [m["digest"] for m in listing["models"]] == [digest]
        assert listing["models"][0]["aliases"] == ["uploaded"]
        status, body, _ = c.request(
            "POST", "/v1/models/uploaded:predict",
            {"rows": _rows(np.random.default_rng(1), 3)})
        assert status == 200 and body["digest"] == digest
        # a corrupt upload is refused with the taxonomy, never indexed
        bad = base64.b64encode(art.to_bytes()[:100]).decode()
        status, body, _ = c.request(
            "POST", "/v1/models", {"artifact_b64": bad, "spec": {}})
        assert status == 503
        assert body["error"]["code"] == "artifact_corrupt"
        c.close()


# ------------------------------------------------------------- coalescing


def test_concurrent_clients_coalesce_into_shared_flushes():
    # a wide flush window so a burst of HTTP requests lands in ONE
    # coalescing window; each client sends 1 row, the engine's
    # min_bucket is 8 — shared flushes are the only way this stays
    # under requests/2 flushes
    app, h = _app_and_server(max_wait_us=100_000.0)
    with app, h:
        _publish(app, _svm(0), "det")
        warm = _Client(h.host, h.port)
        warm.request("POST", "/v1/models/det:predict",
                     {"rows": _rows(np.random.default_rng(0), 2)})
        warm.close()
        n_clients = 12
        barrier = threading.Barrier(n_clients)
        results = [None] * n_clients

        def worker(i):
            c = _Client(h.host, h.port)
            rows = _rows(np.random.default_rng(100 + i), 1)
            barrier.wait()
            results[i] = c.request(
                "POST", "/v1/models/det:predict", {"rows": rows})
            c.close()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(r[0] == 200 for r in results)
        st = app.runtime.stats("det")
        burst_flushes = st["flushes"] - 1            # minus the warmup flush
        assert st["requests"] == n_clients + 1
        assert burst_flushes <= n_clients // 2, st["flushes"]
        assert st["served_requests"] == n_clients + 1


# ------------------------------------------------- overload + Retry-After


def test_overload_returns_429_with_parseable_retry_after():
    fi = FaultInjector(0, slow_step_rate=1.0, slow_step_s=0.05)
    rt = Runtime(engine_opts=ENGINE_OPTS, warmup_on_load=False,
                 fault_injector=fi, max_queue_rows=16, max_wait_us=100.0)
    app = create_app(rt)
    with rt, app, serve(app) as h:
        _publish(app, _svm(1), "det")
        warm = _Client(h.host, h.port)
        warm.request("POST", "/v1/models/det:predict",
                     {"rows": _rows(np.random.default_rng(0), 2)})
        n_clients, per_client = 10, 6
        outcomes = []
        lock = threading.Lock()

        def worker(i):
            c = _Client(h.host, h.port)
            rng = np.random.default_rng(200 + i)
            for _ in range(per_client):
                status, body, headers = c.request(
                    "POST", "/v1/models/det:predict",
                    {"rows": _rows(rng, 4)})
                with lock:
                    outcomes.append((status, body, headers))
            c.close()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        ok = [o for o in outcomes if o[0] == 200]
        shed = [o for o in outcomes if o[0] == 429]
        assert len(ok) + len(shed) == n_clients * per_client
        assert shed, "burst never overloaded the bounded queue"
        for status, body, headers in shed:
            retry = headers.get("retry-after")
            assert retry is not None and int(retry) >= 1    # parseable, RFC
            assert body["error"]["code"] == "overloaded"
            assert body["error"]["retry_after_s"] > 0.0
        # client-observed sheds match the runtime's own accounting
        st = rt.stats("det")
        assert st["shed_requests"] == len(shed)
        digest = st["digest"]
        cons = rt.obs.tracer.conservation(digest[:12])
        assert cons["unaccounted"] == 0, cons
        assert cons["shed"] == len(shed)
        assert cons["served"] == len(ok) + 1                 # + warmup
        warm.close()


def test_deadline_maps_to_504():
    fi = FaultInjector(0, slow_step_rate=1.0, slow_step_s=0.25)
    rt = Runtime(engine_opts=ENGINE_OPTS, warmup_on_load=False,
                 fault_injector=fi, max_wait_us=100.0)
    app = create_app(rt)
    with rt, app, serve(app) as h:
        _publish(app, _svm(1), "det")
        c = _Client(h.host, h.port)
        c.request("POST", "/v1/models/det:predict",
                  {"rows": _rows(np.random.default_rng(0), 2)})   # warm

        # occupy the engine with a slow flush so the deadline request
        # expires IN QUEUE (deadlines bound queue wait, not service)
        def occupy():
            blocker = _Client(h.host, h.port)
            blocker.request("POST", "/v1/models/det:predict",
                            {"rows": _rows(np.random.default_rng(2), 2)})
            blocker.close()

        t = threading.Thread(target=occupy)
        t.start()
        time.sleep(0.05)                      # blocker's flush is in service
        status, body, _ = c.request(
            "POST", "/v1/models/det:predict",
            {"rows": _rows(np.random.default_rng(1), 2),
             "deadline_s": 0.05})
        t.join()
        assert status == 504
        assert body["error"]["code"] == "deadline_exceeded"
        c.close()


# ----------------------------------------------------------------- tenancy


def test_tenant_quota_sheds_conserve_across_all_layers():
    # rate 1e-6 rps with burst 3: exactly 3 admits, then sheds for the
    # next ~11 days — deterministic without clock injection
    tenants = [
        TenantConfig(name="acme", api_key="k-acme",
                     rate_rps=1e-6, burst=3),
        TenantConfig(name="umbrella", api_key="k-umb",
                     rows_per_s=1e-6, row_burst=8),
    ]
    app, h = _app_and_server(tenants=tenants)
    with app, h:
        digest = _publish(app, _svm(0), "det")
        c = _Client(h.host, h.port)
        rng = np.random.default_rng(0)

        # no key / bad key → 401 before anything is accounted
        status, body, _ = c.request(
            "POST", "/v1/models/det:predict", {"rows": _rows(rng, 1)})
        assert status == 401 and body["error"]["code"] == "unauthenticated"
        status, _, _ = c.request(
            "POST", "/v1/models/det:predict", {"rows": _rows(rng, 1)},
            headers={"x-api-key": "wrong"})
        assert status == 401

        # acme: 3 request tokens, then request-rate sheds
        acme_ok = acme_shed = 0
        for _ in range(7):
            status, body, headers = c.request(
                "POST", "/v1/models/det:predict", {"rows": _rows(rng, 2)},
                headers={"x-api-key": "k-acme"})
            if status == 200:
                acme_ok += 1
            else:
                acme_shed += 1
                assert status == 429
                assert body["error"]["code"] == "tenant_quota"
                assert body["error"]["tenant"] == "acme"
                assert body["error"]["quota"] == "rate_rps"
                assert int(headers["retry-after"]) >= 1
        assert (acme_ok, acme_shed) == (3, 4)

        # umbrella: 8 row tokens → a 5-row then a 3-row pass, then shed
        umb_ok = umb_shed = 0
        for n in (5, 3, 2, 2):
            status, body, _ = c.request(
                "POST", "/v1/models/det:predict", {"rows": _rows(rng, n)},
                headers={"x-api-key": "k-umb"})
            if status == 200:
                umb_ok += 1
            else:
                umb_shed += 1
                assert body["error"]["quota"] == "rows_per_s"
        assert (umb_ok, umb_shed) == (2, 2)

        # three-way conservation: client == telemetry == spans
        client_shed = acme_shed + umb_shed
        client_ok = acme_ok + umb_ok
        st = app.runtime.stats("det")
        assert st["shed_requests"] == client_shed
        assert st["served_requests"] == client_ok
        cons = app.runtime.obs.tracer.conservation(digest[:12])
        assert cons["unaccounted"] == 0, cons
        assert cons["shed"] == client_shed
        assert cons["served"] == client_ok
        assert cons["submitted"] == client_ok + client_shed
        # the shed spans name the tenant and the quota
        sheds = app.runtime.obs.tracer.spans(digest[:12], "request.shed")
        assert sorted(s["attrs"]["tenant"] for s in sheds) == sorted(
            ["acme"] * acme_shed + ["umbrella"] * umb_shed)
        assert all(s["attrs"]["reason"] == "tenant_quota" for s in sheds)
        # per-tenant accounting agrees with the client too
        status, tsnap, _ = c.request("GET", "/v1/tenants")
        by_name = {t["name"]: t for t in tsnap["tenants"]}
        assert by_name["acme"]["shed"] == acme_shed
        assert by_name["acme"]["admitted"] == acme_ok
        assert by_name["umbrella"]["shed_rows"] == 4
        c.close()


def test_tenant_max_rows_is_a_400_not_a_shed():
    tenants = [TenantConfig(name="t", api_key="k", max_rows=4)]
    app, h = _app_and_server(tenants=tenants)
    with app, h:
        _publish(app, _svm(0), "det")
        c = _Client(h.host, h.port)
        status, body, _ = c.request(
            "POST", "/v1/models/det:predict",
            {"rows": _rows(np.random.default_rng(0), 5)},
            headers={"x-api-key": "k"})
        assert status == 400
        assert body["error"]["code"] == "invalid_request"
        assert app.runtime.stats("det")["shed_requests"] == 0
        c.close()


# --------------------------------------------------------------- hot swap


def test_alias_hot_swap_mid_traffic_routes_new_requests():
    app, h = _app_and_server(max_wait_us=500.0)
    with app, h:
        m = _svm(0)
        d1 = _publish(app, m, "det", family=maclaurin)
        art2 = fourier.compile(m)
        stop = threading.Event()
        seen, errors = [], []
        lock = threading.Lock()

        def traffic(i):
            c = _Client(h.host, h.port)
            rng = np.random.default_rng(300 + i)
            while not stop.is_set():
                status, body, _ = c.request(
                    "POST", "/v1/models/det:predict", {"rows": _rows(rng, 2)})
                with lock:
                    if status == 200:
                        seen.append(body["digest"])
                    else:
                        errors.append((status, body))
            c.close()

        threads = [threading.Thread(target=traffic, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        admin = _Client(h.host, h.port)

        def wait_for(count, timeout=60.0):
            t0 = time.monotonic()
            while time.monotonic() - t0 < timeout:
                with lock:
                    if len(seen) >= count or errors:
                        return
                time.sleep(0.005)
            raise AssertionError(f"traffic stalled below {count} responses")

        wait_for(8)                           # live old-digest traffic first
        payload = base64.b64encode(art2.to_bytes()).decode()
        status, body, _ = admin.request(
            "POST", "/v1/models",
            {"artifact_b64": payload, "spec": {"alias": "det"}})
        assert status == 201
        d2 = body["digest"]
        assert d2 != d1
        # every NEW request routes to the new digest
        with lock:
            after_flip = len(seen)
        wait_for(after_flip + 8)
        stop.set()
        for t in threads:
            t.join()
        assert not errors, errors[:3]
        assert set(seen) == {d1, d2}          # both digests served, no third
        tail = seen[-4:]
        assert all(d == d2 for d in tail), "new requests still on old digest"
        admin.close()


# -------------------------------------------------------------- management


def test_evict_replicas_and_stats_routes():
    app, h = _app_and_server()
    with app, h:
        digest = _publish(app, _svm(0), "det")
        c = _Client(h.host, h.port)
        rng = np.random.default_rng(0)
        c.request("POST", "/v1/models/det:predict", {"rows": _rows(rng, 2)})

        status, body, _ = c.request("POST", "/v1/models/det:replicas",
                                    {"replicas": 2})
        assert status == 200 and body == {"digest": digest, "replicas": 2}
        status, body, _ = c.request(
            "POST", "/v1/models/det:predict", {"rows": _rows(rng, 2)})
        assert status == 200                   # rescale is a live operation

        status, body, _ = c.request("POST", "/v1/models/det:evict", None)
        assert status == 200 and body["evicted"]
        status, listing, _ = c.request("GET", "/v1/models")
        assert listing["models"][0]["loaded"] is False
        status, body, _ = c.request(
            "POST", "/v1/models/det:predict", {"rows": _rows(rng, 2)})
        assert status == 200                   # transparent rebuild

        status, body, _ = c.request("POST", "/v1/models/det:alias",
                                    {"alias": "prod"})
        assert status == 200 and body["digest"] == digest
        status, st, _ = c.request("GET", "/v1/models/det/stats")
        assert status == 200 and st["digest"] == digest
        assert st["served_requests"] >= 3
        status, st, _ = c.request("GET", "/v1/stats")
        assert status == 200 and digest[:12] in st["models"]
        c.close()


# ----------------------------------------------------------------- metrics


_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$"
)


def test_metrics_endpoint_parses_as_prometheus_text():
    app, h = _app_and_server()
    with app, h:
        _publish(app, _svm(0), "det")
        c = _Client(h.host, h.port)
        c.request("POST", "/v1/models/det:predict",
                  {"rows": _rows(np.random.default_rng(0), 3)})
        status, raw, headers = c.request("GET", "/metrics")
        assert status == 200
        assert headers["content-type"].startswith("text/plain")
        text = raw.decode() if isinstance(raw, bytes) else raw
        assert text == app.runtime.render_prometheus()   # served VERBATIM
        names = set()
        for line in text.strip().splitlines():
            if line.startswith("# HELP") or line.startswith("# TYPE"):
                assert len(line.split(None, 3)) >= 3
                continue
            assert _PROM_LINE.match(line), line
            names.add(line.split("{")[0].split(" ")[0])
        assert any(n.startswith("repro_serve_") for n in names)
        c.close()
