"""SVM substrate tests: LS-SVM / dual SVC trainers, multiclass, engine."""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    approximate,
    approx_decision_function_checked,
    decision_function,
    gamma_max,
)
from repro.data.synthetic import make_blobs, make_dataset
from repro.serve.svm_engine import SVMEngine
from repro.svm import train_lssvm, train_svc
from repro.svm.dual import compress_support
from repro.svm.multiclass import (
    approx_ovr_predict,
    approximate_ovr,
    ovr_predict,
    train_one_vs_rest,
)


def _blob_task(seed=0, n=240, d=6):
    X, y = make_blobs(n, d, seed=seed, separation=3.0)
    n_tr = (2 * n) // 3
    return (
        jnp.asarray(X[:n_tr]), jnp.asarray(y[:n_tr]),
        jnp.asarray(X[n_tr:]), y[n_tr:],
    )


def test_lssvm_accuracy_and_approx_diff():
    X, y, Xte, yte = _blob_task()
    gamma = float(gamma_max(X)) * 0.8
    m = train_lssvm(X, y, jnp.float32(gamma), jnp.float32(10.0))
    f = np.asarray(decision_function(m, Xte))
    acc = (np.sign(f) == yte).mean()
    assert acc >= 0.88
    am = approximate(m)
    fh, valid = approx_decision_function_checked(am, Xte)
    assert np.asarray(valid).all()
    diff = (np.sign(np.asarray(fh)) != np.sign(f)).mean()
    assert diff < 0.02  # paper Table 1: <1% typical under the bound


def test_svc_sparse_and_consistent():
    X, y, Xte, yte = _blob_task(seed=5)
    gamma = float(gamma_max(X)) * 0.8
    m, mask = train_svc(X, y, jnp.float32(gamma), jnp.float32(1.0), num_steps=800)
    assert 0 < int(mask.sum()) < len(y)  # sparsity: true SVM behaviour
    mc = compress_support(m, mask)
    np.testing.assert_allclose(
        np.asarray(decision_function(mc, Xte)),
        np.asarray(decision_function(m, Xte)),
        rtol=1e-4, atol=1e-4,
    )
    acc = (np.sign(np.asarray(decision_function(m, Xte))) == yte).mean()
    assert acc > 0.85


def test_multiclass_ovr_and_approx():
    rng = np.random.default_rng(3)
    K, n, d = 3, 120, 5
    mus = rng.standard_normal((K, d)) * 3
    X = np.concatenate([rng.standard_normal((n // K, d)) + mus[k] for k in range(K)])
    y = np.concatenate([np.full(n // K, k) for k in range(K)])
    X, y = jnp.asarray(X.astype(np.float32)), jnp.asarray(y)
    gamma = float(gamma_max(X)) * 0.5
    m = train_one_vs_rest(X, y, K, jnp.float32(gamma), jnp.float32(10.0))
    pred = np.asarray(ovr_predict(m, X))
    assert (pred == np.asarray(y)).mean() > 0.9
    am = approximate_ovr(m)
    pred_a = np.asarray(approx_ovr_predict(am, X))
    assert (pred_a != pred).mean() < 0.05


def test_engine_fallback_on_bound_violation():
    X, y, Xte, _ = _blob_task(seed=7)
    gamma = float(gamma_max(X)) * 0.8
    m = train_lssvm(X, y, jnp.float32(gamma), jnp.float32(10.0))
    eng = SVMEngine(approximate(m), m)
    # in-envelope batch: no fallback
    f, valid = eng.predict(Xte)
    assert valid.all() and eng.stats.fallback_instances == 0
    # out-of-envelope rows: fallback gives the EXACT values
    Zbad = jnp.concatenate([Xte[:4], 50.0 * Xte[:3]], axis=0)
    f2, valid2 = eng.predict(Zbad)
    assert (~valid2).sum() == 3
    exact = np.asarray(decision_function(m, Zbad))
    np.testing.assert_allclose(f2[~valid2], exact[~valid2], rtol=1e-4, atol=1e-4)
    assert eng.stats.fallback_instances == 3


def test_paper_dataset_generators():
    for name in ("a9a", "mnist", "ijcnn1", "sensit", "epsilon"):
        Xtr, ytr, Xte, yte, spec = make_dataset(name, scale=0.002)
        assert Xtr.shape[1] == spec.d
        assert set(np.unique(ytr)) <= {-1.0, 1.0}
        assert len(Xte) >= 64
