"""Scale-out layer: replicated engine dispatch (least-loaded routing,
per-replica breakers, fault isolation, atomic replica retirement) and
head-sharded extreme multiclass serving (pad -> shard_map -> slice
parity). Runs on however many devices the host exposes — one in the
plain tier-1 suite, eight in CI's forced-host-device step — so every
assertion here is device-count agnostic.

Also covers the roofline analytic prior: candidate pre-pruning in
``autotune`` (rank-and-prune, default always measured) and cost
pre-pruning in ``compile_model``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import gamma_max
from repro.core.rbf import SVMModel, rbf_kernel
from repro.core.families import Budget, compile_model, fourier, maclaurin
from repro.kernels.common import autotune, tuning
from repro.kernels.common.config import TileConfig
from repro.launch import roofline
from repro.serve import PublishSpec, Runtime
from repro.serve.runtime import (
    ENGINE_STEP,
    ArtifactRegistry,
    FaultInjector,
    InjectedFault,
    MetricsRegistry,
    Observability,
)
from repro.serve.svm_engine import SVMEngine

ENGINE_OPTS = dict(min_bucket=8, max_batch=64)


def _svm(seed=0, d=8, n_sv=40, bias=0.1, scale=0.6):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * scale
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
    return SVMModel(
        X=jnp.asarray(X),
        alpha_y=jnp.asarray(ay),
        b=jnp.float32(bias),
        gamma=jnp.float32(gamma),
    )


def _svm_mc(seed=0, d=8, n_sv=40, k=6, scale=0.6):
    """One-vs-rest multiclass model: (k, n_sv) duals, (k,) biases."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * scale
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    ay = rng.standard_normal((k, n_sv)).astype(np.float32) * 0.5
    b = (rng.standard_normal(k) * 0.1).astype(np.float32)
    return SVMModel(
        X=jnp.asarray(X),
        alpha_y=jnp.asarray(ay),
        b=jnp.asarray(b),
        gamma=jnp.float32(gamma),
    )


def _exact_scores(m, Z):
    ay2 = m.alpha_y if m.alpha_y.ndim == 2 else m.alpha_y[None, :]
    b2 = jnp.reshape(m.b, (ay2.shape[0],))
    return np.asarray(
        rbf_kernel(jnp.asarray(Z), m.X, m.gamma) @ ay2.T + b2[None, :]
    )


def _rows(rng, n, d=8, scale=0.3):
    return rng.standard_normal((n, d)).astype(np.float32) * scale


def _head_mesh():
    return Mesh(np.array(jax.local_devices()), ("heads",))


# ---------------------------------------------------------- replica dispatch


def test_replicated_publish_spreads_flushes_and_conserves():
    m = _svm(1)
    art = maclaurin.compile(m)
    with Runtime(engine_opts=ENGINE_OPTS, max_wait_us=500.0) as rt:
        rt.publish("m", art, PublishSpec(exact=m, replicas=3))
        _, engines = rt.registry.get_engines("m")
        assert len(engines) == 3
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))  # warm + build
        cache_before = sum(e.jit_cache_size() for e in engines)
        # sequential submits: idle replicas tie on load, so the
        # round-robin tiebreak must rotate flushes across all three
        for _ in range(6):
            Z = _rows(rng, 8)
            res = rt.submit("m", Z).result(timeout=30.0)
            np.testing.assert_allclose(
                np.asarray(res.values), _exact_scores(m, Z)[:, 0], atol=0.15
            )
        st = rt.stats("m")
        per = st["replicas"]
        assert sorted(per) == ["0", "1", "2"]
        assert all(per[i]["flushes"] >= 1 for i in per)
        assert sum(per[i]["flushes"] for i in per) == st["flushes"]
        assert sum(per[i]["rows"] for i in per) == st["rows"]
        assert st["failed_requests"] == 0 and st["shed_requests"] == 0
        assert st["queue_rows"] == 0
        # replicated dispatch keeps the zero-steady-state-recompile law
        assert sum(e.jit_cache_size() for e in engines) == cache_before


def test_replica_fault_trips_only_its_own_breaker():
    m = _svm(2)
    fi = FaultInjector(0)
    with Runtime(
        engine_opts=ENGINE_OPTS,
        fault_injector=fi,
        max_wait_us=500.0,
        breaker=dict(fail_threshold=1, reset_after_s=60.0),
    ) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m, replicas=3))
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))  # warm flush -> replica 0
        # script the NEXT flush on replica 1 only; siblings stay healthy
        fi.fail_next(FaultInjector.replica_site(ENGINE_STEP, 1), 1)
        doomed = rt.submit("m", _rows(rng, 3))  # rotation -> replica 1
        with pytest.raises(InjectedFault):
            doomed.result(timeout=30.0)
        # replica 1 is open (threshold 1); 0 and 2 keep the FAST path —
        # the whole model never degrades to exact serving
        served = 0
        for _ in range(6):
            res = rt.submit("m", _rows(rng, 4)).result(timeout=30.0)
            assert np.asarray(res.valid).all()  # fast path, not degraded
            served += 1
        st = rt.stats("m")
        per = st["replicas"]
        assert per["1"]["breaker_state"] == "open"
        assert per["1"]["trips"] == 1 and per["1"]["failures"] == 1
        assert per["0"]["breaker_state"] == "closed"
        assert per["2"]["breaker_state"] == "closed"
        assert per["0"]["flushes"] >= 1 and per["2"]["flushes"] >= 1
        assert st["batch_failures"] == 1 and st["failed_requests"] == 1
        assert st["breaker"]["degraded_requests"] == 0
        # accounting conserves: warm + doomed + served all enqueued
        assert st["requests"] == 1 + 1 + served
        assert st["queue_rows"] == 0


def test_all_replicas_open_degrades_once_and_keeps_drift_window_clean():
    m = _svm(3)
    fi = FaultInjector(0)
    with Runtime(
        engine_opts=ENGINE_OPTS,
        fault_injector=fi,
        max_wait_us=500.0,
        breaker=dict(fail_threshold=1, reset_after_s=60.0),
    ) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m, replicas=2))
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))  # warm: 2 valid fast-path rows
        for i in range(2):
            fi.fail_next(FaultInjector.replica_site(ENGINE_STEP, i), 1)
        for _ in range(2):  # rotation trips replica 0 then replica 1
            with pytest.raises(InjectedFault):
                rt.submit("m", _rows(rng, 2)).result(timeout=30.0)
        # every breaker refuses -> ONE degraded exact flush for the model
        Z = _rows(rng, 5)
        res = rt.submit("m", Z).result(timeout=30.0)
        np.testing.assert_allclose(
            np.asarray(res.values), _exact_scores(m, Z)[:, 0],
            rtol=1e-4, atol=1e-5,
        )
        assert not np.asarray(res.valid).any()  # exact-served rows
        st = rt.stats("m")
        assert st["replicas"]["0"]["breaker_state"] == "open"
        assert st["replicas"]["1"]["breaker_state"] == "open"
        assert st["breaker"]["degraded_requests"] == 1
        assert st["breaker"]["degraded_rows"] == 5
        # degraded rows never enter the drift window: only the warm
        # flush's 2 valid rows were recorded (a fault is not drift)
        win = st["fallback_window"]
        assert win["rows"] == 2 and win["invalid"] == 0


def test_registry_retires_every_replica_on_count_change():
    art = maclaurin.compile(_svm(4))
    reg = ArtifactRegistry(warmup_on_load=False, engine_opts=ENGINE_OPTS)
    reg.publish("m", art, PublishSpec(replicas=2))
    _, two = reg.get_engines("m")
    assert len(two) == 2
    reg.publish("m", art, PublishSpec(replicas=3))  # same digest, new scale
    _, three = reg.get_engines("m")
    assert len(three) == 3
    # atomic retirement: no old engine survives into the new set
    assert not set(map(id, two)) & set(map(id, three))
    # replicas=None re-publish keeps the scale AND the built engines
    reg.publish("m", art)
    _, again = reg.get_engines("m")
    assert len(again) == 3
    assert [id(e) for e in again] == [id(e) for e in three]


def test_runtime_survives_replica_count_change_mid_traffic():
    m = _svm(5)
    art = maclaurin.compile(m)
    with Runtime(engine_opts=ENGINE_OPTS, max_wait_us=500.0) as rt:
        rt.publish("m", art, PublishSpec(exact=m, replicas=2))
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))
        rt.publish("m", art, PublishSpec(exact=m, replicas=3))  # hot re-scale
        Z = _rows(rng, 4)
        vals, _ = rt.predict("m", Z)  # stale batcher retired, rebuilt
        np.testing.assert_allclose(vals, _exact_scores(m, Z)[:, 0], atol=0.15)
        assert len(rt.registry.get_engines("m")[1]) == 3


# ------------------------------------------------------ head-sharded serving


def test_pad_heads_is_argmax_and_validity_neutral():
    art = maclaurin.compile(_svm_mc(6, k=6))
    padded = maclaurin.pad_heads(art, 4)  # 6 -> 8 heads
    assert padded.meta["padded_heads"] == 8
    assert padded.meta["num_heads"] == 6  # real width preserved
    Z = jnp.asarray(_rows(np.random.default_rng(0), 16))
    ref, ref_valid = maclaurin.score(art, Z)
    got, got_valid = maclaurin.score(padded, Z)
    np.testing.assert_allclose(np.asarray(got[:, :6]), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    # pad heads score PAD_HEAD_BIAS: argmax can never land on them
    assert int(np.asarray(got).argmax(axis=1).max()) < 6
    np.testing.assert_array_equal(np.asarray(got_valid), np.asarray(ref_valid))
    # already-aligned width is a no-op, not a copy
    assert maclaurin.pad_heads(art, 2) is art


def test_head_sharded_engine_matches_unsharded():
    mesh = _head_mesh()
    shards = mesh.shape["heads"]
    k = 4 * shards + 1 if shards > 1 else 6  # force padding when sharded
    m = _svm_mc(7, k=k)
    art = maclaurin.compile(m)
    ref = SVMEngine(art, **ENGINE_OPTS)
    shd = SVMEngine(art, head_mesh=mesh, **ENGINE_OPTS)
    if shards > 1:
        assert shd._serve_artifact.meta["padded_heads"] % shards == 0
    Z = _rows(np.random.default_rng(0), 32)
    r_ref = ref.submit(Z)
    r_shd = shd.submit(Z)
    assert np.asarray(r_shd.values).shape == (32, k)  # pad columns sliced
    np.testing.assert_allclose(
        np.asarray(r_shd.values), np.asarray(r_ref.values),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(r_shd.labels), np.asarray(r_ref.labels)
    )
    np.testing.assert_array_equal(
        np.asarray(r_shd.valid), np.asarray(r_ref.valid)
    )


def test_head_sharded_fourier_matches_unsharded():
    mesh = _head_mesh()
    shards = mesh.shape["heads"]
    k = 2 * shards + 1 if shards > 1 else 5
    m = _svm_mc(8, k=k, scale=0.4)
    art = fourier.compile(m, num_features=512)
    ref = SVMEngine(art, **ENGINE_OPTS)
    shd = SVMEngine(art, head_mesh=mesh, **ENGINE_OPTS)
    Z = _rows(np.random.default_rng(1), 16, scale=0.25)
    r_ref = ref.submit(Z)
    r_shd = shd.submit(Z)
    np.testing.assert_allclose(
        np.asarray(r_shd.values), np.asarray(r_ref.values),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(r_shd.labels), np.asarray(r_ref.labels)
    )


def test_head_sharded_int8_quadform_matches_unsharded():
    # Flipped from the PR-7 rejection test: int8 quadform now shards.
    mesh = _head_mesh()
    shards = mesh.shape["heads"]
    k = 2 * shards + 1 if shards > 1 else 5  # force padding when sharded
    m = _svm_mc(9, k=k)
    q = maclaurin.compile(m, dtype="int8")
    ref = SVMEngine(q, **ENGINE_OPTS)
    shd = SVMEngine(q, head_mesh=mesh, **ENGINE_OPTS)
    Z = _rows(np.random.default_rng(0), 16)
    r_ref = ref.submit(Z)
    r_shd = shd.submit(Z)
    np.testing.assert_allclose(
        np.asarray(r_shd.values), np.asarray(r_ref.values),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(r_shd.labels), np.asarray(r_ref.labels)
    )
    np.testing.assert_array_equal(
        np.asarray(r_shd.valid), np.asarray(r_ref.valid)
    )


def test_head_sharded_fastfood_matches_unsharded():
    # Flipped from the PR-7 rejection test: structured fourier now shards,
    # in both dtypes.
    mesh = _head_mesh()
    shards = mesh.shape["heads"]
    k = 2 * shards + 1 if shards > 1 else 5
    m = _svm_mc(9, k=k, scale=0.4)
    for dtype in ("float32", "int8"):
        art = fourier.compile(
            m, num_features=256, structured=True, dtype=dtype
        )
        ref = SVMEngine(art, **ENGINE_OPTS)
        shd = SVMEngine(art, head_mesh=mesh, **ENGINE_OPTS)
        Z = _rows(np.random.default_rng(1), 16, scale=0.25)
        r_ref = ref.submit(Z)
        r_shd = shd.submit(Z)
        np.testing.assert_allclose(
            np.asarray(r_shd.values), np.asarray(r_ref.values),
            rtol=1e-4, atol=1e-5,
        )
        np.testing.assert_array_equal(
            np.asarray(r_shd.labels), np.asarray(r_ref.labels)
        )


def _synthetic_fastfood_artifact(k, d=32, num_features=64, seed=0,
                                 dtype="float32"):
    """A fastfood artifact with K heads built directly from arrays —
    compiling a real K=4096 one-vs-rest model would dwarf the test."""
    rng = np.random.default_rng(seed)
    from repro.core.families.base import CompiledArtifact, base_meta

    arrays, f, proj_meta = fourier._fastfood_arrays(rng, d, num_features, 0.5)
    arrays = dict(arrays)
    arrays["phase"] = jnp.asarray(
        rng.uniform(0, 2 * np.pi, (f,)).astype(np.float32)
    )
    arrays["weights"] = jnp.asarray(
        (rng.standard_normal((k, f)) * 0.05).astype(np.float32)
    )
    arrays["b"] = jnp.asarray((rng.standard_normal(k) * 0.1).astype(np.float32))
    art = CompiledArtifact(
        family="fourier",
        arrays=arrays,
        meta=base_meta(
            d=d, num_heads=k, multiclass=True, kind="rff",
            validity="global", num_features=f, seed=seed, **proj_meta,
        ),
    )
    if dtype == "int8":
        art = fourier.quantize_fastfood_artifact(art)
    return art


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_head_sharded_fastfood_argmax_parity_at_k4096(dtype):
    """ISSUE 8 acceptance: extreme-multiclass (K=4096) Fastfood serving
    under head_mesh keeps exact argmax parity with the unsharded path."""
    mesh = _head_mesh()
    art = _synthetic_fastfood_artifact(4096, dtype=dtype)
    Z = _rows(np.random.default_rng(2), 24, d=32)
    ref = SVMEngine(art, **ENGINE_OPTS)
    shd = SVMEngine(art, head_mesh=mesh, **ENGINE_OPTS)
    r_ref = ref.submit(Z)
    r_shd = shd.submit(Z)
    assert np.asarray(r_shd.values).shape == (24, 4096)
    np.testing.assert_allclose(
        np.asarray(r_shd.values), np.asarray(r_ref.values),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(r_shd.labels), np.asarray(r_ref.labels)
    )


def test_runtime_serves_head_sharded_replicas():
    """The two scale-out axes compose: replicated dispatch over engines
    that each serve the head-sharded path."""
    mesh = _head_mesh()
    m = _svm_mc(10, k=6)
    art = maclaurin.compile(m)
    opts = dict(ENGINE_OPTS, head_mesh=mesh)
    with Runtime(engine_opts=opts, max_wait_us=500.0) as rt:
        rt.publish("mc", art, PublishSpec(replicas=2))
        rng = np.random.default_rng(0)
        Z = _rows(rng, 8)
        res = rt.submit("mc", Z).result(timeout=30.0)
        assert np.asarray(res.values).shape == (8, 6)
        exact = _exact_scores(m, Z)
        np.testing.assert_array_equal(
            np.asarray(res.labels), exact.argmax(axis=1)
        )


# ------------------------------------------------------------ roofline prior


def test_roofline_prior_ranks_bigger_tiles_cheaper():
    small = TileConfig(block_n=64)
    big = TileConfig(block_n=512)
    t_small = roofline.quadform_tile_seconds(small, n=1024, d=64, k=8)
    t_big = roofline.quadform_tile_seconds(big, n=1024, d=64, k=8)
    # fewer row-blocks re-stream the stacked Hessian fewer times
    assert t_big < t_small
    assert roofline.rbf_tile_seconds(big, n=1024, d=64, m=512) < \
        roofline.rbf_tile_seconds(small, n=1024, d=64, m=512)
    # family-level closed forms: int8 streams fewer weight bytes
    f32 = roofline.family_candidate_seconds("maclaurin", "float32",
                                            n=256, d=32, k=8)
    i8 = roofline.family_candidate_seconds("maclaurin", "int8",
                                           n=256, d=32, k=8)
    assert i8 < f32
    assert roofline.family_candidate_seconds("nope", "float32",
                                             n=256, d=32, k=8) is None


def test_prune_candidates_keeps_default_under_any_prior():
    default = tuning.DEFAULTS["quadform"]
    cands = [TileConfig(block_n=b) for b in (64, 128, 256)] + [default]
    prior = lambda cfg: roofline.quadform_tile_seconds(cfg, n=512, d=32, k=4)
    kept = autotune.prune_candidates(cands, default, prior, keep=1)
    assert default in kept  # never-worse-than-default survives pruning
    assert len(kept) <= 2
    assert kept == [c for c in cands if c in set(kept)]  # order preserved
    # an adversarial prior (default ranked worst) still keeps it
    bad = autotune.prune_candidates(
        cands, default, lambda c: -prior(c), keep=1
    )
    assert default in bad


def test_compile_model_prunes_predictably_expensive_candidates():
    m = _svm(11, scale=0.4)
    sample = _rows(np.random.default_rng(0), 64, scale=0.3)
    art = compile_model(
        m,
        Budget(max_err=0.05),
        sample=sample,
        families=("maclaurin", "fourier"),
        family_opts={"fourier": {"num_features": 65536}},
    )
    rows = art.meta["compile_report"]["families"]
    pruned = [r for r in rows if r.get("skipped") == "pruned_by_cost"]
    assert pruned, rows  # a 65536-feature basis prices itself out
    assert all("predicted_cost_s" in r for r in pruned)
    assert art.family == "maclaurin"
    # exhaustive mode: cost_margin=None measures everything
    art2 = compile_model(
        m,
        Budget(max_err=0.05),
        sample=sample,
        families=("maclaurin",),
        cost_margin=None,
    )
    rows2 = art2.meta["compile_report"]["families"]
    assert not any(r.get("skipped") == "pruned_by_cost" for r in rows2)


def test_per_replica_span_counts_sum_to_model_totals_under_faults():
    """Observability across scale-out: the tracer's per-replica served
    sub-keys (plus the degraded sub-key) partition the model's served
    total, and a scripted per-replica fault shows up attributed to
    exactly that replica's flush — even though the span ring could have
    evicted the individual spans."""
    m = _svm(5)
    fi = FaultInjector(0)
    obs = Observability(seed=2, registry=MetricsRegistry())
    with Runtime(
        engine_opts=ENGINE_OPTS,
        fault_injector=fi,
        max_wait_us=500.0,
        breaker=dict(fail_threshold=1, reset_after_s=60.0),
        obs=obs,
    ) as rt:
        digest = rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m, replicas=3))
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))            # warm flush -> replica 0
        fi.fail_next(FaultInjector.replica_site(ENGINE_STEP, 1), 1)
        doomed = rt.submit("m", _rows(rng, 3))    # rotation -> replica 1
        with pytest.raises(InjectedFault):
            doomed.result(timeout=30.0)
        for _ in range(6):
            rt.submit("m", _rows(rng, 4)).result(timeout=30.0)

        st = rt.stats("m")
        counts = obs.tracer.counts(digest[:12])
        per_replica = {
            i: counts.get(f"request.served[replica={i}]", 0) for i in range(3)
        }
        degraded = counts.get("request.served[degraded]", 0)
        assert sum(per_replica.values()) + degraded == counts["request.served"]
        assert counts["request.served"] == st["served_requests"] == 7
        assert degraded == 0                      # siblings kept the fast path
        # replica 1 served nothing after its trip; 0 and 2 carried the load
        assert per_replica[1] == 0
        assert per_replica[0] >= 1 and per_replica[2] >= 1
        # the injected fault is attributed to replica 1, span- and count-wise
        assert counts.get("flush.failed[replica=1]", 0) == 1
        assert counts.get("request.failed", 0) == 1 == st["failed_requests"]
        cons = obs.tracer.conservation(digest[:12])
        assert cons["unaccounted"] == 0 and cons["submitted"] == 8


def test_degraded_rows_never_appear_in_validity_spans():
    """flush.validity spans are the drift window's span-level twin: they
    must cover fast-path rows only. A degraded (all-breakers-open) exact
    flush emits flush.degraded / degraded request.served spans instead,
    so the validity spans' row total equals the fallback window's."""
    m = _svm(3)
    fi = FaultInjector(0)
    obs = Observability(seed=4, registry=MetricsRegistry())
    with Runtime(
        engine_opts=ENGINE_OPTS,
        fault_injector=fi,
        max_wait_us=500.0,
        breaker=dict(fail_threshold=1, reset_after_s=60.0),
        obs=obs,
    ) as rt:
        digest = rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m, replicas=2))
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))            # warm: 2 fast-path rows
        for i in range(2):
            fi.fail_next(FaultInjector.replica_site(ENGINE_STEP, i), 1)
        for _ in range(2):                        # trip both breakers
            with pytest.raises(InjectedFault):
                rt.submit("m", _rows(rng, 2)).result(timeout=30.0)
        res = rt.submit("m", _rows(rng, 5)).result(timeout=30.0)
        assert not np.asarray(res.valid).any()    # exact-served rows

        key = digest[:12]
        validity = obs.tracer.spans(key, "flush.validity")
        assert validity, "fast-path flushes must record validity spans"
        assert all(not s["attrs"].get("degraded") for s in validity)
        valid_rows = sum(s["attrs"]["rows"] for s in validity)
        st = rt.stats("m")
        assert valid_rows == st["fallback_window"]["rows"] == 2
        # the degraded flush is traced as degraded, not as drift evidence
        degraded = obs.tracer.spans(key, "flush.degraded")
        assert len(degraded) == 1 and degraded[0]["attrs"]["rows"] == 5
        served = obs.tracer.spans(key, "request.served")
        by_degraded = [s for s in served if s["attrs"].get("degraded")]
        assert len(by_degraded) == 1
        assert all("replica" not in s["attrs"] for s in by_degraded)
        assert obs.tracer.counts(key).get("request.served[degraded]") == 1
