"""Observability layer (PR 9): deterministic request tracing, the unified
metrics registry with Prometheus text exposition, nearest-rank latency
percentiles, DriftGuard heal history, and the ``jax.profiler`` hooks.

The load-bearing property is three-way conservation: every submitted
request is accounted for (served + shed + failed + timed-out + closed ==
submitted) in the telemetry counters, in the tracer's monotone span
counts, AND in the Prometheus rendering — under healthy traffic and
under seeded chaos interleavings alike.
"""

import json
import re

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gamma_max
from repro.core.rbf import SVMModel
from repro.core.families import Budget, compile_model, maclaurin
from repro.serve import PublishSpec, Runtime
from repro.serve.runtime import (
    ENGINE_STEP,
    DriftGuard,
    FaultInjector,
    InjectedFault,
    MetricsRegistry,
    Observability,
    Tracer,
)
from repro.serve.runtime.telemetry import LatencyWindow, _nearest_rank

ENGINE_OPTS = dict(min_bucket=8, max_batch=64)


def _svm(seed=0, d=8, n_sv=40, bias=0.1, scale=0.6):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * scale
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
    return SVMModel(
        X=jnp.asarray(X),
        alpha_y=jnp.asarray(ay),
        b=jnp.float32(bias),
        gamma=jnp.float32(gamma),
    )


def _rows(rng, n, d=8, scale=0.6):
    return rng.standard_normal((n, d)).astype(np.float32) * scale


_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{.*\})? [^ ]+$",
)


def _parse_prometheus(text):
    """Validate the text format line by line; return {metric: n_samples}."""
    samples = {}
    typed = set()
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            assert len(parts) >= 3, line
            if line.startswith("# TYPE "):
                assert parts[3] in ("counter", "gauge", "histogram"), line
                typed.add(parts[2])
            continue
        assert _SAMPLE_RE.match(line), f"bad sample line: {line!r}"
        name = line.split("{", 1)[0].split(" ", 1)[0]
        samples[name] = samples.get(name, 0) + 1
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        assert name in typed or base in typed, f"untyped sample: {line!r}"
    return samples


def _counter_total(registry, name):
    """Sum a counter family's children across all label sets."""
    return sum(registry.collect().get(name, {}).values())


# ---------------------------------------------------------------- metrics


def test_registry_renders_valid_prometheus_text():
    reg = MetricsRegistry()
    c = reg.counter("demo_requests_total", "Requests.", ("model", "verdict"))
    c.labels(model="m1", verdict="ok").inc()
    c.labels(model="m1", verdict="ok").inc(2)
    c.labels(model='we"ird\\na{me}', verdict="shed").inc()
    g = reg.gauge("demo_depth", "Queue depth.", ("model",))
    g.labels(model="m1").set(7)
    h = reg.histogram(
        "demo_latency_seconds", "Latency.", ("model",), buckets=(0.1, 1.0)
    )
    h.labels(model="m1").observe(0.05)
    h.labels(model="m1").observe(0.5)
    h.labels(model="m1").observe(5.0)

    text = reg.render()
    samples = _parse_prometheus(text)
    assert samples["demo_requests_total"] == 2
    assert samples["demo_depth"] == 1
    # histogram: 2 finite buckets + +Inf + _sum + _count
    assert samples["demo_latency_seconds_bucket"] == 3
    assert samples["demo_latency_seconds_sum"] == 1
    assert samples["demo_latency_seconds_count"] == 1
    assert 'demo_latency_seconds_bucket{model="m1",le="+Inf"} 3' in text
    assert 'demo_latency_seconds_bucket{model="m1",le="0.1"} 1' in text
    assert 'demo_latency_seconds_bucket{model="m1",le="1"} 2' in text
    # label values escaped, not mangled
    assert 'model="we\\"ird\\\\na{me}"' in text
    assert c.labels(model="m1", verdict="ok").value == 3


def test_registry_rejects_type_and_label_conflicts():
    reg = MetricsRegistry()
    reg.counter("demo_total", "x", ("a",))
    reg.counter("demo_total", "x", ("a",))  # re-registration is idempotent
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("demo_total", "x", ("a",))
    with pytest.raises(ValueError, match="labels"):
        reg.counter("demo_total", "x", ("b",))
    with pytest.raises(ValueError, match="expected labels"):
        reg.counter("demo_total", "x", ("a",)).labels(wrong="v")
    with pytest.raises(ValueError, match=">= 0"):
        reg.counter("demo_total", "x", ("a",)).labels(a="v").inc(-1)


# ----------------------------------------------------------------- tracer


def test_span_ids_are_deterministic_replay():
    def drive(tracer):
        ids = [tracer.new_trace()]
        ids.append(tracer.span("m", "request.admitted", attrs={"rows": 3}))
        ids.append(tracer.span("m", "request.served", attrs={"replica": 1}))
        ids.append(tracer.span("other", "engine.step"))
        return ids

    a, b = Tracer(seed=7), Tracer(seed=7)
    assert drive(a) == drive(b)  # pure function of (seed, ordinal)
    assert drive(a) != drive(Tracer(seed=8))
    assert a.new_id() == f"{7:04x}-{8:012x}"  # 2 drives x 4 ids minted
    # ids never encode wall-clock or thread identity: a tracer with a
    # frozen clock mints the exact same ids
    frozen = Tracer(seed=7, clock=lambda: 123.0)
    assert drive(frozen) == drive(Tracer(seed=7))


def test_ring_bounds_spans_but_counts_survive_eviction():
    tracer = Tracer(seed=1, capacity=8)
    for i in range(50):
        tracer.span("m", "request.admitted", attrs={"rows": 1})
        tracer.span("m", "request.served", attrs={"replica": i % 2})
    assert len(tracer.spans("m")) == 8  # ring forgot the early spans
    counts = tracer.counts("m")
    assert counts["request.admitted"] == 50  # accounting did not
    assert counts["request.served"] == 50
    assert counts["request.served[replica=0]"] == 25
    assert counts["request.served[replica=1]"] == 25
    cons = tracer.conservation("m")
    assert cons["submitted"] == 50 and cons["unaccounted"] == 0


def test_jsonl_export_round_trips(tmp_path):
    tracer = Tracer(seed=2, clock=lambda: 5.0)
    trace = tracer.new_trace()
    tracer.span("m", "request.admitted", trace_id=trace, attrs={"rows": 4})
    tracer.span("m", "request.served", trace_id=trace, attrs={"replica": 0})
    path = tmp_path / "spans.jsonl"
    assert tracer.export_jsonl(path) == 2
    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["name"] for r in records] == ["request.admitted", "request.served"]
    assert all(r["trace_id"] == trace for r in records)
    assert records[0]["attrs"] == {"rows": 4}
    assert records[0]["t_start"] == records[0]["t_end"] == 5.0


# ------------------------------------------------------------ percentiles


def test_nearest_rank_percentiles_at_small_n():
    # nearest-rank: idx = ceil(p/100 * n) - 1 over the sorted window.
    # At small n this is exact and never interpolates.
    assert _nearest_rank([3.0], 50) == 3.0
    assert _nearest_rank([3.0], 99) == 3.0
    assert _nearest_rank([1.0, 2.0], 50) == 1.0
    assert _nearest_rank([1.0, 2.0], 99) == 2.0
    assert _nearest_rank([1.0, 2.0, 3.0, 4.0], 50) == 2.0
    assert _nearest_rank([1.0, 2.0, 3.0, 4.0], 99) == 4.0

    for n, p50, p99 in [(1, 10.0, 10.0), (2, 10.0, 20.0), (4, 20.0, 40.0)]:
        win = LatencyWindow(maxlen=64)
        for i in range(n):
            win.record((i + 1) * 0.010)
        snap = win.snapshot()
        assert snap["n"] == n
        assert snap["p50_ms"] == pytest.approx(p50)
        assert snap["p99_ms"] == pytest.approx(p99)


# ---------------------------------------------------- runtime integration


def test_runtime_exposes_first_class_gauges_and_spans():
    m = _svm(0)
    obs = Observability(seed=3, registry=MetricsRegistry())
    rng = np.random.default_rng(1)
    with Runtime(engine_opts=ENGINE_OPTS, max_wait_us=500.0, obs=obs) as rt:
        digest = rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m, replicas=2))
        rt.predict("m", _rows(rng, 2))
        futs = [rt.submit("m", _rows(rng, 3)) for _ in range(8)]
        for f in futs:
            f.result(timeout=30.0)

        text = rt.render_prometheus()
        samples = _parse_prometheus(text)
        for gauge in (
            "repro_serve_validity_fraction",
            "repro_serve_fallback_rate",
            "repro_serve_queue_rows",
            "repro_serve_step_time_ewma_seconds",
        ):
            assert samples.get(gauge) == 1, gauge
        # per-replica breaker state: one sample per replica, closed == 0
        assert samples.get("repro_serve_breaker_state") == 2
        assert "repro_serve_breaker_state{" in text
        assert _counter_total(obs.metrics, "repro_serve_requests_total") == 9
        assert "repro_serve_request_latency_seconds_bucket" in text

        key = digest[:12]
        steps = rt.obs.tracer.spans(key, "engine.step")
        assert steps, "engine steps must be traced"
        for s in steps:
            assert s["attrs"]["bucket"] in (8, 16, 32, 64)
            assert "TileConfig" in s["attrs"]["tile_config"]
            assert s["attrs"]["recompiled"] in (True, False)
            assert s["attrs"]["replica"] in (0, 1)
        # queue-wait spans link into the same flush trace as the step
        waits = rt.obs.tracer.spans(key, "request.queue_wait")
        assert waits and all(w["trace_id"] is not None for w in waits)
        served = rt.obs.tracer.spans(key, "request.served")
        assert {s["attrs"]["replica"] for s in served} <= {0, 1}


def _conservation_identities(rt, model, digest, registry):
    """Assert the three-way conservation identity; returns the counts."""
    st = rt.stats(model)
    tele_total = (
        st["served_requests"]
        + st["failed_requests"]
        + st["deadline_timeouts"]
        + st["closed_requests"]
    )
    assert st["requests"] == tele_total, st

    cons = rt.obs.tracer.conservation(digest[:12])
    assert cons["unaccounted"] == 0, cons
    assert cons["admitted"] == st["requests"], (cons, st["requests"])
    assert cons["shed"] == st["shed_requests"]
    assert cons["served"] == st["served_requests"]
    assert cons["failed"] == st["failed_requests"]
    assert cons["expired"] == st["deadline_timeouts"]
    assert cons["closed"] == st["closed_requests"]

    prom = {
        name: _counter_total(registry, f"repro_serve_{name}_total")
        for name in (
            "requests",
            "served_requests",
            "failed_requests",
            "deadline_timeouts",
            "closed_requests",
            "shed_requests",
        )
    }
    assert prom["requests"] == st["requests"], prom
    assert prom["requests"] == (
        prom["served_requests"]
        + prom["failed_requests"]
        + prom["deadline_timeouts"]
        + prom["closed_requests"]
    ), prom
    assert prom["shed_requests"] == st["shed_requests"]
    return cons


def test_conservation_holds_under_scripted_faults():
    m = _svm(2)
    fi = FaultInjector(0)
    obs = Observability(seed=5, registry=MetricsRegistry())
    rng = np.random.default_rng(0)
    with Runtime(
        engine_opts=ENGINE_OPTS,
        fault_injector=fi,
        max_wait_us=500.0,
        breaker=dict(fail_threshold=1, reset_after_s=60.0),
        obs=obs,
    ) as rt:
        digest = rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m, replicas=2))
        rt.predict("m", _rows(rng, 2))
        fi.fail_next(FaultInjector.replica_site(ENGINE_STEP, 1), 1)
        doomed = rt.submit("m", _rows(rng, 3))
        with pytest.raises(InjectedFault):
            doomed.result(timeout=30.0)
        for _ in range(5):
            rt.submit("m", _rows(rng, 4)).result(timeout=30.0)

        cons = _conservation_identities(rt, "m", digest, obs.metrics)
        assert cons["submitted"] == 7
        assert cons["failed"] == 1 and cons["served"] == 6
        # the injected fault is visible as a failed flush span carrying
        # its replica, and the request verdict records the error type
        key = digest[:12]
        flush_failures = rt.obs.tracer.spans(key, "flush.failed")
        assert len(flush_failures) == 1
        assert flush_failures[0]["attrs"]["replica"] == 1
        failed = rt.obs.tracer.spans(key, "request.failed")
        assert failed[0]["attrs"]["error"] == "InjectedFault"


@pytest.mark.stress
def test_conservation_under_seeded_chaos_interleavings():
    """Concurrent submitters + scripted faults + admission pressure +
    close with work in flight: zero unaccounted requests in counters,
    span counts, and the Prometheus rendering alike."""
    import threading

    m = _svm(4)
    for chaos_seed in (0, 1):
        fi = FaultInjector(chaos_seed, engine_fault_rate=0.15)
        obs = Observability(seed=chaos_seed, registry=MetricsRegistry())
        rt = Runtime(
            engine_opts=ENGINE_OPTS,
            fault_injector=fi,
            max_wait_us=200.0,
            max_queue_rows=64,
            breaker=dict(fail_threshold=2, reset_after_s=0.05),
            obs=obs,
        )
        try:
            digest = rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m, replicas=2))
            rng = np.random.default_rng(chaos_seed)
            try:
                rt.predict("m", _rows(rng, 2))  # warm; may itself be faulted
            except Exception:
                pass

            def submitter(worker):
                wrng = np.random.default_rng(100 + worker)
                for _ in range(12):
                    try:
                        fut = rt.submit("m", _rows(wrng, int(wrng.integers(1, 9))))
                        fut.result(timeout=30.0)
                    except Exception:
                        pass  # every verdict is fine; accounting must balance

            threads = [threading.Thread(target=submitter, args=(w,)) for w in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        finally:
            rt.close()
        cons = _conservation_identities(rt, "m", digest, obs.metrics)
        assert cons["submitted"] == 1 + 4 * 12


# ------------------------------------------------------------ heal history


def test_heal_history_in_stats_with_injected_clock():
    m = _svm(27, scale=0.35)
    rng = np.random.default_rng(2)
    art = compile_model(
        m,
        Budget(max_err=0.05),
        sample=_rows(rng, 256, scale=0.25),
        families=("maclaurin",),
    )
    now = [100.0]
    obs = Observability(seed=9, registry=MetricsRegistry())
    with Runtime(engine_opts=ENGINE_OPTS, obs=obs) as rt:
        old_digest = rt.publish("clf", art, PublishSpec(exact=m))
        guard = DriftGuard(
            rt,
            "clf",
            exact=m,
            budget=Budget(max_err=0.08),
            threshold=0.3,
            min_rows=48,
            min_agreement=1.5,  # impossible bar -> first canary fails
            capacity=192,
            seed=9,
            clock=lambda: now[0],
        ).attach()
        for _ in range(12):
            # materializing .values feeds the validity window (deferred sync)
            fut = rt.submit("clf", _rows(rng, 8, scale=1.5))
            assert fut.result(timeout=30.0).values.shape == (8,)

        now[0] = 111.5
        verdict = guard.check()
        assert verdict["triggered"] and not verdict["healed"]
        heals = rt.stats("clf")["heals"]
        assert heals["attempts"] == 1
        assert heals["last_trigger_at"] == 111.5
        assert heals["flipped_digests"] == []
        assert heals["history"][-1]["healed"] is False
        assert heals["history"][-1]["trigger_at"] == 111.5

        now[0] = 222.5
        guard.min_agreement = 0.8
        verdict = guard.check()
        assert verdict["healed"], verdict
        new_digest = rt.registry.resolve("clf")
        assert new_digest != old_digest
        # the full arc lives on the digest that drifted ...
        heals = rt.stats(old_digest)["heals"]
        assert heals["attempts"] == 2
        assert heals["last_trigger_at"] == 222.5
        assert heals["flipped_digests"] == [new_digest]
        assert [h["healed"] for h in heals["history"]] == [False, True]
        assert heals["history"][-1]["new_digest"] == new_digest
        # ... and the flip is mirrored onto the alias's new digest, so
        # watching ``stats("clf")`` across the swap keeps the heal visible
        heals = rt.stats("clf")["heals"]
        assert heals["attempts"] == 1
        assert heals["last_trigger_at"] == 222.5
        assert [h["healed"] for h in heals["history"]] == [True]

        # the heal arc is traced as linked spans under the OLD digest
        key = old_digest[:12]
        arcs = {
            name: rt.obs.tracer.spans(key, name)
            for name in (
                "heal.trigger",
                "heal.reservoir",
                "heal.recompile",
                "heal.canary",
                "heal.flip",
            )
        }
        assert len(arcs["heal.trigger"]) == 2
        assert len(arcs["heal.canary"]) == 2
        assert len(arcs["heal.flip"]) == 1
        flip = arcs["heal.flip"][0]
        trigger = arcs["heal.trigger"][-1]
        assert flip["trace_id"] == trigger["trace_id"]
        assert flip["parent_id"] == trigger["span_id"]
        assert flip["attrs"]["new_digest"] == new_digest[:12]
        assert [c["attrs"]["passed"] for c in arcs["heal.canary"]] == [False, True]
        # canary verdicts mirrored onto the registry
        collected = obs.metrics.collect()["repro_serve_heals_total"]
        outcomes = {dict(k)["outcome"]: v for k, v in collected.items()}
        assert outcomes == {"failed": 1, "healed": 1}


# -------------------------------------------------------------- profiling


def test_runtime_profile_writes_a_trace(tmp_path):
    import os

    from repro.serve.runtime.obs import profile as obs_profile

    m = _svm(0)
    rng = np.random.default_rng(0)
    with Runtime(engine_opts=ENGINE_OPTS, obs=Observability()) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        out = rt.profile("m", _rows(rng, 4), tmp_path)
        assert out == str(tmp_path)
    assert not obs_profile.enabled()  # capture() restored the hook state
    produced = [
        os.path.join(root, f) for root, _, files in os.walk(tmp_path) for f in files
    ]
    assert produced, "jax.profiler.trace must leave trace files behind"


def test_profile_hooks_install_and_uninstall_cleanly():
    from repro.serve import svm_engine
    from repro.serve.runtime.obs import profile as obs_profile
    import repro.core.backend as backend

    assert not obs_profile.enabled()
    assert backend._profile_scope is None
    assert svm_engine._profile_annotation is None
    prev = obs_profile.enable(True)
    try:
        assert prev is False and obs_profile.enabled()
        assert backend._profile_scope is not None
        assert svm_engine._profile_annotation is not None
        with obs_profile.annotate("test/annotation"):
            pass
    finally:
        obs_profile.enable(False)
    assert backend._profile_scope is None
    assert svm_engine._profile_annotation is None
