"""Partitioning-rule unit tests + an end-to-end sharded lowering smoke test
(subprocess: needs its own XLA device count)."""

import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.partitioning import (
    DEFAULT_RULES,
    TP_ONLY_RULES,
    abstract_mesh,
    batch_pspec,
    spec_to_pspec,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh(shape=(2, 2), axes=("data", "model")):
    # AbstractMesh: rule/spec logic only needs names+sizes, not real devices
    return abstract_mesh(shape, axes)


def test_spec_to_pspec_basic():
    mesh = _mesh()
    assert spec_to_pspec(("embed", "ffn"), DEFAULT_RULES, mesh) == P("data", "model")
    assert spec_to_pspec(("vocab", "embed"), DEFAULT_RULES, mesh) == P("model", "data")
    assert spec_to_pspec((None, "heads"), DEFAULT_RULES, mesh) == P(None, "model")


def test_mesh_axis_used_at_most_once():
    mesh = _mesh()
    # ("embed", "embed") must not map 'data' twice
    ps = spec_to_pspec(("embed", "embed"), DEFAULT_RULES, mesh)
    assert ps == P("data", None)


def test_missing_mesh_axes_degrade_to_replication():
    mesh = _mesh((4,), ("model",))
    ps = spec_to_pspec(("embed", "ffn"), DEFAULT_RULES, mesh)  # no 'data' axis
    assert ps == P(None, "model")


def test_batch_pspec_single_and_multipod():
    assert batch_pspec(_mesh()) == P("data")
    m3 = _mesh((2, 2, 2), ("pod", "data", "model"))
    assert batch_pspec(m3) == P(("pod", "data"))


def test_tp_only_rules_drop_fsdp():
    mesh = _mesh()
    assert spec_to_pspec(("embed", "ffn"), TP_ONLY_RULES, mesh) == P(None, "model")


def test_rules_replace():
    r = DEFAULT_RULES.replace(ffn=("data", "model"))
    mesh = _mesh()
    assert spec_to_pspec((None, "ffn"), r, mesh) == P(None, ("data", "model"))


@pytest.mark.slow
def test_end_to_end_sharded_lowering_subprocess():
    """Reduced-config cell lowers + compiles on a (2,4) fake mesh with the
    full specs/dryrun machinery — the multi-pod dry-run in miniature."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
import sys
sys.path.insert(0, "src")
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.specs import build_cell
from repro.sharding.partitioning import DEFAULT_RULES
from repro.sharding.hints import use_hints

mesh = make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(ARCHS["smollm-135m"].reduced(), dtype="bfloat16", remat=True)
shape = ShapeConfig("mini_train", seq_len=64, global_batch=4, kind="train")
cell = build_cell(cfg, shape, mesh, DEFAULT_RULES)
with mesh, use_hints(mesh, DEFAULT_RULES):
    c = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
ma = c.memory_analysis()
assert ma.temp_size_in_bytes > 0
txt = c.as_text()
assert any(k in txt for k in ("all-reduce", "all-gather", "reduce-scatter")), "no collectives?!"
# decode cell too
shape_d = ShapeConfig("mini_decode", seq_len=128, global_batch=4, kind="decode")
cell_d = build_cell(cfg, shape_d, mesh, DEFAULT_RULES)
with mesh, use_hints(mesh, DEFAULT_RULES):
    cd = jax.jit(cell_d.step_fn, in_shardings=cell_d.in_shardings,
                 out_shardings=cell_d.out_shardings,
                 donate_argnums=cell_d.donate_argnums).lower(*cell_d.args).compile()
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, cwd=REPO,
        timeout=600,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "OK" in out.stdout
