"""End-to-end system tests: the two pillars, each exercised through their
full production path in one go."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core import approximate, decision_function, gamma_max
from repro.data.loader import lm_token_batches
from repro.data.synthetic import make_blobs
from repro.models.transformer import init_cache, init_params
from repro.serve.decode_step import greedy_generate
from repro.serve.svm_engine import SVMEngine
from repro.svm import train_lssvm
from repro.train import checkpoint as ckpt
from repro.train.train_step import OptimizerConfig, init_opt_state, make_train_step


def test_svm_pillar_end_to_end(tmp_path):
    """Pillar A: data -> train -> collapse -> bounded serving."""
    X, y = make_blobs(300, 12, seed=11, separation=2.5)
    Xtr, ytr, Xte, yte = X[:200], y[:200], X[200:], y[200:]
    gamma = 0.8 * float(gamma_max(jnp.asarray(X)))
    model = train_lssvm(jnp.asarray(Xtr), jnp.asarray(ytr),
                        jnp.float32(gamma), jnp.float32(10.0))
    engine = SVMEngine(approximate(model), model)
    labels = engine.predict_labels(jnp.asarray(Xte))
    acc = (labels == yte).mean()
    assert acc > 0.85
    exact = np.sign(np.asarray(decision_function(model, jnp.asarray(Xte))))
    assert (labels != exact).mean() < 0.02  # paper's contract under the bound
    assert engine.stats.fallback_rate == 0.0


def test_lm_pillar_end_to_end(tmp_path):
    """Pillar B: init -> train steps -> async ckpt -> restore -> decode."""
    cfg = dataclasses.replace(
        ARCHS["qwen2-0.5b"].reduced(), n_layers=2, d_model=64, n_heads=2,
        n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256,
    )
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup=2, total_steps=10)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(ocfg, params)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    make = lm_token_batches(cfg.vocab_size, batch=4, seq_len=32, seed=7)
    for s in range(4):
        batch = {k: jnp.asarray(v) for k, v in make(s).items()}
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(s))
        assert np.isfinite(float(metrics["loss"]))

    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(3, {"params": params})
    saver.wait()
    restored = ckpt.restore(str(tmp_path), 3, {"params": params})["params"]

    prompt = jnp.asarray([[1, 2, 3]], jnp.int32)
    cache = init_cache(cfg, 1, 64, params=restored, dtype=jnp.float32)
    toks, _ = greedy_generate(cfg, restored, prompt, cache, steps=4)
    assert toks.shape == (1, 4)
    assert int(toks.max()) < cfg.vocab_size
