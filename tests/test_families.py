"""The pluggable approximation-family layer: CompiledArtifact save/load
(deterministic bytes, versioning), every family served through the same
SVMEngine API, compile_model budget selection, the fourier global
fallback, and the error-bound property of each family (hypothesis when
available, seeded sweep otherwise)."""

import json
import subprocess
import sys
import zipfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Budget, CompiledArtifact, backend, compile_model, gamma_max
from repro.core.families import FAMILIES, fourier, get_family, maclaurin, score_artifact
from repro.core.rbf import SVMModel, decision_function, rbf_kernel
from repro.kernels.common import TileConfig
from repro.kernels.rff_score.kernel import rff_score_pallas
from repro.kernels.rff_score.ref import rff_score_ref
from repro.serve.svm_engine import SVMEngine

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # container baseline
    HAVE_HYPOTHESIS = False


def _svm(seed=0, d=8, n_sv=60, heads=None, scale=0.6):
    """Deterministic small model straight from an rng (no training)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * scale
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    if heads is None:
        ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
        b = jnp.float32(0.1)
    else:
        ay = rng.standard_normal((heads, n_sv)).astype(np.float32) * 0.5
        b = jnp.asarray(0.1 * rng.standard_normal(heads).astype(np.float32))
    return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                    b=b, gamma=jnp.float32(gamma))


def _exact_scores(m, Z):
    """(n, K) exact per-head scores for binary or OvR models."""
    ay2 = m.alpha_y if m.alpha_y.ndim == 2 else m.alpha_y[None, :]
    b2 = jnp.reshape(m.b, (ay2.shape[0],))
    return np.asarray(rbf_kernel(Z, m.X, m.gamma) @ ay2.T + b2[None, :])


# ---------------------------------------------------------------- artifacts


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_artifact_save_load_roundtrip(family, tmp_path):
    m = _svm(3)
    art = get_family(family).compile(m, num_features=256)
    path = str(tmp_path / f"{family}.npz")
    art.save(path)
    back = CompiledArtifact.load(path)
    assert back.family == art.family
    assert back.meta == art.meta
    assert set(back.arrays) == set(art.arrays)
    for k in art.arrays:
        np.testing.assert_array_equal(np.asarray(back.arrays[k]),
                                      np.asarray(art.arrays[k]))


def test_artifact_bytes_identical_across_processes(tmp_path):
    """Same model + seed => BIT-IDENTICAL artifact files, even from a fresh
    interpreter (content-addressable artifact stores depend on this)."""
    import os

    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "src")
    here = str(tmp_path / "here.npz")
    there = str(tmp_path / "there.npz")
    # must construct the identical model _svm(11, d=6, n_sv=24) builds
    prog = (
        f"import sys; sys.path.insert(0, {src!r})\n"
        "import numpy as np, jax.numpy as jnp\n"
        "from repro.core import gamma_max\n"
        "from repro.core.rbf import SVMModel\n"
        "from repro.core.families import fourier\n"
        "rng = np.random.default_rng(11)\n"
        "X = rng.standard_normal((24, 6)).astype(np.float32) * 0.6\n"
        "gamma = float(gamma_max(jnp.asarray(X))) * 0.8\n"
        "ay = rng.standard_normal(24).astype(np.float32) * 0.5\n"
        "m = SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),\n"
        "             b=jnp.float32(0.1), gamma=jnp.float32(gamma))\n"
        f"fourier.compile(m, num_features=64, seed=4).save({there!r})\n"
    )
    fourier.compile(_svm(11, d=6, n_sv=24), num_features=64, seed=4).save(here)
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True)
    assert proc.returncode == 0, proc.stderr
    with open(here, "rb") as a, open(there, "rb") as b:
        assert a.read() == b.read()


def test_artifact_digest_matches_bytes_and_file(tmp_path):
    """digest() is sha256(to_bytes()), and save writes exactly those bytes,
    so hashing the FILE reproduces the digest (registry lazy indexing)."""
    import hashlib

    art = maclaurin.compile(_svm(7))
    raw = art.to_bytes()
    assert art.digest() == hashlib.sha256(raw).hexdigest()
    path = str(tmp_path / "a.npz")
    art.save(path)
    with open(path, "rb") as f:
        assert f.read() == raw


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_artifact_digest_save_load_save_roundtrip(family, tmp_path):
    """save -> load -> save lands on the SAME digest (content-addressed
    stores can dedupe identical compiles no matter who re-serialized)."""
    art = get_family(family).compile(_svm(9, d=6, n_sv=24), num_features=64)
    path = str(tmp_path / "a.npz")
    art.save(path)
    back = CompiledArtifact.load(path)
    assert back.digest() == art.digest()
    path2 = str(tmp_path / "b.npz")
    back.save(path2)
    with open(path, "rb") as f1, open(path2, "rb") as f2:
        assert f1.read() == f2.read()


def test_artifact_digest_roundtrip_across_processes(tmp_path):
    """A FRESH interpreter loading the saved file and re-saving it computes
    the identical digest — the registry key is process-independent."""
    import os

    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "src")
    path = str(tmp_path / "art.npz")
    resaved = str(tmp_path / "resaved.npz")
    art = fourier.compile(_svm(11, d=6, n_sv=24), num_features=64, seed=4)
    art.save(path)
    prog = (
        f"import sys; sys.path.insert(0, {src!r})\n"
        "from repro.core.families import CompiledArtifact\n"
        f"a = CompiledArtifact.load({path!r})\n"
        f"a.save({resaved!r})\n"
        "print(a.digest())\n"
    )
    proc = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                          text=True)
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.strip() == art.digest()
    assert CompiledArtifact.load(resaved).digest() == art.digest()


def test_artifact_digest_distinguishes_content():
    a = maclaurin.compile(_svm(1))
    b = maclaurin.compile(_svm(2))
    assert a.digest() != b.digest()
    assert a.with_meta(note="x").digest() != a.digest()   # meta is content too


def test_artifact_rejects_future_format_version(tmp_path):
    import io

    from repro.core.families import base

    path = str(tmp_path / "art.npz")
    maclaurin.compile(_svm(5)).save(path)
    # forge a copy whose header claims a future format version
    with np.load(path) as z:
        header = json.loads(bytes(z["__artifact__"]).decode())
        members = {k: z[k].copy() for k in header["keys"]}
    header["format_version"] = 999
    forged = str(tmp_path / "future.npz")
    with zipfile.ZipFile(forged, "w", zipfile.ZIP_STORED) as zf:
        payload = np.frombuffer(json.dumps(header).encode(), np.uint8)
        for name, arr in {"__artifact__": payload, **members}.items():
            buf = io.BytesIO()
            np.lib.format.write_array(buf, arr, allow_pickle=False)
            base._write_member(zf, name + ".npy", buf.getvalue())
    with pytest.raises(ValueError, match="newer than this reader"):
        CompiledArtifact.load(forged)
    # and a plain npz that was never an artifact is rejected too
    plain = str(tmp_path / "plain.npz")
    np.savez(plain, x=np.zeros(3))
    with pytest.raises(ValueError, match="not a CompiledArtifact"):
        CompiledArtifact.load(plain)


def test_artifact_is_pytree():
    art = maclaurin.compile(_svm(1))
    leaves = jax.tree_util.tree_leaves(art)
    assert len(leaves) == len(art.arrays)
    moved = jax.tree_util.tree_map(lambda x: x * 1.0, art)
    assert isinstance(moved, CompiledArtifact)
    assert moved.family == art.family and moved.meta == art.meta


# ------------------------------------------------------- engine, per family


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("heads", [None, 3])
def test_engine_serves_every_family(family, heads):
    """One submit/predict API across maclaurin, poly2 and fourier, binary
    and multiclass — engine output equals the family's direct score."""
    m = _svm(7, heads=heads)
    art = get_family(family).compile(m, num_features=256)
    eng = SVMEngine(art, m, min_bucket=32, max_batch=64)
    rng = np.random.default_rng(0)
    Z = rng.standard_normal((41, 8)).astype(np.float32) * 0.3
    vals, valid = eng.predict(Z)
    direct, _ = score_artifact(art, jnp.asarray(Z))
    direct = np.asarray(direct)
    want = direct if heads else direct[:, 0]
    got = vals.copy()
    if valid.any():
        np.testing.assert_allclose(got[valid], want[valid], rtol=1e-5, atol=1e-5)
    labels = eng.predict_labels(Z)
    if heads:
        assert vals.shape == (41, 3) and labels.shape == (41,)
    else:
        assert set(np.unique(labels)) <= {-1, 1}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_engine_bit_identical_after_reload(family, tmp_path):
    """compile -> save -> load -> serve produces the SAME bits as serving
    the in-memory artifact (the npz round-trip is exact for f32/int32)."""
    m = _svm(9, heads=2)
    art = get_family(family).compile(m, num_features=128)
    path = str(tmp_path / "a.npz")
    art.save(path)
    rng = np.random.default_rng(1)
    Z = rng.standard_normal((37, 8)).astype(np.float32) * 0.3
    a = SVMEngine(art, None, min_bucket=32, max_batch=64).predict(Z)
    b = SVMEngine(CompiledArtifact.load(path), None,
                  min_bucket=32, max_batch=64).predict(Z)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_fourier_global_fallback_and_quadform_row_fallback():
    """The two validity regimes: fourier's held-out verdict is per
    ARTIFACT (tolerance violated => every row re-scored exactly), the
    quadform families' Eq 3.11 envelope is per ROW."""
    m = _svm(13)
    bad = fourier.compile(m, num_features=8, err_tolerance=1e-12)
    assert bad.meta["valid_globally"] is False
    eng = SVMEngine(bad, m)
    rng = np.random.default_rng(2)
    Z = rng.standard_normal((17, 8)).astype(np.float32) * 0.3
    vals, valid = eng.predict(Z)
    assert not valid.any() and eng.stats.fallback_rate == 1.0
    np.testing.assert_allclose(
        vals, np.asarray(decision_function(m, jnp.asarray(Z))),
        rtol=1e-4, atol=1e-4,
    )
    # quadform: only the out-of-envelope rows fall back
    art = maclaurin.compile(m)
    eng2 = SVMEngine(art, m)
    Zmix = np.concatenate([Z[:5], 50.0 * Z[:3]])
    _, valid2 = eng2.predict(Zmix)
    assert valid2[:5].all() and not valid2[5:].any()


# ------------------------------------------------------------ compile_model


def test_compile_model_meets_budget_and_reports():
    m = _svm(21, d=10, n_sv=80)
    art = compile_model(m, Budget(max_err=0.05, metric="mean_abs"), seed=3)
    rep = art.meta["compile_report"]
    assert rep["chosen"] == art.family
    assert rep["chosen_dtype"] == art.dtype
    # candidate rows cover the (family, dtype) grid
    rows = {(r["family"], r["dtype"]): r for r in rep["families"]}
    assert {f for f, _ in rows} == set(FAMILIES)
    chosen = rows[(art.family, art.dtype)]
    assert chosen["meets_budget"]
    assert chosen["mean_abs"] <= rep["limit"]
    # chosen is the fastest among budget-meeting candidates
    ok = [r for r in rep["families"] if r["meets_budget"]]
    assert chosen["latency_ms"] == min(r["latency_ms"] for r in ok)
    # the artifact actually serves
    eng = SVMEngine(art, m)
    vals, _ = eng.predict(np.asarray(m.X[:9]))
    assert vals.shape == (9,)


def test_compile_model_impossible_budget_raises():
    m = _svm(22)
    with pytest.raises(ValueError, match="no family meets"):
        compile_model(m, Budget(max_err=1e-12, metric="max_abs"), seed=1)


def test_budget_validates_metric():
    with pytest.raises(ValueError):
        Budget(max_err=0.1, metric="p99")


def test_compile_model_family_opts_can_override_defaults():
    """family_opts entries (including 'seed' and 'holdout', which
    compile_model also sets) override, not collide."""
    m = _svm(23, d=6, n_sv=30)
    art = compile_model(
        m, Budget(max_err=10.0), seed=1,
        families=("fourier",),
        family_opts={"fourier": {"seed": 7, "num_features": 32}},
    )
    assert art.meta["seed"] == 7 and art.meta["num_features"] == 32


def test_backend_family_scores_matches_score_artifact():
    """backend's family-axis front door is the same dispatch."""
    m = _svm(24)
    art = maclaurin.compile(m)
    Z = jnp.asarray(np.asarray(m.X[:7]))
    s1, v1 = backend.family_scores(art, Z)
    s2, v2 = score_artifact(art, Z)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_bound_constants_are_the_sups():
    """The per-term constants both families report are the numerical sups
    of their exp-approximation relative errors on the Eq 3.9 envelope."""
    from repro.core import POLY2_REL_ERR_AT_HALF, REL_ERR_AT_HALF
    from repro.core.bounds import maclaurin_rel_error, poly2_rel_error

    x = jnp.linspace(-0.5, 0.5, 20001)
    for rel_err, const in ((maclaurin_rel_error, REL_ERR_AT_HALF),
                           (poly2_rel_error, POLY2_REL_ERR_AT_HALF)):
        sup = float(jnp.max(rel_err(x)))
        assert sup <= const                      # the constant is a bound...
        assert sup >= const - 5e-4               # ...and a tight one


# ----------------------------------------------------------- rff primitives


@pytest.mark.parametrize("n,d,f,k", [(5, 7, 33, 1), (64, 128, 96, 4), (130, 20, 256, 3)])
def test_rff_score_pallas_matches_ref(n, d, f, k):
    """Padded-everything edge shapes through the fused kernel (interpret)."""
    rng = np.random.default_rng(n + d + f)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    W = jnp.asarray(rng.standard_normal((f, d)).astype(np.float32) * 0.3)
    phase = jnp.asarray(rng.uniform(0, 2 * np.pi, f).astype(np.float32))
    wt = jnp.asarray(rng.standard_normal((k, f)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.standard_normal(k).astype(np.float32))
    got = rff_score_pallas(Z, W, phase, wt, b,
                           config=TileConfig(block_n=32), interpret=True)
    want = rff_score_ref(Z, W, phase, wt, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rff_backend_dispatch_pallas_vs_xla():
    prev = backend.set_backend("pallas")
    try:
        rng = np.random.default_rng(0)
        Z = jnp.asarray(rng.standard_normal((40, 12)).astype(np.float32))
        W = jnp.asarray(rng.standard_normal((64, 12)).astype(np.float32) * 0.3)
        phase = jnp.asarray(rng.uniform(0, 2 * np.pi, 64).astype(np.float32))
        wt = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
        b = jnp.zeros((2,), jnp.float32)
        got = backend.rff_score(Z, W, phase, wt, b)
    finally:
        backend.set_backend(prev or "auto")
    want = backend.rff_score_xla(Z, W, phase, wt, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fastfood_projection_matches_implicit_dense_w():
    """The structured transform IS a linear map: projecting the identity
    recovers the implicit W, and the fastfood score path equals dense RFF
    scoring with that W."""
    m = _svm(31, d=6, n_sv=24)
    art = fourier.compile(m, num_features=64, structured=True, seed=2)
    assert art.meta["projection"] == "fastfood"
    a = art.arrays
    W_implicit = np.asarray(fourier._fastfood_project(
        jnp.eye(6, dtype=jnp.float32), a["ff_b"], a["ff_g"],
        a["ff_perm"], a["ff_scale"],
    )).T                                                      # (F, d)
    rng = np.random.default_rng(3)
    Z = jnp.asarray(rng.standard_normal((9, 6)).astype(np.float32))
    scores, _ = fourier.score(art, Z)
    want = rff_score_ref(Z, jnp.asarray(W_implicit), a["phase"],
                         a["weights"], a["b"])
    np.testing.assert_allclose(np.asarray(scores), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # W entries should look N(0, 2 gamma): check the variance within 25%
    g = float(m.gamma)
    assert abs(W_implicit.std() ** 2 - 2 * g) / (2 * g) < 0.25


# ------------------------------------------------------ error-bound property


def _check_family_bound(seed: int):
    """Every family's measured error respects its reported bound.

    quadform families: on Eq 3.11-valid rows, |f_hat - f| is bounded by
    rel_err_at_half * sum_i |alpha_i| K(x_i, z) (the per-term relative
    bound summed through the triangle inequality).
    fourier: the held-out error regenerated from the artifact's seed
    matches the estimate shipped in the meta.
    """
    rng = np.random.default_rng(seed)
    d = int(rng.integers(2, 9))
    n_sv = int(rng.integers(4, 24))
    m = _svm(seed, d=d, n_sv=n_sv, scale=float(rng.uniform(0.3, 1.0)))
    Z = jnp.asarray(rng.standard_normal((24, d)).astype(np.float32)
                    * rng.uniform(0.1, 0.6))
    exact = _exact_scores(m, Z)[:, 0]
    ay_abs = np.abs(np.asarray(m.alpha_y))
    K_mat = np.asarray(rbf_kernel(Z, m.X, m.gamma))           # (n, n_sv)
    term_budget = K_mat @ ay_abs                              # sum_i |a_i| K_i(z)

    for name in ("maclaurin", "poly2"):
        art = get_family(name).compile(m)
        scores, valid = score_artifact(art, Z)
        scores, valid = np.asarray(scores)[:, 0], np.asarray(valid)
        if not valid.any():
            continue
        bound = art.meta["rel_err_at_half"] * term_budget[valid] + 1e-4
        assert (np.abs(scores[valid] - exact[valid]) <= bound).all(), (
            f"{name} bound violated at seed {seed}"
        )

    art = fourier.compile(m, num_features=128, seed=seed)
    Zh = jnp.asarray(fourier.holdout_sample(m, seed))
    approx, _ = fourier.score(art, Zh)
    err = np.abs(np.asarray(approx) - _exact_scores(m, Zh))
    assert err.max() <= art.meta["holdout_max_abs_err"] * (1 + 1e-5) + 1e-6
    assert abs(err.mean() - art.meta["holdout_mean_abs_err"]) <= 1e-5


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_family_error_respects_reported_bound(seed):
        _check_family_bound(seed)

else:

    @pytest.mark.parametrize("seed", range(8))
    def test_family_error_respects_reported_bound(seed):
        _check_family_bound(seed)
