"""Fused multi-head serving path: kernel vs per-head oracle, backend
dispatch, engine shape-bucketing (zero recompiles within a bucket),
deferred sync, and the mesh-sharded exact fallback."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import approximate, backend, decision_function, gamma_max
from repro.kernels.common import TileConfig
from repro.data.synthetic import make_blobs
from repro.kernels.quadform.kernel import quadform_heads_pallas
from repro.kernels.quadform.ref import quadform_heads_ref
from repro.serve.svm_engine import SVMEngine, bucket_size
from repro.svm import train_lssvm
from repro.svm.multiclass import (
    approx_ovr_predict,
    approximate_ovr,
    ovr_predict,
    train_one_vs_rest,
)


def _random_heads(K, d, seed=0, gamma=0.05):
    rng = np.random.default_rng(seed)
    M = rng.standard_normal((K, d, d)).astype(np.float32) * 0.1
    M_all = jnp.asarray((M + M.transpose(0, 2, 1)) / 2)
    V = jnp.asarray(rng.standard_normal((K, d)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal(K).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(K).astype(np.float32))
    g = jnp.full((K,), gamma, jnp.float32)
    msq = jnp.full((K,), 2.0, jnp.float32)
    return M_all, V, c, b, g, msq


# ------------------------------------------------- fused kernel vs vmap oracle


@pytest.mark.parametrize("K", [1, 3, 10])
@pytest.mark.parametrize("n,d", [(5, 7), (64, 128), (513, 60)])
def test_fused_heads_pallas_matches_vmap_reference(K, n, d):
    """Padded-n (513), padded-d (7, 60) and aligned (128) edge shapes."""
    rng = np.random.default_rng(K * n + d)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.5)
    heads = _random_heads(K, d, seed=K)
    s_ref, zsq_ref, v_ref = quadform_heads_ref(Z, *heads)
    s, zsq, v = quadform_heads_pallas(
        Z, *heads, config=TileConfig(block_n=64), interpret=True
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(zsq), np.asarray(zsq_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))


@pytest.mark.parametrize("K", [1, 3, 10])
def test_fused_heads_xla_matches_vmap_reference(K):
    """The CPU serving path (single stacked-Hessian GEMM) is equivalent too."""
    n, d = 130, 33
    rng = np.random.default_rng(K)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.5)
    heads = _random_heads(K, d, seed=K + 1)
    s_ref, _, v_ref = quadform_heads_ref(Z, *heads)
    s, _, v = backend.quadform_heads_xla(Z, *heads)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))


def test_fused_xla_gemm_count_independent_of_heads():
    """The fused path issues ONE stacked contraction, not K: the number of
    dot_generals in the jaxpr is identical for K=1 and K=10."""
    def count_dots(K):
        d = 16
        Z = jnp.zeros((8, d))
        heads = _random_heads(K, d)
        jaxpr = jax.make_jaxpr(backend.quadform_heads_xla)(Z, *heads)
        return str(jaxpr).count("dot_general")

    assert count_dots(10) == count_dots(1)


def test_backend_dispatch_override():
    prev = backend.set_backend("pallas")
    try:
        assert backend.resolve() == "pallas"
        backend.set_backend("xla")
        assert backend.resolve() == "xla"
        with pytest.raises(ValueError):
            backend.set_backend("cuda")
    finally:
        backend.set_backend(prev or "auto")


# --------------------------------------------------------------- the engine


def _binary_engine(mesh=None, **kw):
    X, y = make_blobs(240, 6, seed=7, separation=3.0)
    X, y = jnp.asarray(X), jnp.asarray(y)
    gamma = float(gamma_max(X)) * 0.8
    m = train_lssvm(X, y, jnp.float32(gamma), jnp.float32(10.0))
    return SVMEngine(approximate(m), m, mesh=mesh, **kw), m, X


def test_bucket_size_policy():
    assert bucket_size(1) == 32
    assert bucket_size(32) == 32
    assert bucket_size(33) == 64
    assert bucket_size(100) == 128
    assert bucket_size(10_000, max_batch=8192) == 8192


def test_engine_zero_recompiles_within_bucket():
    """Repeated batches inside one bucket never grow the jit cache."""
    eng, _, X = _binary_engine()
    rng = np.random.default_rng(0)
    for n in (1, 3, 9, 17, 31, 32):
        eng.predict(rng.standard_normal((n, 6)).astype(np.float32))
    assert eng.jit_cache_size() == 1
    eng.predict(rng.standard_normal((33, 6)).astype(np.float32))  # next bucket
    assert eng.jit_cache_size() == 2
    for n in (2, 40, 20, 64):
        eng.predict(rng.standard_normal((n, 6)).astype(np.float32))
    assert eng.jit_cache_size() == 2                       # steady state
    assert eng.stats.bucket_hits.keys() == {32, 64}


def test_engine_warmup_bounds_cache():
    eng, _, _ = _binary_engine(min_bucket=32, max_batch=128)
    n_variants = eng.warmup()
    assert n_variants == 3                                  # 32, 64, 128
    eng.predict(np.zeros((5, 6), np.float32))
    eng.predict(np.zeros((300, 6), np.float32))             # chunked: 128-buckets
    assert eng.jit_cache_size() == 3                        # nothing new compiled


def test_engine_chunks_oversized_batches():
    from repro.core import approx_decision_function

    eng, m, X = _binary_engine(min_bucket=32, max_batch=64)
    Z = jnp.concatenate([X, X], axis=0)[:150]
    f, valid = eng.predict(Z)                  # 3 chunks: 64 + 64 + 22
    assert f.shape == (150,) and valid.all()
    ref = np.asarray(approx_decision_function(eng.approx, Z))
    np.testing.assert_allclose(f, ref, rtol=1e-5, atol=1e-5)


def test_engine_fallback_exact_and_deferred_sync():
    eng, m, X = _binary_engine()
    Zbad = jnp.concatenate([X[:4], 50.0 * X[:3]], axis=0)
    r = eng.submit(Zbad)                                    # no sync yet
    r.block_until_ready()
    f, valid = r.values, r.valid
    assert (~valid).sum() == 3
    exact = np.asarray(decision_function(m, Zbad))
    np.testing.assert_allclose(f[~valid], exact[~valid], rtol=1e-4, atol=1e-4)
    assert eng.stats.fallback_instances == 3
    labels = r.labels
    assert set(np.unique(labels)) <= {-1, 1}


def test_engine_mesh_sharded_fallback():
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    eng, m, X = _binary_engine(mesh=mesh)
    Zbad = jnp.concatenate([X[:4], 50.0 * X[:3]], axis=0)
    f, valid = eng.predict(Zbad)
    exact = np.asarray(decision_function(m, Zbad))
    np.testing.assert_allclose(f[~valid], exact[~valid], rtol=1e-4, atol=1e-4)


def test_engine_multiclass_fused_argmax():
    rng = np.random.default_rng(3)
    K, n, d = 3, 120, 5
    mus = rng.standard_normal((K, d)) * 3
    X = np.concatenate([rng.standard_normal((n // K, d)) + mus[k] for k in range(K)])
    y = np.concatenate([np.full(n // K, k) for k in range(K)])
    X, y = jnp.asarray(X.astype(np.float32)), jnp.asarray(y)
    gamma = float(gamma_max(X)) * 0.5
    m = train_one_vs_rest(X, y, K, jnp.float32(gamma), jnp.float32(10.0))
    am = approximate_ovr(m)
    eng = SVMEngine(am, m)
    labels = eng.predict_labels(X)
    np.testing.assert_array_equal(labels, np.asarray(approx_ovr_predict(am, X)))
    scores, valid = eng.predict(X)
    assert scores.shape == (n, K)
    # fused exact OvR (shared kernel-matrix GEMM) agrees with the engine's
    # fallback labels on out-of-envelope rows
    Zbad = 50.0 * X[:3]
    bad_labels = eng.predict_labels(Zbad)
    np.testing.assert_array_equal(bad_labels, np.asarray(ovr_predict(m, Zbad)))
