"""Robustness layer: admission control (bounded queues, typed shed,
deadlines, SLO tightening), fault isolation (per-batch failure scatter,
circuit breaker degrading to the exact path, half-open recovery),
registry corruption quarantine, shutdown/evict future accounting, the
deterministic fault-injection harness itself, and the DriftGuard
recompile → canary → alias-flip self-healing loop. The chaos tests run
seeded faults under multi-threaded load and assert EXACT accounting:
every submitted request is served, shed, failed, or expired — and
nothing hangs."""

import os
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gamma_max
from repro.core.rbf import SVMModel, rbf_kernel
from repro.core.families import Budget, compile_model, maclaurin
from repro.serve import PublishSpec, Runtime
from repro.serve.runtime import (
    ENGINE_STEP,
    REGISTRY_LOAD,
    ArtifactCorrupt,
    ArtifactRegistry,
    BatcherClosed,
    CircuitBreaker,
    DeadlineExceeded,
    DriftGuard,
    FaultInjector,
    InjectedFault,
    ReservoirSampler,
    RuntimeOverloaded,
)

ENGINE_OPTS = dict(min_bucket=8, max_batch=64)


def _svm(seed=0, d=8, n_sv=40, bias=0.1, scale=0.6):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * scale
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
    return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                    b=jnp.float32(bias), gamma=jnp.float32(gamma))


def _exact_scores(m, Z):
    ay2 = m.alpha_y if m.alpha_y.ndim == 2 else m.alpha_y[None, :]
    b2 = jnp.reshape(m.b, (ay2.shape[0],))
    return np.asarray(rbf_kernel(jnp.asarray(Z), m.X, m.gamma) @ ay2.T + b2[None, :])


def _rows(rng, n, d=8, scale=0.3):
    return rng.standard_normal((n, d)).astype(np.float32) * scale


# ---------------------------------------------------------- circuit breaker


def test_breaker_state_machine():
    t = [0.0]
    br = CircuitBreaker(fail_threshold=3, reset_after_s=1.0, clock=lambda: t[0])
    assert br.state == "closed" and br.allow_fast()
    br.record_failure(); br.record_failure()
    assert br.state == "closed"                      # below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow_fast()
    assert 0.0 < br.retry_after() <= 1.0
    t[0] = 0.5
    assert not br.allow_fast()                       # still inside reset window
    t[0] = 1.5
    assert br.allow_fast()                           # this call IS the probe
    assert br.state == "half_open"
    br.record_failure()                              # probe fails -> reopen
    assert br.state == "open"
    t[0] = 3.0
    assert br.allow_fast() and br.state == "half_open"
    br.record_success()                              # probe passes -> closed
    assert br.state == "closed" and br.consecutive_failures == 0
    assert br.retry_after() == 0.0


def test_breaker_success_resets_failure_streak():
    br = CircuitBreaker(fail_threshold=2)
    br.record_failure(); br.record_success(); br.record_failure()
    assert br.state == "closed"                      # streak broken, not 2-in-a-row


# ------------------------------------------------------------ fault harness


def test_fault_injector_is_deterministic():
    def verdicts(seed, n=64):
        fi = FaultInjector(seed, engine_fault_rate=0.3, slow_step_rate=0.2,
                           slow_step_s=0.0, sleep=lambda s: None)
        out = []
        for _ in range(n):
            try:
                fi.check(ENGINE_STEP)
                out.append("ok")
            except InjectedFault:
                out.append("fault")
        return out

    a, b = verdicts(7), verdicts(7)
    assert a == b                                    # same seed -> same run
    assert a != verdicts(8)                          # different seed differs
    assert "fault" in a and "ok" in a


def test_fault_injector_scripts_override_rates():
    fi = FaultInjector(0, engine_fault_rate=1.0)     # every check would fault
    fi.pass_next(ENGINE_STEP, 2)
    fi.check(ENGINE_STEP)                            # scripted pass wins
    fi.check(ENGINE_STEP)
    with pytest.raises(InjectedFault) as ei:
        fi.check(ENGINE_STEP)                        # back on the seeded rate
    assert ei.value.site == ENGINE_STEP and ei.value.ordinal == 3
    snap = fi.snapshot()[ENGINE_STEP]
    assert snap["checks"] == 3 and snap["faults"] == 1


def test_corrupt_bytes_deterministic_and_corrupting():
    data = bytes(range(256)) * 8
    c1 = FaultInjector.corrupt_bytes(data, seed=5)
    c2 = FaultInjector.corrupt_bytes(data, seed=5)
    assert c1 == c2 and c1 != data and len(c1) == len(data)
    assert FaultInjector.corrupt_bytes(data, seed=6) != c1


# -------------------------------------------------------- admission control


def test_bounded_queue_sheds_with_retry_after():
    m = _svm(1)
    art = maclaurin.compile(m)
    fi = FaultInjector(0, slow_step_rate=1.0, slow_step_s=0.02)
    with Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 max_queue_rows=16, max_wait_us=100.0) as rt:
        rt.publish("m", art, PublishSpec(exact=m))
        rt.predict("m", _rows(np.random.default_rng(0), 2))  # warm
        rng = np.random.default_rng(1)
        futs, shed = [], 0
        for _ in range(80):
            try:
                futs.append(rt.submit("m", _rows(rng, 4)))
            except RuntimeOverloaded as e:
                shed += 1
                assert e.retry_after_s > 0.0         # server names its backoff
        for f in futs:
            f.result(timeout=30.0)                   # every admitted one serves
        st = rt.stats("m")
        assert shed > 0
        assert st["shed_requests"] == shed
        assert st["requests"] == len(futs) + 1       # shed never enqueued (+warm)
        assert st["queue_rows"] == 0                 # accounting drains to zero


def test_empty_queue_always_admits_oversized_request():
    m = _svm(2)
    with Runtime(engine_opts=ENGINE_OPTS, max_queue_rows=8) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        Z = _rows(np.random.default_rng(0), 32)      # 4x the queue bound
        vals, _ = rt.predict("m", Z)                 # admitted: queue was empty
        assert vals.shape == (32,)


def test_deadline_exceeded_fails_future_not_batcher():
    m = _svm(3)
    with Runtime(engine_opts=ENGINE_OPTS, max_wait_us=50_000.0) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        rng = np.random.default_rng(0)
        fut = rt.submit("m", _rows(rng, 1), deadline_s=0.005)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=10.0)
        st = rt.stats("m")
        assert st["deadline_timeouts"] == 1
        assert st["queue_rows"] == 0                 # expired rows left the gauge
        # the batcher survived: a deadline-free request still serves
        vals, _ = rt.predict("m", _rows(rng, 3))
        assert vals.shape == (3,)


def test_queue_pressure_tightens_wait():
    m = _svm(4)
    with Runtime(engine_opts=ENGINE_OPTS, max_queue_rows=16,
                 max_wait_us=10_000.0) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        rng = np.random.default_rng(0)
        # 3 queued rows on a 16-row bound is ~19% pressure: below the
        # 8-row bucket (so the flush is deadline-triggered) but above the
        # 10% threshold that marks the flush as tightened
        rt.submit("m", _rows(rng, 3)).result(timeout=10.0)
        st = rt.stats("m")
        assert st["deadline_flushes"] >= 1
        assert st["tightened_waits"] >= 1
        # an UNBOUNDED runtime never tightens (no pressure signal)
        with Runtime(engine_opts=ENGINE_OPTS, max_wait_us=10_000.0) as rt2:
            rt2.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
            rt2.submit("m", _rows(rng, 3)).result(timeout=10.0)
            assert rt2.stats("m")["tightened_waits"] == 0


# ----------------------------------------------------------- fault isolation


def test_engine_fault_fails_only_its_batch():
    m = _svm(5)
    fi = FaultInjector(0)
    with Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 breaker=dict(fail_threshold=5)) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))               # warm
        fi.fail_next(ENGINE_STEP, 1)
        doomed = rt.submit("m", _rows(rng, 3))
        with pytest.raises(InjectedFault):
            doomed.result(timeout=10.0)
        # the flush worker survived the exception: next batch serves fine
        Z = _rows(rng, 4)
        vals, _ = rt.predict("m", Z)
        np.testing.assert_allclose(
            vals, _exact_scores(m, Z)[:, 0], atol=0.15
        )
        st = rt.stats("m")
        assert st["batch_failures"] == 1
        assert st["failed_requests"] == 1 and st["failed_rows"] == 3
        assert st["breaker"]["state"] == "closed"    # one failure < threshold


def test_fault_on_one_model_leaves_others_serving():
    m1, m2 = _svm(6), _svm(7)
    fi = FaultInjector(0)
    with Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 breaker=dict(fail_threshold=1, reset_after_s=60.0)) as rt:
        rt.publish("a", maclaurin.compile(m1), PublishSpec(exact=m1))
        rt.publish("b", maclaurin.compile(m2), PublishSpec(exact=m2))
        rng = np.random.default_rng(0)
        rt.predict("a", _rows(rng, 2))
        rt.predict("b", _rows(rng, 2))
        fi.fail_next(ENGINE_STEP, 1)
        with pytest.raises(InjectedFault):
            rt.submit("a", _rows(rng, 2)).result(timeout=10.0)
        # "a" is now breaker-open (threshold 1) and degrades to exact;
        # "b" has its own breaker, untouched, and serves the fast path
        ra = rt.submit("a", _rows(rng, 3)).result(timeout=10.0)
        assert not np.asarray(ra.valid).any()        # exact-served rows
        rb = rt.submit("b", _rows(rng, 3)).result(timeout=10.0)
        assert rb.values.shape == (3,)
        assert rt.stats("a")["breaker"]["state"] == "open"
        assert rt.stats("b")["breaker"]["state"] == "closed"
        assert rt.stats("b")["batch_failures"] == 0


def test_breaker_degrades_to_exact_and_recovers():
    m = _svm(8)
    fi = FaultInjector(0)
    with Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 breaker=dict(fail_threshold=2, reset_after_s=0.1)) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))
        fi.fail_next(ENGINE_STEP, 2)
        for _ in range(2):
            with pytest.raises(InjectedFault):
                rt.submit("m", _rows(rng, 2)).result(timeout=10.0)
        st = rt.stats("m")
        assert st["breaker"]["state"] == "open" and st["breaker"]["trips"] == 1
        # open: served EXACTLY (scores match the RBF expansion, not the
        # approximation), valid all-False, fast-path fallback stats untouched
        Z = _rows(rng, 5)
        res = rt.submit("m", Z).result(timeout=10.0)
        np.testing.assert_allclose(
            np.asarray(res.values), _exact_scores(m, Z)[:, 0],
            rtol=1e-4, atol=1e-5,
        )
        assert not np.asarray(res.valid).any()
        st = rt.stats("m")
        assert st["breaker"]["degraded_requests"] == 1
        assert st["breaker"]["degraded_rows"] == 5
        assert st["engine"]["degraded_instances"] == 5
        # degraded traffic must not read as drift (validity window clean)
        assert st["fallback_window"]["rows"] == 0 or \
            st["fallback_window"]["invalid"] < st["fallback_window"]["rows"]
        time.sleep(0.15)                             # past reset_after_s
        res = rt.submit("m", _rows(rng, 3)).result(timeout=10.0)  # probe
        st = rt.stats("m")
        assert st["breaker"]["state"] == "closed"
        assert st["breaker"]["probes"] >= 1


def test_open_breaker_without_exact_sheds_typed():
    m = _svm(9)
    fi = FaultInjector(0)
    with Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 breaker=dict(fail_threshold=1, reset_after_s=60.0)) as rt:
        rt.publish("m", maclaurin.compile(m))        # NO exact model
        rng = np.random.default_rng(0)
        rt.predict("m", _rows(rng, 2))
        fi.fail_next(ENGINE_STEP, 1)
        with pytest.raises(InjectedFault):
            rt.submit("m", _rows(rng, 2)).result(timeout=10.0)
        fut = rt.submit("m", _rows(rng, 2))
        with pytest.raises(RuntimeOverloaded) as ei:
            fut.result(timeout=10.0)
        assert ei.value.retry_after_s > 0.0
        assert rt.stats("m")["breaker"]["shed_requests"] == 1


# ------------------------------------------------------- registry hardening


def test_add_file_rejects_corrupt_and_truncated(tmp_path):
    art = maclaurin.compile(_svm(10))
    good = str(tmp_path / "good.npz")
    art.save(good)
    ArtifactRegistry().add_file(good)                # sanity: clean file indexes

    flipped = str(tmp_path / "flipped.npz")
    art.save(flipped)
    FaultInjector.corrupt_file(flipped, seed=1)
    with pytest.raises(ArtifactCorrupt):
        ArtifactRegistry().add_file(flipped)

    trunc = str(tmp_path / "trunc.npz")
    art.save(trunc)
    FaultInjector.truncate_file(trunc, keep_fraction=0.4)
    with pytest.raises(ArtifactCorrupt):
        ArtifactRegistry().add_file(trunc)


def test_mutated_file_never_serves_under_old_digest(tmp_path):
    m = _svm(11)
    art = maclaurin.compile(m)
    path = str(tmp_path / "m.npz")
    art.save(path)
    reg = ArtifactRegistry(warmup_on_load=False, engine_opts=ENGINE_OPTS)
    digest = reg.add_file(path, alias="m@latest")
    # mutate on disk BEFORE first load: the digest names the old bytes
    other = maclaurin.compile(_svm(12))
    other.save(path)                                 # valid npz, wrong content
    with pytest.raises(ArtifactCorrupt) as ei:
        reg.get_engine("m")
    assert ei.value.digest == digest
    # quarantined: subsequent resolves fail fast without touching disk
    with pytest.raises(ArtifactCorrupt) as ei2:
        reg.get_engine("m")
    assert "quarantined" in str(ei2.value)
    assert reg.snapshot()["quarantined"] == 1


def test_reload_after_evict_reverifies_sha(tmp_path):
    m = _svm(13)
    art = maclaurin.compile(m)
    path = str(tmp_path / "m.npz")
    art.save(path)
    reg = ArtifactRegistry(warmup_on_load=False, engine_opts=ENGINE_OPTS,
                           memory_budget_bytes=1)    # evict everything cold
    reg.add_file(path, alias="m@latest")
    other = maclaurin.compile(_svm(14), dtype="float32")
    d2 = reg.register(other, alias="other@latest")
    _, e1 = reg.get_engine("m@latest")               # load #1 verifies + serves
    reg.get_engine("other@latest")                   # budget=1 evicts "m"
    assert reg.eviction_count >= 1
    FaultInjector.corrupt_file(path, seed=2)         # mutate while evicted
    with pytest.raises(ArtifactCorrupt):
        reg.get_engine("m@latest")                   # reload re-hashes, refuses


def test_injected_load_fault_is_transient_not_quarantined(tmp_path):
    art = maclaurin.compile(_svm(15))
    path = str(tmp_path / "m.npz")
    art.save(path)
    fi = FaultInjector(0)
    reg = ArtifactRegistry(warmup_on_load=False, engine_opts=ENGINE_OPTS,
                           fault_injector=fi)
    reg.add_file(path, alias="m@latest")
    fi.fail_next(REGISTRY_LOAD, 1)
    with pytest.raises(InjectedFault):
        reg.get_engine("m")
    _, engine = reg.get_engine("m")                  # next resolve retries
    assert engine is not None
    assert reg.snapshot()["quarantined"] == 0


# ------------------------------------------------------ shutdown / eviction


def test_close_resolves_every_pending_future_and_joins_threads():
    m = _svm(16)
    fi = FaultInjector(0, slow_step_rate=1.0, slow_step_s=0.02)
    rt = Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 max_wait_us=50_000.0)
    rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
    rng = np.random.default_rng(0)
    rt.predict("m", _rows(rng, 2))
    batcher = rt._batchers[rt.registry.resolve("m")]
    futs = [rt.submit("m", _rows(rng, 2)) for _ in range(6)]
    t0 = time.perf_counter()
    rt.close()
    assert time.perf_counter() - t0 < 10.0
    resolved = 0
    for f in futs:
        assert f.done()                              # NOTHING left pending
        try:
            f.result(timeout=0)
            resolved += 1
        except (BatcherClosed, InjectedFault):
            resolved += 1
    assert resolved == len(futs)
    batcher._worker.join(timeout=5.0)                # regression: thread exits
    assert not batcher._worker.is_alive()
    with pytest.raises(BatcherClosed):
        batcher.submit(_rows(rng, 1))


def test_eviction_mid_traffic_resolves_pending_futures():
    m1, m2 = _svm(17), _svm(18)
    rt = Runtime(engine_opts=ENGINE_OPTS, memory_budget_bytes=1,
                 warmup_on_load=False, max_wait_us=20_000.0)
    rt.publish("a", maclaurin.compile(m1), PublishSpec(exact=m1))
    rt.publish("b", maclaurin.compile(m2), PublishSpec(exact=m2))
    rng = np.random.default_rng(0)
    futs = [rt.submit("a", _rows(rng, 2)) for _ in range(4)]
    rt.predict("b", _rows(rng, 2))                   # forces eviction of "a"
    for f in futs:                                   # evict close() drained them
        r = f.result(timeout=10.0)
        assert r.values.shape == (2,)
    rt.close()


# --------------------------------------------------------------- chaos suite


def _chaos_run(seed, *, threads=8, per_thread=25, fi_kwargs=None,
               runtime_kwargs=None, deadline_every=0):
    """Seeded multi-threaded storm; returns exact outcome accounting."""
    m = _svm(seed)
    fi = FaultInjector(seed, **(fi_kwargs or {}))
    counts = {"served": 0, "shed": 0, "failed": 0, "expired": 0}
    lock = threading.Lock()
    with Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 breaker=dict(fail_threshold=3, reset_after_s=0.05),
                 **(runtime_kwargs or {})) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        try:
            rt.predict("m", _rows(np.random.default_rng(seed), 2))
        except InjectedFault:
            pass                                     # warm-up is best-effort
                                                     # under a fault rate

        def client(tid):
            rng = np.random.default_rng((seed, tid))
            got = {"served": 0, "shed": 0, "failed": 0, "expired": 0}
            for i in range(per_thread):
                dl = (0.002 if deadline_every and i % deadline_every == 0
                      else None)
                try:
                    fut = rt.submit("m", _rows(rng, int(rng.integers(1, 5))),
                                    deadline_s=dl)
                except RuntimeOverloaded:
                    got["shed"] += 1
                    continue
                try:
                    fut.result(timeout=30.0)
                    got["served"] += 1
                except DeadlineExceeded:
                    got["expired"] += 1
                except (InjectedFault, RuntimeOverloaded):
                    got["failed"] += 1
            with lock:
                for k in got:
                    counts[k] += got[k]

        ts = [threading.Thread(target=client, args=(t,)) for t in range(threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=60.0)
            assert not t.is_alive(), "client thread hung — a future never resolved"
        stats = rt.stats("m")
    return counts, stats, threads * per_thread


@pytest.mark.stress
def test_chaos_engine_faults_exact_accounting():
    counts, stats, submitted = _chaos_run(
        21, fi_kwargs=dict(engine_fault_rate=0.15),
        runtime_kwargs=dict(max_queue_rows=64),
    )
    assert sum(counts.values()) == submitted         # every request accounted
    assert counts["served"] > 0
    assert stats["queue_rows"] == 0                  # nothing left behind
    # requests the batcher admitted == served + failed through futures
    assert stats["shed_requests"] == counts["shed"]


@pytest.mark.stress
def test_chaos_slow_steps_with_deadlines_and_shedding():
    counts, stats, submitted = _chaos_run(
        22,
        fi_kwargs=dict(engine_fault_rate=0.05, slow_step_rate=0.5,
                       slow_step_s=0.01),
        runtime_kwargs=dict(max_queue_rows=48, max_wait_us=2_000.0),
        deadline_every=5,
    )
    assert sum(counts.values()) == submitted
    assert counts["served"] > 0
    assert stats["queue_rows"] == 0
    assert stats["deadline_timeouts"] == counts["expired"]


@pytest.mark.stress
def test_chaos_corrupt_file_under_load(tmp_path):
    """A model whose file is corrupted mid-flight quarantines; the OTHER
    model keeps serving through the same storm; accounting is exact."""
    m1, m2 = _svm(23), _svm(24)
    p1 = str(tmp_path / "a.npz")
    maclaurin.compile(m1).save(p1)
    rt = Runtime(engine_opts=ENGINE_OPTS, warmup_on_load=False,
                 memory_budget_bytes=1)              # every swap evicts
    rt.registry.add_file(p1, alias="a@latest", exact=m1)
    rt.publish("b", maclaurin.compile(m2), PublishSpec(exact=m2))
    rt.predict("a", _rows(np.random.default_rng(0), 2))
    FaultInjector.corrupt_file(p1, seed=3)           # mutate behind the registry
    outcomes = {"served": 0, "corrupt": 0}
    lock = threading.Lock()

    def client(tid):
        rng = np.random.default_rng((23, tid))
        got = {"served": 0, "corrupt": 0}
        for i in range(20):
            model = "a" if (tid + i) % 2 == 0 else "b"
            try:
                fut = rt.submit(model, _rows(rng, 2))
                fut.result(timeout=30.0)
                got["served"] += 1
            except ArtifactCorrupt:
                assert model == "a"                  # only the mutated model
                got["corrupt"] += 1
        with lock:
            for k in got:
                outcomes[k] += got[k]

    ts = [threading.Thread(target=client, args=(t,)) for t in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60.0)
        assert not t.is_alive()
    assert outcomes["served"] + outcomes["corrupt"] == 8 * 20
    assert outcomes["served"] > 0                    # "b" never stopped
    rt.close()


# ------------------------------------------ interleaving conservation law


def _conservation_world(max_queue_rows, fault_rate, schedule, seed):
    """Replay one submit/outcome schedule; assert shed+served+failed+
    expired == submitted and no future is left unresolved."""
    m = _svm(seed % 7)
    fi = FaultInjector(seed, engine_fault_rate=fault_rate,
                       slow_step_rate=0.3, slow_step_s=0.003)
    submitted = served = shed = failed = expired = 0
    with Runtime(engine_opts=ENGINE_OPTS, fault_injector=fi,
                 max_queue_rows=max_queue_rows, max_wait_us=1_000.0,
                 breaker=dict(fail_threshold=2, reset_after_s=0.02)) as rt:
        rt.publish("m", maclaurin.compile(m), PublishSpec(exact=m))
        rng = np.random.default_rng(seed)
        futs = []
        for step in schedule:
            submitted += 1
            dl = 0.002 if step % 3 == 0 else None
            try:
                futs.append(rt.submit("m", _rows(rng, (step % 4) + 1),
                                      deadline_s=dl))
            except RuntimeOverloaded:
                shed += 1
            if step % 5 == 0:
                time.sleep(0.002)                    # vary the interleaving
        for f in futs:
            try:
                f.result(timeout=30.0)
                served += 1
            except DeadlineExceeded:
                expired += 1
            except (InjectedFault, RuntimeOverloaded, BatcherClosed):
                failed += 1
    assert shed + served + failed + expired == submitted
    assert all(f.done() for f in futs)


@pytest.mark.stress
@pytest.mark.parametrize("seed", [31, 32, 33, 34])
def test_conservation_seeded_interleavings(seed):
    rng = np.random.default_rng(seed)
    schedule = [int(s) for s in rng.integers(0, 16, size=40)]
    _conservation_world(max_queue_rows=int(rng.integers(8, 48)),
                        fault_rate=float(rng.uniform(0, 0.3)),
                        schedule=schedule, seed=seed)


@pytest.mark.stress
def test_conservation_property_hypothesis():
    """Property form of the conservation law (runs when hypothesis is
    installed; the seeded parametrization above always runs)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        schedule=st.lists(st.integers(0, 15), min_size=1, max_size=30),
        max_queue_rows=st.integers(8, 48),
        fault_rate=st.floats(0, 0.3),
        seed=st.integers(0, 2**16),
    )
    @hyp.settings(max_examples=15, deadline=None)
    def prop(schedule, max_queue_rows, fault_rate, seed):
        _conservation_world(max_queue_rows, fault_rate, schedule, seed)

    prop()


# -------------------------------------------------------------- drift guard


def test_reservoir_sampler_seeded_and_bounded():
    r1 = ReservoirSampler(capacity=16, seed=3)
    r2 = ReservoirSampler(capacity=16, seed=3)
    rng = np.random.default_rng(0)
    stream = rng.standard_normal((200, 4)).astype(np.float32)
    for i in range(0, 200, 7):
        r1.offer(stream[i:i + 7])
        r2.offer(stream[i:i + 7])
    assert len(r1) == 16 and r1.seen == 200
    np.testing.assert_array_equal(r1.sample(), r2.sample())  # seeded replay
    # the sample is drawn from the stream, uniformly-ish over its span
    s = r1.sample()
    assert all(any(np.array_equal(row, x) for x in stream) for row in s)


def test_drift_guard_green_window_is_cheap_noop():
    m = _svm(26, scale=0.4)
    art = compile_model(m, Budget(max_err=0.05),
                        sample=_rows(np.random.default_rng(0), 128, scale=0.3))
    with Runtime(engine_opts=ENGINE_OPTS) as rt:
        rt.publish("clf", art, PublishSpec(exact=m))
        guard = DriftGuard(rt, "clf", exact=m, budget=Budget(max_err=0.05),
                           threshold=0.5, min_rows=32, seed=5).attach()
        rng = np.random.default_rng(1)
        for _ in range(6):
            rt.submit("clf", _rows(rng, 8, scale=0.3)).result().values
        v = guard.check()
        assert not v["healed"]
        assert rt.stats("clf")["canary"]["recompiles"] == 0


def test_drift_guard_end_to_end_heal():
    """The acceptance-criteria loop: in-distribution traffic serves the
    fast path; drifted traffic pushes the windowed fallback rate over
    threshold; the guard recompiles on reservoir-sampled traffic,
    canaries against the exact judge, flips the alias atomically with
    zero dropped in-flight requests; post-flip fallback drops."""
    m = _svm(27, scale=0.35)
    rng = np.random.default_rng(2)
    art = compile_model(m, Budget(max_err=0.05),
                        sample=_rows(rng, 256, scale=0.25),
                        families=("maclaurin",))
    with Runtime(engine_opts=ENGINE_OPTS) as rt:
        rt.publish("clf", art, PublishSpec(exact=m))
        guard = DriftGuard(rt, "clf", exact=m, budget=Budget(max_err=0.08),
                           threshold=0.3, min_rows=48, min_agreement=0.9,
                           capacity=192, seed=9).attach()
        # phase 1: in-distribution -> fast path, green window
        for i in range(8):
            r = rt.submit("clf", _rows(rng, 8, scale=0.25)).result()
            assert np.asarray(r.valid).all()
        assert guard.fallback_rate()["rate"] < 0.05
        assert not guard.check()["triggered"]
        old_digest = rt.registry.resolve("clf")

        # phase 2: drifted traffic (norms past the Maclaurin bound)
        in_flight = [rt.submit("clf", _rows(rng, 8, scale=1.5))
                     for _ in range(12)]
        for f in in_flight:
            # materializing triggers the exact fallback patch AND feeds
            # the validity window (deferred sync records on first touch)
            assert f.result(timeout=30.0).values.shape == (8,)
        window = guard.fallback_rate()
        assert window["rate"] > 0.3 and window["rows"] >= 48

        # phase 3: heal — submit more traffic DURING the flip to prove
        # nothing in flight is dropped by the alias swap
        concurrent = [rt.submit("clf", _rows(rng, 4, scale=1.5))
                      for _ in range(4)]
        verdict = guard.check()
        assert verdict["triggered"] and verdict["healed"], verdict
        assert verdict["agreement"] >= 0.9
        for f in concurrent:                         # zero dropped in-flight
            assert f.result(timeout=30.0).values.shape == (4,)

        new_digest = rt.registry.resolve("clf")
        assert new_digest == verdict["new_digest"] != old_digest
        old_stats = rt.stats(old_digest)
        assert old_stats["canary"]["recompiles"] == 1
        assert old_stats["canary"]["passed"] == 1

        # phase 4: the same drifted distribution now serves mostly fast
        for i in range(10):
            rt.submit("clf", _rows(rng, 8, scale=1.5)).result().values
        post = guard.fallback_rate()
        assert post["rate"] < 0.3, post              # healed model fits traffic


def test_drift_guard_rejects_bad_canary():
    """A candidate that disagrees with the exact judge must NOT flip."""
    m = _svm(28, scale=0.35)
    rng = np.random.default_rng(3)
    art = compile_model(m, Budget(max_err=0.05),
                        sample=_rows(rng, 128, scale=0.25),
                        families=("maclaurin",))
    with Runtime(engine_opts=ENGINE_OPTS) as rt:
        rt.publish("clf", art, PublishSpec(exact=m))
        # min_agreement=1.01 is unreachable: every canary fails
        guard = DriftGuard(rt, "clf", exact=m, budget=Budget(max_err=0.08),
                           threshold=0.2, min_rows=32, min_agreement=1.01,
                           capacity=128, seed=11).attach()
        old_digest = rt.registry.resolve("clf")
        for _ in range(10):
            rt.submit("clf", _rows(rng, 8, scale=1.5)).result().values
        verdict = guard.check()
        assert verdict["triggered"]
        assert not verdict["healed"]
        assert rt.registry.resolve("clf") == old_digest   # alias untouched
        st = rt.stats("clf")
        assert st["canary"]["failed"] >= 1 or "reason" in verdict


def test_drift_guard_cooldown_limits_heal_rate():
    m = _svm(29, scale=0.35)
    rng = np.random.default_rng(4)
    art = compile_model(m, Budget(max_err=0.05),
                        sample=_rows(rng, 128, scale=0.25),
                        families=("maclaurin",))
    with Runtime(engine_opts=ENGINE_OPTS) as rt:
        rt.publish("clf", art, PublishSpec(exact=m))
        guard = DriftGuard(rt, "clf", exact=m, budget=Budget(max_err=0.08),
                           threshold=0.2, min_rows=32, min_agreement=1.01,
                           capacity=128, seed=13, cooldown_s=300.0).attach()
        for _ in range(10):
            rt.submit("clf", _rows(rng, 8, scale=1.5)).result().values
        v1 = guard.check()                           # attempts (and fails canary)
        v2 = guard.check()                           # inside cooldown: no attempt
        assert v1["triggered"] and v2["triggered"]
        assert v2.get("reason") == "cooldown"
        assert rt.stats("clf")["canary"]["recompiles"] == 1
