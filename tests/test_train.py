"""Training-loop tests: loss goes down, microbatch equivalence, optimizer
math, gradient compression keeps convergence, schedules, clipping."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.loader import lm_token_batches
from repro.models.transformer import init_params
from repro.train.optimizer import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.train.train_step import OptimizerConfig, init_opt_state, make_train_step
from repro.train import compression


def _tiny_cfg():
    return dataclasses.replace(
        ARCHS["smollm-135m"].reduced(), n_layers=2, d_model=64, n_heads=2,
        n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=128,
    )


def test_loss_decreases():
    cfg = _tiny_cfg()
    ocfg = OptimizerConfig(peak_lr=3e-3, warmup=5, total_steps=60)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(ocfg, params)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    make = lm_token_batches(cfg.vocab_size, batch=8, seq_len=32, seed=1)
    losses = []
    for s in range(40):
        b = {k: jnp.asarray(v) for k, v in make(s).items()}
        params, opt, metrics = step_fn(params, opt, b, jnp.int32(s))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_microbatch_equivalence():
    """k microbatches of size n/k == one batch of size n (same grads)."""
    cfg = dataclasses.replace(_tiny_cfg(), remat=False, dtype="float32")
    base = OptimizerConfig(peak_lr=1e-3, microbatches=1)
    micro = OptimizerConfig(peak_lr=1e-3, microbatches=4)
    params, _ = init_params(cfg, jax.random.PRNGKey(1))
    opt1 = init_opt_state(base, params)
    opt2 = init_opt_state(micro, params)
    make = lm_token_batches(cfg.vocab_size, batch=8, seq_len=16, seed=2)
    b = {k: jnp.asarray(v) for k, v in make(0).items()}
    p1, _, m1 = jax.jit(make_train_step(cfg, base))(params, opt1, b, jnp.int32(0))
    p2, _, m2 = jax.jit(make_train_step(cfg, micro))(params, opt2, b, jnp.int32(0))
    # parameters after one step agree to numerical tolerance
    err = jax.tree.map(
        lambda a, b_: float(jnp.max(jnp.abs(a - b_))), p1, p2
    )
    assert max(jax.tree.leaves(err)) < 5e-3


def test_adamw_reduces_quadratic():
    w = {"w": jnp.array([5.0, -3.0])}
    st = adamw_init(w)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, w)
        w, st = adamw_update(w, g, st, 0.05, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.5


def test_adafactor_reduces_quadratic_matrix():
    w = {"w": jnp.ones((8, 4)) * 3.0}
    st = adafactor_init(w)
    for _ in range(300):
        g = jax.tree.map(lambda x: 2 * x, w)
        w, st = adafactor_update(w, g, st, 0.05)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.5
    # factored state is O(n+m), not O(nm)
    assert st["v"]["w"]["vr"].shape == (8,)
    assert st["v"]["w"]["vc"].shape == (4,)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100))
    lr_peak = float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100))
    lr_end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
    assert lr0 < 0.05 and abs(lr_peak - 1.0) < 1e-5 and 0.09 < lr_end < 0.11


def test_error_feedback_unbiased():
    """Across steps, compressed gradient sums converge to the true sums
    (error feedback carries the residual)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((64,)).astype(np.float32))}
    ef = compression.init_error_feedback(g_true)
    total = jnp.zeros((64,))
    for _ in range(50):
        deq, ef = compression.compress_decompress(g_true, ef)
        total = total + deq["w"]
    avg = total / 50
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g_true["w"]), atol=0.01)


def test_compressed_training_converges():
    cfg = _tiny_cfg()
    ocfg = OptimizerConfig(peak_lr=3e-3, warmup=5, total_steps=60, compress_grads=True)
    params, _ = init_params(cfg, jax.random.PRNGKey(3))
    opt = init_opt_state(ocfg, params)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    make = lm_token_batches(cfg.vocab_size, batch=8, seq_len=32, seed=4)
    losses = []
    for s in range(30):
        b = {k: jnp.asarray(v) for k, v in make(s).items()}
        params, opt, metrics = step_fn(params, opt, b, jnp.int32(s))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
